"""Analytic TPU profiler: (arch, batch, seq, hardware) -> execution duration.

This replaces the paper's offline GPU profiling pass (Sec. III-A "profiling
library"): module execution duration is the roofline max of the compute and
HBM-streaming terms, with a batch-dependent efficiency ramp (small batches
under-utilize the MXU) — producing Table-I-shaped profiles (duration affine-ish
in batch, concave throughput) for the 10 assigned architectures.
"""
from __future__ import annotations

from ..configs.base import ArchConfig
from ..core.profiles import Config, ModuleProfile
from .analytics import flops_per_token, param_count
from .hardware import CATALOG, TPUSpec

DEFAULT_BATCHES = (1, 2, 4, 8, 16, 32)


def module_duration(
    cfg: ArchConfig,
    batch: int,
    seq: int,
    hw: TPUSpec,
    *,
    mode: str = "prefill",
    base_mfu: float = 0.55,
) -> float:
    """Seconds to run one batched inference of the module on ONE chip."""
    ftok = flops_per_token(cfg, seq, decode=(mode == "decode"))
    tokens = batch * (1 if mode == "decode" else seq)
    flops = ftok * tokens
    # efficiency ramps with batch: tiny batches stall the MXU
    mfu = base_mfu * min(1.0, 0.35 + 0.65 * (batch / 16.0) ** 0.5)
    compute_t = flops / (hw.peak_flops_bf16 * mfu)
    # memory: weights stream once per batch; activations per token
    n_params = param_count(cfg, active=True)
    bytes_moved = 2.0 * n_params + tokens * cfg.d_model * 2.0 * (2 * cfg.n_layers)
    mem_t = bytes_moved / hw.hbm_bw
    fixed = 30e-6  # launch/dispatch overhead
    return fixed + max(compute_t, mem_t)


def arch_profile(
    cfg: ArchConfig,
    *,
    seq: int = 128,
    batches=DEFAULT_BATCHES,
    hardware: tuple[str, ...] = ("tpu-v5e", "tpu-v4", "tpu-v5p"),
    mode: str = "prefill",
) -> ModuleProfile:
    """A Harpagon ModuleProfile for one architecture (the planner's input)."""
    cfgs = []
    for hw_name in hardware:
        hw = CATALOG[hw_name]
        for b in batches:
            d = module_duration(cfg, b, seq, hw, mode=mode)
            cfgs.append(Config(b, round(d, 6), hw.name, hw.unit_price))
    return ModuleProfile(cfg.name, tuple(cfgs))
