from .analytic import arch_profile, module_duration
from .analytics import flops_per_token, kv_cache_bytes_per_token, param_count
from .hardware import CATALOG, TARGET, TPUSpec
from .measured import (
    corrected_profile,
    corrected_profiles,
    duration_scale,
    quantize_scale,
)

__all__ = [
    "CATALOG", "TARGET", "TPUSpec", "arch_profile", "corrected_profile",
    "corrected_profiles", "duration_scale", "flops_per_token",
    "kv_cache_bytes_per_token", "module_duration", "param_count",
    "quantize_scale",
]
