"""Per-frame results of the pipelined co-simulation + overrun attribution.

The flat engine only reports per-instance module latencies and a per-frame
end-to-end number; the pipelined core tracks every frame as an entity, so
this result object can answer the question the latency splitter
(`core.splitter`) actually poses: *which module's budget did a late frame
blow, and by how much?*

Attribution is exact, not heuristic.  For frame *f* define the per-module
sojourn ``s_m = finish_m - avail_m`` where ``avail_m`` is the instant every
parent finished (so queueing delay — including backpressure parking — counts
against the stage that queued).  The realized end-to-end latency decomposes
over the frame's critical path through the SP tree
(`core.dag.sp_critical_masks`), giving the identity::

    e2e(f) == sum_{m on path(f)} s_m(f)
    e2e(f) - sum_{m on path(f)} budget_m == sum_{m on path(f)} (s_m - budget_m)

so per-module overrun attributions sum to the frame's end-to-end overrun
beyond its critical-path budget sum (negative attribution = the module ran
under budget and donated slack).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from ...core.dag import SP, sp_critical_masks
from .stages import StageStats


class FrameTable:
    """Preallocated struct-of-arrays per-frame state, indexed by frame id.

    One numpy column per fact the co-simulation tracks about a frame —
    issue/shed/lost flags, per-stage availability / finish timestamps,
    outstanding fanout counts (``pend``), parent join counters — shared by
    the event-by-event loop (which mutates single cells as events fire) and
    the segment fast-path (which fills whole columns vectorized).  Keeping
    every record columnar is what lets both producers :meth:`finalize` into
    the same :class:`PipelineResult` with one vectorized classification
    pass, and what keeps the result object O(arrays), not O(frames) Python
    objects, at 10^5+ frames.
    """

    __slots__ = (
        "n", "topo", "issue", "shed", "lost", "resolved", "sink_bad",
        "sink_max", "sinks_left", "e2e", "avail", "finish", "pend",
        "parents_left", "child_void", "child_avail", "stalled", "flushed",
        "fan", "failed",
    )

    def __init__(
        self,
        n_frames: int,
        topo: Sequence[str],
        parents: Mapping[str, Sequence[str]],
        n_sinks: int,
    ):
        n = n_frames
        self.n = n
        self.topo = tuple(topo)
        self.issue = np.full(n, np.nan)
        self.shed = np.zeros(n, dtype=bool)
        self.lost = np.zeros(n, dtype=bool)      # materialized instances, none done
        self.resolved = np.zeros(n, dtype=bool)
        self.sink_bad = np.zeros(n, dtype=bool)  # some sink never completed
        self.sink_max = np.zeros(n)
        self.sinks_left = np.full(n, n_sinks, dtype=np.int64)
        self.e2e = np.full(n, np.nan)
        self.avail = {m: np.full(n, np.nan) for m in topo}
        self.finish = {m: np.full(n, np.nan) for m in topo}
        self.pend = {m: np.zeros(n, dtype=np.int64) for m in topo}
        self.parents_left = {
            m: np.full(n, len(parents[m]), dtype=np.int64) for m in topo
        }
        self.child_void = {m: np.zeros(n, dtype=bool) for m in topo}
        self.child_avail = {m: np.zeros(n) for m in topo}
        # always-on forensic columns (`observability.forensics`): set at
        # events that already touch the frame, so they cost one cell write
        self.stalled = np.zeros(n, dtype=bool)   # parked by backpressure
        self.flushed = np.zeros(n, dtype=bool)   # served from a partial batch
        self.fan = {m: np.zeros(n, dtype=np.int64) for m in topo}
        self.failed = np.zeros(n, dtype=bool)    # touched by a machine failure

    def finalize(self, dag, stats: dict, attempts: int) -> "PipelineResult":
        """Classify every frame and assemble the result (one vector pass).

        Frames still unresolved at end of run are wedged in-pipeline: never
        issued -> shed, otherwise lost (their sinks can never complete).
        """
        un = ~self.resolved
        if un.any():
            never_issued = un & np.isnan(self.issue)
            self.shed |= never_issued
            wedged = un & ~never_issued
            self.lost |= wedged
            self.sink_bad |= wedged
        completed = ~np.isnan(self.e2e)
        dropped = self.lost & ~self.shed & ~completed
        skipped = ~completed & ~self.shed & ~dropped
        return PipelineResult(
            modules=self.topo,
            sp=dag.sp,
            issue=self.issue,
            e2e=self.e2e,
            avail=self.avail,
            finish=self.finish,
            shed=self.shed,
            dropped=dropped,
            skipped=skipped,
            stats=stats,
            attempts=attempts,
            stalled=self.stalled,
            flushed=self.flushed,
            fan=self.fan,
            failed=self.failed,
        )


@dataclass
class PipelineResult:
    """Everything the co-simulation learned about every frame."""

    modules: tuple[str, ...]
    sp: SP
    issue: np.ndarray                 # frame issue/arrival time (NaN: never issued)
    e2e: np.ndarray                   # end-to-end latency (NaN: shed/skipped/dropped)
    avail: dict[str, np.ndarray]      # per-stage availability (all parents done)
    finish: dict[str, np.ndarray]     # per-stage completion (last instance's batch)
    shed: np.ndarray                  # bool: rejected at ingress for good
    dropped: np.ndarray               # bool: admitted but lost mid-pipeline
    skipped: np.ndarray               # bool: excluded by a zero-instance fanout
    stats: dict[str, StageStats]
    attempts: int = 0                 # closed-loop issue attempts (0 = open loop)
    # forensic columns (see `observability.forensics`): parked under
    # backpressure, served from a partial (deadline/drain/EOS) batch, and
    # per-module realized fanout counts
    stalled: "np.ndarray | None" = None
    flushed: "np.ndarray | None" = None
    fan: "dict[str, np.ndarray] | None" = None
    # frames whose in-flight work was on a machine later declared dead
    # (re-queued to siblings, or lost when none survived)
    failed: "np.ndarray | None" = None
    _path_cache: "tuple[np.ndarray, dict[str, np.ndarray]] | None" = field(
        default=None, repr=False, compare=False
    )

    @property
    def completed(self) -> np.ndarray:
        return ~np.isnan(self.e2e)

    def sojourn(self, m: str) -> np.ndarray:
        """Per-frame time spent at module ``m`` (queueing + collection +
        service + backpressure parking), NaN where never traversed."""
        return self.finish[m] - self.avail[m]

    def critical_path(self) -> tuple[np.ndarray, dict[str, np.ndarray]]:
        """``(path_latency, masks)`` — see `core.dag.sp_critical_masks`."""
        if self._path_cache is None:
            sojourns = {m: self.sojourn(m) for m in self.modules}
            self._path_cache = sp_critical_masks(self.sp, sojourns)
        return self._path_cache

    def overrun_attribution(
        self, budgets: Mapping[str, float]
    ) -> tuple[dict[str, np.ndarray], np.ndarray]:
        """Per-frame, per-module budget-overrun attribution.

        Returns ``(attr, path_budget)``: ``attr[m][f]`` is frame *f*'s
        overrun charged to module *m* (``s_m - budget_m`` on the critical
        path, 0 off it) and ``path_budget[f]`` the budget sum along the
        frame's realized critical path.  Exact identity (completed frames)::

            sum_m attr[m][f] == e2e[f] - path_budget[f]
        """
        _, masks = self.critical_path()
        attr: dict[str, np.ndarray] = {}
        path_budget = np.zeros(self.e2e.size)
        for m in self.modules:
            on = masks[m]
            attr[m] = np.where(on, self.sojourn(m) - budgets[m], 0.0)
            path_budget += np.where(on, budgets[m], 0.0)
        return attr, path_budget

    def overrun_by_module(
        self, budgets: Mapping[str, float], slo: float
    ) -> dict[str, float]:
        """Mean attributed overrun per module across SLO-missing frames —
        the one-line answer to 'which budget assignment is wrong'."""
        late = self.completed & (self.e2e > slo + 1e-9)
        if not late.any():
            return {m: 0.0 for m in self.modules}
        attr, _ = self.overrun_attribution(budgets)
        return {m: float(attr[m][late].mean()) for m in self.modules}

    def miss_report(self, slo: float, epochs=None):
        """Classify every missed/shed frame into exactly one cause (an
        `observability.forensics.MissReport`, conservation-checked —
        ``epochs`` is the control plane's audit trail when one ran)."""
        from ..observability.forensics import classify_misses

        return classify_misses(self, slo, epochs)
