from .checkpoint import restore, save
from .loop import TrainResult, cross_entropy, make_loss_fn, make_train_step, train
from .optimizer import OptConfig, adamw_init, adamw_update, schedule

__all__ = [
    "OptConfig", "TrainResult", "adamw_init", "adamw_update", "cross_entropy",
    "make_loss_fn", "make_train_step", "restore", "save", "schedule", "train",
]
