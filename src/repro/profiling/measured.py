"""Measured-profile correction: fold observed service durations into profiles.

The planner optimizes against offline profiles (here: the analytic TPU
roofline of `profiling.analytic`).  When the serving loop measures actual
batch durations (`repro.serving.service_time` trace/live sources), the
control plane needs profiles that reflect reality — otherwise every epoch
replans against the same miscalibrated roofline and provisions the same
wrong machine count.  This module is the small algebra for that correction:

* per-module duration *scale* estimation from ``(modeled, measured)``
  observation pairs (throughput-weighted: each pair contributes its
  modeled-duration weight, so big-batch observations dominate exactly as
  they dominate machine occupancy);
* **log-quantization** of scales (`quantize_scale`) so an epoch-to-epoch
  estimator wobble of a few percent maps to the *same* corrected profile —
  keeping `Planner.replan`'s memo cache hot and the hot-swap stream free of
  correction-noise churn;
* `corrected_profile` / `corrected_profiles` — scaled copies of the
  original profiles (every config's duration multiplied; throughput and
  ratio re-derive automatically).

Corrections are always expressed against the ORIGINAL profiles, never
compounded onto previously corrected ones: the estimator ratio is
measured-vs-original-modeled, so applying it twice would square it.
"""
from __future__ import annotations

import math
from typing import Iterable, Mapping

from ..core.profiles import Config, ModuleProfile


def duration_scale(pairs: "Iterable[tuple[float, float]]") -> float:
    """Measured/modeled duration scale from ``(modeled, measured)`` pairs.

    The ratio of weighted sums (not the mean of ratios): each observation
    contributes proportionally to its modeled duration, so one noisy tiny
    batch cannot swing the scale a fleet of large batches runs under.
    Returns 1.0 with no observations.
    """
    num = den = 0.0
    for modeled, measured in pairs:
        if modeled <= 0.0 or measured <= 0.0:
            continue
        num += measured
        den += modeled
    return num / den if den > 0.0 else 1.0


def quantize_scale(scale: float, tolerance: float = 0.05) -> float:
    """Snap ``scale`` to a log-spaced bucket of relative width ``tolerance``.

    Scales within one bucket of 1.0 snap to exactly 1.0 (no correction), so
    a well-calibrated profile is never churned by estimator noise.
    """
    if scale <= 0.0:
        return 1.0
    q = math.log1p(max(tolerance, 1e-6))
    return math.exp(round(math.log(scale) / q) * q)


def corrected_profile(profile: ModuleProfile, scale: float) -> ModuleProfile:
    """A copy of ``profile`` with every config duration scaled by ``scale``."""
    if scale == 1.0:
        return profile
    return ModuleProfile(
        profile.name,
        tuple(
            Config(c.batch, c.duration * scale, c.hardware, c.unit_price)
            for c in profile.configs
        ),
    )


def corrected_profiles(
    profiles: Mapping[str, ModuleProfile],
    scales: Mapping[str, float],
) -> Mapping[str, ModuleProfile]:
    """Apply per-module scales; modules absent from ``scales`` pass through.

    Returns the input mapping object itself when every scale is 1.0, so
    downstream identity/fingerprint caches see no change at all.
    """
    if all(scales.get(m, 1.0) == 1.0 for m in profiles):
        return profiles
    return {
        m: corrected_profile(p, scales.get(m, 1.0)) for m, p in profiles.items()
    }
