"""Batched planner core vs the scalar oracle.

The vectorized Algorithm-1 cascade (`config_wcl_batch` / `get_wcl_batch` /
the `_VecState` splitter) must be *bit-identical* to the scalar path it
replaced — not merely close: the scalar cascade is the reference
implementation of the paper's Theorem 1 / Algorithm 1, and `PlannerOptions
(vectorized=False)` is kept exactly so that equality stays testable.

Three layers of pinning:

* property tests: elementwise `config_wcl_batch == config_wcl` and
  `get_wcl_batch == get_wcl` across policies x full/partial x headroom x
  burst (hypothesis-driven when available, a dense fixed grid otherwise);
* plan-level: `vectorized=True` and `False` produce bit-equal plans
  (feasibility, cost, per-module schedules) over the benchmark workload
  suite, for every splitter and policy;
* DP splitter: `split="dp"` realizes `bruteforce.optimal_cost`'s optimum
  on every feasible workload of the check suite.
"""
import math
import os
import sys

import numpy as np
import pytest

from repro.core.dispatch import (
    ConfigArrays,
    Policy,
    config_wcl,
    config_wcl_batch,
)
from repro.core.harpagon import Planner, PlannerOptions
from repro.core.profiles import Config
from repro.core.scheduler import get_wcl, get_wcl_batch

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import PROFILES, workload_suite  # noqa: E402

POLICIES = (Policy.TC, Policy.RR, Policy.DT, Policy.DT_OPT)


def _configs(batches, durations, prices):
    return tuple(
        Config(b, d, "hw", p) for b, d, p in zip(batches, durations, prices)
    )


def _assert_elementwise(configs, policy, *, collect_rate, full, burst):
    arrs = ConfigArrays.build(configs)
    got = config_wcl_batch(
        arrs, policy, collect_rate=collect_rate, full=full, burst=burst
    )
    for i, c in enumerate(configs):
        cr = collect_rate[i] if isinstance(collect_rate, np.ndarray) else collect_rate
        fl = bool(full[i]) if isinstance(full, np.ndarray) else full
        exp = config_wcl(c, policy, collect_rate=cr, full=fl, burst=burst)
        assert got[i] == exp or (math.isinf(got[i]) and math.isinf(exp)), (
            policy, i, got[i], exp
        )


def _assert_get_wcl(configs, policy, rw, *, full, headroom, burst):
    arrs = ConfigArrays.build(configs)
    got = get_wcl_batch(
        arrs, policy, rw, full=full, headroom=headroom, burst=burst
    )
    for i, c in enumerate(configs):
        fl = bool(full[i]) if isinstance(full, np.ndarray) else full
        exp = get_wcl(c, policy, rw, full=fl, headroom=headroom, burst=burst)
        assert got[i] == exp or (math.isinf(got[i]) and math.isinf(exp)), (
            policy, i, got[i], exp
        )


GRID_BATCHES = (1, 2, 4, 8, 16, 32)
GRID_DURATIONS = (0.05, 0.111, 0.2, 0.32, 0.8, 1.7)
GRID_PRICES = (1.0, 1.35, 1.75, 1.0, 2.5, 0.8)
GRID_CONFIGS = _configs(GRID_BATCHES, GRID_DURATIONS, GRID_PRICES)


class TestConfigWclBatchMatchesScalar:
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("cr", [0.0, 1e-12, 0.37, 5.0, 123.456])
    @pytest.mark.parametrize("full", [True, False])
    @pytest.mark.parametrize("burst", [0.0, 0.05])
    def test_scalar_rate_grid(self, policy, cr, full, burst):
        _assert_elementwise(
            GRID_CONFIGS, policy, collect_rate=cr, full=full, burst=burst
        )

    @pytest.mark.parametrize("policy", POLICIES)
    def test_array_rate_and_mixed_full(self, policy):
        rng = np.random.default_rng(7)
        cr = rng.uniform(0.0, 40.0, len(GRID_CONFIGS))
        cr[0] = 0.0  # starved branch
        full = rng.random(len(GRID_CONFIGS)) < 0.5
        _assert_elementwise(
            GRID_CONFIGS, policy, collect_rate=cr, full=full, burst=0.02
        )

    def test_hypothesis_random_tables(self):
        hyp = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        @hyp.given(
            batches=st.lists(st.integers(1, 64), min_size=1, max_size=12),
            seed=st.integers(0, 2**32 - 1),
            policy=st.sampled_from(POLICIES),
            full=st.booleans(),
            burst=st.floats(0.0, 0.5),
        )
        @hyp.settings(max_examples=120, deadline=None)
        def check(batches, seed, policy, full, burst):
            rng = np.random.default_rng(seed)
            durations = rng.uniform(1e-3, 3.0, len(batches))
            prices = rng.uniform(0.1, 4.0, len(batches))
            configs = _configs(batches, durations, prices)
            cr = float(rng.uniform(0.0, 60.0))
            _assert_elementwise(
                configs, policy, collect_rate=cr, full=full, burst=burst
            )
            crs = rng.uniform(0.0, 60.0, len(configs))
            fulls = rng.random(len(configs)) < 0.5
            _assert_elementwise(
                configs, policy, collect_rate=crs, full=fulls, burst=burst
            )

        check()


class TestGetWclBatchMatchesScalar:
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("rw", [0.0, 0.31, 4.7, 55.0])
    @pytest.mark.parametrize("full", [True, False])
    @pytest.mark.parametrize("headroom", [0.0, 0.15])
    @pytest.mark.parametrize("burst", [0.0, 0.04])
    def test_grid(self, policy, rw, full, headroom, burst):
        _assert_get_wcl(
            GRID_CONFIGS, policy, rw, full=full, headroom=headroom, burst=burst
        )

    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("headroom", [0.0, 0.2])
    def test_mixed_full_array(self, policy, headroom):
        rng = np.random.default_rng(11)
        full = rng.random(len(GRID_CONFIGS)) < 0.5
        _assert_get_wcl(
            GRID_CONFIGS, policy, 3.3, full=full, headroom=headroom, burst=0.01
        )


def _plan_key(plan):
    return (
        plan.feasible,
        plan.cost,
        tuple(sorted((m, repr(s)) for m, s in plan.schedules.items())),
    )


class TestPlanBitEquality:
    """vectorized=True and =False must agree plan-for-plan, bit for bit."""

    @pytest.mark.parametrize("policy", POLICIES)
    def test_default_cascade(self, policy):
        suite = workload_suite(40)
        vec = Planner(PlannerOptions(policy=policy, vectorized=True))
        sca = Planner(PlannerOptions(policy=policy, vectorized=False))
        for wl in suite:
            assert _plan_key(vec.plan(wl, PROFILES)) == _plan_key(
                sca.plan(wl, PROFILES)
            )

    @pytest.mark.parametrize(
        "split", ["lc", "throughput", "even", "quantized"]
    )
    def test_each_splitter(self, split):
        suite = workload_suite(25)
        vec = Planner(PlannerOptions(split=split, vectorized=True))
        sca = Planner(PlannerOptions(split=split, vectorized=False))
        for wl in suite:
            assert _plan_key(vec.plan(wl, PROFILES)) == _plan_key(
                sca.plan(wl, PROFILES)
            )

    @pytest.mark.parametrize(
        "opts",
        [
            dict(headroom=0.1),
            dict(burst_aware=True),
            dict(k_tuples=2),
            dict(max_batch=8),
            dict(node_merge=False, cost_direct=False),
        ],
    )
    def test_option_variants(self, opts):
        suite = workload_suite(20)
        vec = Planner(PlannerOptions(vectorized=True, **opts))
        sca = Planner(PlannerOptions(vectorized=False, **opts))
        for wl in suite:
            assert _plan_key(vec.plan(wl, PROFILES)) == _plan_key(
                sca.plan(wl, PROFILES)
            )

    @pytest.mark.slow
    def test_full_suite(self):
        suite = workload_suite(200)
        vec = Planner(PlannerOptions(vectorized=True))
        sca = Planner(PlannerOptions(vectorized=False))
        for wl in suite:
            assert _plan_key(vec.plan(wl, PROFILES)) == _plan_key(
                sca.plan(wl, PROFILES)
            )


class TestDpSplitter:
    """split="dp" realizes the brute-force DP optimum."""

    def test_matches_bruteforce_optimum(self):
        from repro.core.bruteforce import optimal_cost

        suite = workload_suite(15)
        dp = Planner(PlannerOptions(split="dp", reassign=0))
        for wl in suite:
            opt = optimal_cost(wl, PROFILES)
            plan = dp.plan(wl, PROFILES)
            if math.isinf(opt):
                continue
            assert plan.feasible
            # The plan schedules each module at the DP-recovered budget
            # with the same scheduler the curves were priced with, so the
            # cost must equal the DP optimum exactly (reassigner disabled).
            assert plan.cost <= opt + 1e-9, (wl, plan.cost, opt)

    def test_reassigner_only_improves(self):
        suite = workload_suite(10)
        bare = Planner(PlannerOptions(split="dp", reassign=0))
        full = Planner(PlannerOptions(split="dp"))
        for wl in suite:
            a, b = bare.plan(wl, PROFILES), full.plan(wl, PROFILES)
            if a.feasible:
                assert b.feasible and b.cost <= a.cost + 1e-12

    def test_dp_beats_or_ties_lc(self):
        # Compare on workloads feasible for both: budget quantization can
        # (rarely) make the DP grid infeasible where the continuous LC
        # split squeezes through — the fig5 bench reports that separately
        # as the "feasible suite".
        suite = workload_suite(15)
        dp = Planner(PlannerOptions(split="dp"))
        lc = Planner(PlannerOptions(split="lc"))
        wins = ties = 0
        for wl in suite:
            pd, pl = dp.plan(wl, PROFILES), lc.plan(wl, PROFILES)
            if not (pl.feasible and pd.feasible):
                continue
            # grid quantization can cost the DP a hair; never more than 2%
            assert pd.cost <= pl.cost * 1.02 + 1e-9
            if pd.cost < pl.cost - 1e-9:
                wins += 1
            elif pd.cost <= pl.cost + 1e-9:
                ties += 1
        assert wins + ties > 0


class TestCurveCache:
    """The module cost-curve cache (ISSUE-10 satellite): curves are cached
    across workloads by quantized (rate, slo) bucket, computed exact at the
    first-seen rate/SLO in each bucket — replayed suites hit with zero
    approximation, and cached results are value-identical to cold ones."""

    def test_warm_results_identical_to_cold(self):
        from repro.core.bruteforce import (
            curve_cache_clear, curve_cache_stats, optimal_cost,
        )

        suite = workload_suite(12)
        curve_cache_clear()
        cold = [optimal_cost(wl, PROFILES) for wl in suite]
        stats = curve_cache_stats()
        assert stats["misses"] > 0
        warm = [optimal_cost(wl, PROFILES) for wl in suite]
        assert warm == cold  # exact, not approx: the same curve objects
        after = curve_cache_stats()
        assert after["hits"] > stats["hits"]
        assert after["misses"] == stats["misses"]  # full warm hit

    def test_dp_splitter_unchanged_by_cache_state(self):
        from repro.core.bruteforce import curve_cache_clear

        suite = workload_suite(6)
        dp = Planner(PlannerOptions(split="dp"))
        curve_cache_clear()
        cold = [dp.plan(wl, PROFILES).cost for wl in suite]
        warm = [dp.plan(wl, PROFILES).cost for wl in suite]
        assert warm == cold

    def test_quantization_buckets_are_log_spaced(self):
        from repro.core.bruteforce import _quantized

        # ~0.5% log buckets: a tiny perturbation shares the bucket, a
        # 1% move does not; non-positive inputs get the sentinel bucket
        assert _quantized(100.0) == _quantized(100.0001)
        assert _quantized(100.0) != _quantized(101.0)
        assert _quantized(0.0) == _quantized(-5.0) == -1
