"""Quickstart: plan a multi-DNN session with Harpagon and compare all systems.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import Planner
from repro.core.baselines import ALL_SYSTEMS
from repro.core.bruteforce import optimal_cost
from repro.workloads import synth_profiles
from repro.workloads.apps import TRAFFIC, make_workload


def main() -> None:
    profiles = synth_profiles()
    # the paper's traffic app: SSD detector -> {vehicle, pedestrian} classifiers
    wl = make_workload(TRAFFIC, rate=150.0, slo=1.2)
    print(f"workload: app={wl.app.name} rate=150/s slo={wl.slo}s "
          f"modules={list(wl.app.modules)}\n")

    plans = {}
    for opts in ALL_SYSTEMS:
        plans[opts.name] = Planner(opts).plan(wl, profiles)

    h = plans["harpagon"]
    print(h.summary(), "\n")
    opt = min(optimal_cost(wl, profiles), h.cost)
    print(f"{'system':<12} {'cost':>8} {'normalized':>11} {'e2e (s)':>9}")
    for name, p in plans.items():
        if p.feasible:
            print(f"{name:<12} {p.cost:8.2f} {p.cost / h.cost:11.3f} {p.e2e_latency:9.3f}")
        else:
            print(f"{name:<12} {'infeasible':>8}")
    print(f"{'optimal':<12} {opt:8.2f} {opt / h.cost:11.3f}")


if __name__ == "__main__":
    main()
