"""Structured trace recorder: ring-buffered typed events, Perfetto export.

The recorder is a passive sink: the serving loops call its ``span`` /
``instant`` hooks at the points where state changes (batch starts, flush
causes, admission sheds, epoch swaps), and nothing about the simulation
reads it back — results are bit-identical with tracing on or off, which is
what lets the tracing-overhead CI gate compare the two runs directly.

Two cost controls keep the hooks cheap enough for the hot path:

* a **ring buffer** of fixed ``capacity``: the recorder never grows beyond
  it; once full, the oldest events are overwritten and counted in
  :attr:`dropped` (a long run keeps its most recent window, which is the
  one a tail-latency investigation needs);
* **sampling** for the high-frequency event classes (batch spans, parking
  instants): ``sample=0.1`` records every 10th such event via a stride
  counter — deterministic, not random, so repeated runs trace identically.
  Low-frequency control-plane events (epoch swaps, admission sheds, flush
  causes) are always recorded.

Export is the Chrome trace-event JSON format (``traceEvents`` array), which
Perfetto (https://ui.perfetto.dev) loads directly: one process per module,
one thread per machine, ``X`` complete spans for batch service, ``i``
instants for flushes / sheds / epochs, and ``C`` counters for queue depth.
"""
from __future__ import annotations

import json

# event tuple layout: (kind, ts, module, mid, name, dur, args)
#   kind 0 = span (batch service), 1 = instant, 2 = counter
_SPAN, _INSTANT, _COUNTER = 0, 1, 2

# synthetic pid for events not tied to a module (admission, control plane)
_CTRL = "(frontend/control)"


class TraceRecorder:
    """Fixed-capacity ring buffer of typed serving events."""

    __slots__ = (
        "capacity", "stride", "_buf", "_head", "dropped", "_n_hot", "recorded",
    )

    def __init__(self, capacity: int = 200_000, sample: float = 1.0):
        if capacity < 1:
            raise ValueError("trace capacity must be >= 1")
        if not 0.0 < sample <= 1.0:
            raise ValueError("trace sample must be in (0, 1]")
        self.capacity = capacity
        # deterministic stride sampling: record every k-th hot event
        self.stride = max(1, round(1.0 / sample))
        self._buf: list = []
        self._head = 0
        self.dropped = 0       # events overwritten by the ring
        self._n_hot = 0        # hot-event counter driving the sample stride
        self.recorded = 0      # events actually stored (pre-ring)

    # -- recording ----------------------------------------------------------
    def _push(self, ev: tuple) -> None:
        self.recorded += 1
        buf = self._buf
        if len(buf) < self.capacity:
            buf.append(ev)
            return
        buf[self._head] = ev
        self._head = (self._head + 1) % self.capacity
        self.dropped += 1

    def sampled(self) -> bool:
        """Advance the hot-event stride; True when this event is recorded."""
        n = self._n_hot
        self._n_hot = n + 1
        return n % self.stride == 0

    def span(self, ts: float, dur: float, module: str, mid: int,
             name: str, **args) -> None:
        """A complete span (batch service) on module ``module``, machine
        ``mid`` — caller is responsible for sampling (see :meth:`sampled`)."""
        self._push((_SPAN, ts, module, mid, name, dur, args or None))

    def instant(self, ts: float, module: "str | None", mid: int,
                name: str, **args) -> None:
        """A point event (flush cause, shed, epoch swap, drain)."""
        self._push((_INSTANT, ts, module or _CTRL, mid, name, 0.0, args or None))

    def counter(self, ts: float, module: str, name: str, value: float) -> None:
        """A counter sample (queue depth) rendered as a track in Perfetto."""
        self._push((_COUNTER, ts, module, 0, name, 0.0, {"value": value}))

    # -- export -------------------------------------------------------------
    def events(self) -> list:
        """Buffered events in recording order (ring unwound)."""
        return self._buf[self._head:] + self._buf[:self._head]

    def to_chrome(self) -> dict:
        """The trace as a Chrome trace-event / Perfetto-loadable dict.

        One process per module (pid = first-seen order), one thread per
        machine id; timestamps converted to microseconds.
        """
        pids: dict[str, int] = {}
        out: list[dict] = []
        for kind, ts, module, mid, name, dur, args in self.events():
            pid = pids.get(module)
            if pid is None:
                pid = pids[module] = len(pids) + 1
            us = ts * 1e6
            if kind == _SPAN:
                ev = {
                    "name": name, "cat": "service", "ph": "X",
                    "ts": us, "dur": dur * 1e6, "pid": pid, "tid": mid,
                }
            elif kind == _INSTANT:
                ev = {
                    "name": name, "cat": "event", "ph": "i", "s": "t",
                    "ts": us, "pid": pid, "tid": mid,
                }
            else:  # _COUNTER
                ev = {
                    "name": name, "cat": "gauge", "ph": "C",
                    "ts": us, "pid": pid, "tid": 0, "args": args,
                }
            if args and kind != _COUNTER:
                ev["args"] = args
            out.append(ev)
        meta = []
        for module, pid in pids.items():
            meta.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": module},
            })
            meta.append({
                "name": "process_sort_index", "ph": "M", "pid": pid, "tid": 0,
                "args": {"sort_index": pid},
            })
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Perfetto-loadable JSON to ``path``; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")
        return path
