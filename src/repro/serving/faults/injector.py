"""The seeded fault injector and the failure-detection state machine."""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Mapping

import numpy as np

# taxonomy — see the package docstring for semantics
FAULT_KINDS = ("crash", "straggler", "device_loss")


@dataclass(frozen=True)
class FaultConfig:
    """Knobs for ``ServingEngine.run(..., faults=...)`` (pipeline mode only).

    ``mtbf`` arms an exponential fault process (mean seconds between
    faults, first draw at ``start``); ``schedule`` lists explicit
    ``(time, kind)`` pairs that fire deterministically (benchmarks use it
    for the one-crash-per-epoch grid).  Both may be combined: the
    schedule drains first, then the MTBF chain takes over.  With neither
    set the config is disabled and the run is bit-exact with
    ``faults=None``.

    ``kinds`` is the taxonomy the MTBF chain draws from (uniform over
    the tuple); ``detect_k`` the watchdog multiplier — a machine whose
    closed batch has not completed ``detect_k ×`` its modeled service
    duration after close is declared suspect, and dead one missed
    heartbeat later.  ``spare`` keeps the most-recently-drained machine
    of each stage idle-warm for one epoch instead of retiring it
    (failover promotes it without a cold add).  ``straggler_factor`` /
    ``straggler_duration`` shape the transient-slowdown fault.

    ``device_map`` / ``on_device_loss`` are not user knobs: the shared
    pool injects them per app (machine slot → physical device id, and
    the allocator repack callback) so a ``device_loss`` fault can take
    down every co-located slot at once.
    """

    mtbf: "float | None" = None
    schedule: "tuple[tuple[float, str], ...]" = ()
    kinds: "tuple[str, ...]" = ("crash",)
    seed: int = 0
    start: float = 0.0
    detect_k: float = 4.0
    spare: bool = True
    straggler_factor: float = 4.0
    straggler_duration: float = 0.5
    # shared-pool wiring (injected via dataclasses.replace, not by users)
    device_map: "Mapping[tuple[str, int], int] | None" = field(
        default=None, compare=False
    )
    on_device_loss: "Callable[[float, int], None] | None" = field(
        default=None, compare=False
    )

    def __post_init__(self):
        if self.mtbf is not None and self.mtbf <= 0.0:
            raise ValueError("mtbf must be positive")
        if self.detect_k <= 1.0:
            raise ValueError("detect_k must exceed 1 (a modeled service)")
        if self.straggler_factor <= 1.0:
            raise ValueError("straggler_factor must exceed 1")
        if self.straggler_duration <= 0.0:
            raise ValueError("straggler_duration must be positive")
        for k in self.kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}; have {FAULT_KINDS}")
        for t, k in self.schedule:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r} in schedule")
            if t < 0.0:
                raise ValueError("schedule times must be >= 0")

    @property
    def enabled(self) -> bool:
        """True when the injector will actually fire anything."""
        return self.mtbf is not None or bool(self.schedule)


class FaultRuntime:
    """Per-run injector + detector state, driven by the pipelined loop.

    The loop primes one fault event from :meth:`next_fault`, and each
    fired fault chains the next.  ``slow`` is the live straggler table —
    `service_time.DegradedServiceTime` holds it by reference, so entering
    and leaving it changes batch durations mid-run without touching the
    stages.  The detector state (``_suspect``) backs the suspect→dead
    escalation: :meth:`escalate` is called on a missed watchdog
    heartbeat, :meth:`clear` when a completion proves the machine alive.
    """

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._sched = deque(sorted(cfg.schedule))
        self.slow: dict[tuple[str, int], float] = {}
        self._suspect: set[tuple[str, int]] = set()
        # machines already declared dead: makes the declaration idempotent
        # under stale watchdog events (the core object outlives its verdict
        # until the next stage update retires it)
        self.dead: set[tuple[str, int]] = set()
        # counters surfaced on ServeResult.faults
        self.n_injected = 0
        self.n_killed = 0
        self.n_requeued = 0

    def next_fault(self, t: float) -> "tuple[float, str] | None":
        """The next fault instant/kind at or after ``t`` (None: no more)."""
        if self._sched:
            ft, kind = self._sched.popleft()
            return max(ft, t), kind
        if self.cfg.mtbf is not None:
            dt = float(self.rng.exponential(self.cfg.mtbf))
            return max(t, self.cfg.start) + dt, self._draw_kind()
        return None

    def _draw_kind(self) -> str:
        kinds = self.cfg.kinds
        if len(kinds) == 1:
            return kinds[0]
        return kinds[int(self.rng.integers(len(kinds)))]

    def pick(self, candidates: "list"):
        """Deterministic victim draw over a caller-sorted candidate list."""
        if not candidates:
            return None
        return candidates[int(self.rng.integers(len(candidates)))]

    # -- suspect -> dead escalation (batch-duration watchdog) ----------------
    def escalate(self, module: str, mid: int) -> str:
        """One missed heartbeat: returns ``"suspect"`` first, ``"dead"``
        on the next miss while still suspect."""
        key = (module, mid)
        if key in self._suspect:
            return "dead"
        self._suspect.add(key)
        return "suspect"

    def clear(self, module: str, mid: int) -> None:
        """A completed batch proves the machine alive — drop suspicion."""
        self._suspect.discard((module, mid))

    def forget(self, module: str, mid: int) -> None:
        """The machine is gone (dead or retired): drop all its state."""
        self._suspect.discard((module, mid))
        self.slow.pop((module, mid), None)


__all__ = ["FAULT_KINDS", "FaultConfig", "FaultRuntime"]
