"""Serving observability layer: tracing, metrics, and SLO-miss forensics.

Three pieces, all passive (results are bit-identical with observability on,
off, or sampled — the layer only *watches* the simulation):

* :mod:`.trace`     — ring-buffered structured trace recorder with
  deterministic sampling; exports Chrome-trace/Perfetto JSON so a serve run
  renders as a per-machine/per-module timeline.
* :mod:`.metrics`   — cheap per-module counters/gauges/histograms (batch
  occupancy, dummy fill, backpressure stalls, queue depth, utilization),
  flushed per control-plane epoch into ``ServeResult.metrics``.
* :mod:`.forensics` — classifies every missed/shed frame of a pipelined run
  into an exhaustive cause taxonomy with a conservation invariant; no
  opt-in needed (its columns are always on).

Enable via ``ServingEngine.run(..., observability=True)`` (or an
:class:`ObservabilityConfig`); dump with ``launch/serve.py --trace``.  The
:class:`Observability` runtime is the single object the serving loops talk
to: every hook guards on the piece being enabled, and the loops guard on
the runtime being present at all, so the disabled path stays hook-free.
"""
from __future__ import annotations

from dataclasses import dataclass

from .forensics import MISS_CAUSES, MissReport, classify_misses
from .metrics import MetricsRegistry, MetricsSnapshot
from .trace import TraceRecorder


@dataclass(frozen=True)
class ObservabilityConfig:
    """Engine-facing knobs for ``ServingEngine.run(..., observability=...)``.

    ``trace`` / ``metrics`` toggle the two recorders independently;
    ``sample`` thins the high-frequency trace events (batch spans, parking)
    by a deterministic stride (0.1 = every 10th), control-plane events are
    always recorded; ``capacity`` bounds the trace ring buffer.
    """

    trace: bool = True
    metrics: bool = True
    sample: float = 1.0
    capacity: int = 200_000

    def __post_init__(self):
        if not 0.0 < self.sample <= 1.0:
            raise ValueError("sample must be in (0, 1]")
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")


class Observability:
    """The live hook sink threaded through the serving loops."""

    __slots__ = ("cfg", "trace", "metrics")

    def __init__(self, cfg: ObservabilityConfig):
        self.cfg = cfg
        self.trace = (
            TraceRecorder(cfg.capacity, cfg.sample) if cfg.trace else None
        )
        self.metrics = MetricsRegistry() if cfg.metrics else None

    @staticmethod
    def make(spec) -> "Observability | None":
        """Resolve the engine's ``observability=`` argument (None / False /
        True / ObservabilityConfig / Observability)."""
        if spec is None or spec is False:
            return None
        if isinstance(spec, Observability):
            return spec
        if spec is True:
            spec = ObservabilityConfig()
        if not isinstance(spec, ObservabilityConfig):
            raise TypeError(
                f"observability= expects bool or ObservabilityConfig, got {spec!r}"
            )
        return Observability(spec)

    # -- hot-path hooks (loops guard on the runtime being non-None) ---------
    def batch_start(self, module: str, mid: int, start: float, dur: float,
                    size: int, cap: int, n_phantom: int) -> None:
        """A batch began service on ``module``/``mid`` at ``start``."""
        if self.metrics is not None:
            self.metrics.batch(module, size, cap, n_phantom, dur)
        tr = self.trace
        if tr is not None and tr.sampled():
            tr.span(
                start, dur, module, mid, f"batch b={size}/{cap}",
                phantoms=n_phantom,
            )

    def batch_close(self, t: float, module: str, mid: int, size: int,
                    cause: str, backlog: int) -> None:
        """A formation buffer closed (``cause``: full/deadline/eos/drain)."""
        if self.metrics is not None:
            self.metrics.close(module, cause, backlog)
        tr = self.trace
        if tr is not None and cause != "full":
            # partial flushes are the interesting (and rare) closes; full
            # closes are implied by the batch spans
            tr.instant(t, module, mid, f"flush:{cause}", size=size)

    def park(self, t: float, module: str) -> None:
        """A delivery parked under backpressure."""
        if self.metrics is not None:
            self.metrics.park(module)
        tr = self.trace
        if tr is not None and tr.sampled():
            tr.instant(t, module, 0, "park")

    def queue_depth(self, t: float, module: str, depth: int) -> None:
        tr = self.trace
        if tr is not None and tr.sampled():
            tr.counter(t, module, "queue_depth", depth)

    def shed(self, t: float, kind: str) -> None:
        """An admission decision denied a frame.

        ``kind``: ``"shed"`` (terminal), ``"shed_retry"`` (interim
        closed-loop denial the client re-issues), or ``"pipeline_drop"``
        (an in-flight instance drop lost the frame).  Summing ``"shed"``
        instants over a run equals terminal ``ServeResult.shed``.
        """
        if self.metrics is not None:
            self.metrics.close("(ingress)", kind, 0)
        if self.trace is not None:
            self.trace.instant(t, None, 0, kind)

    def drain(self, t: float, module: str, mid: int) -> None:
        """A machine was marked draining by a plan hot-swap."""
        if self.trace is not None:
            self.trace.instant(t, module, mid, "drain")

    # -- failure lifecycle hooks (always recorded, like control events) ------
    def suspect(self, t: float, module: str, mid: int) -> None:
        """The watchdog missed a heartbeat: machine flagged suspect."""
        if self.trace is not None:
            self.trace.instant(t, module, mid, "suspect")

    def fail(self, t: float, module: str, mid: int) -> None:
        """A machine was declared dead (second missed heartbeat)."""
        if self.metrics is not None:
            self.metrics.close(module, "machine_dead", 0)
        if self.trace is not None:
            self.trace.instant(t, module, mid, "fail")

    def requeue(self, t: float, module: str, mid: int, n: int) -> None:
        """``n`` unfinished members of a dead machine re-queued to siblings."""
        if self.trace is not None:
            self.trace.instant(t, module, mid, "requeue", members=n)

    def promote_spare(self, t: float, module: str, mid: int) -> None:
        """A warm spare was promoted back into dispatch by a stage update."""
        if self.trace is not None:
            self.trace.instant(t, module, mid, "promote_spare")

    # -- multi-tenant pool hooks (always recorded, like control events) -----
    def colocate(self, t: float, did: int, app: str, module: str, mid: int,
                 fraction: float) -> None:
        """The allocator packed a module residue onto shared device ``did``."""
        if self.trace is not None:
            self.trace.instant(
                t, "(pool)", did, "colocate",
                app=app, stage=module, machine=mid, frac=round(fraction, 4),
            )

    def evict(self, t: float, did: int, app: str, module: str,
              mid: int) -> None:
        """A repack removed a residue from its shared device ``did``."""
        if self.trace is not None:
            self.trace.instant(
                t, "(pool)", did, "evict", app=app, stage=module, machine=mid,
            )

    def device_occupancy(self, t: float, did: int, occupancy: float) -> None:
        """Per-device occupancy sample after a (re)pack."""
        if self.trace is not None:
            self.trace.counter(
                t, "(pool)", f"dev{did}_occupancy", round(occupancy, 4)
            )

    def phantom(self, t: float, module: str) -> None:
        """An adaptive phantom was injected into ``module``'s formation."""
        tr = self.trace
        if tr is not None and tr.sampled():
            tr.instant(t, module, 0, "phantom")

    def epoch(self, t: float, record, machines_of: "dict[str, int]") -> None:
        """A control-plane epoch boundary fired (after same-instant events)."""
        if self.metrics is not None:
            self.metrics.flush(t, machines_of, record.duration_err)
        if self.trace is not None:
            self.trace.instant(
                t, None, 0, "epoch",
                version=record.version,
                target=round(record.target, 3),
                swapped=record.swapped,
                delta=record.delta_summary,
            )

    # -- column-level hooks (segment fast path / flat engine) ---------------
    def bulk_module(self, module: str, *, batches: int, members: int,
                    phantoms: int, slots: int, busy: float) -> None:
        if self.metrics is not None:
            self.metrics.bulk(
                module, batches=batches, members=members, phantoms=phantoms,
                slots=slots, busy=busy,
            )

    def finalize(self, t_end: float,
                 machines_of: "dict[str, int]") -> "MetricsSnapshot | None":
        """Flush the trailing accumulation window; returns the snapshot."""
        if self.metrics is None:
            return None
        self.metrics.flush(t_end, machines_of)
        return self.metrics.snapshot()


__all__ = [
    "MISS_CAUSES",
    "MetricsRegistry",
    "MetricsSnapshot",
    "MissReport",
    "Observability",
    "ObservabilityConfig",
    "TraceRecorder",
    "classify_misses",
]
