"""Serving engine: executes a Harpagon Plan over a request stream.

Per module, the TC dispatcher hands whole batches to machines (weighted-fair
batch scheduling, `core.dispatch.dispatch_trace`); machines execute batches
with either (a) profiled durations (virtual time — used for the 1131-workload
evaluations) or (b) real jitted JAX model calls on CPU (wall-clock measured,
used by the end-to-end example).  Requests flow through the app DAG with
per-module *fanout* (a detector emits several crops per frame; a decoder
consumes every other frame): module m sees ``rates[m] / frame_rate``
instances per frame, exactly the rates the plan provisioned for.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Mapping

from ..core.dag import Workload
from ..core.dispatch import Policy, dispatch_trace, expand_machines
from ..core.harpagon import Plan


@dataclass
class ModuleStats:
    latencies: list[float] = field(default_factory=list)
    batches: int = 0

    @property
    def max_latency(self) -> float:
        return max(self.latencies) if self.latencies else 0.0


@dataclass
class ServeResult:
    e2e_latencies: list[float]
    module_stats: dict[str, ModuleStats]
    slo: float

    @property
    def attainment(self) -> float:
        if not self.e2e_latencies:
            return 1.0
        ok = sum(1 for l in self.e2e_latencies if l <= self.slo + 1e-9)
        return ok / len(self.e2e_latencies)

    @property
    def p99(self) -> float:
        s = sorted(self.e2e_latencies)
        return s[int(0.99 * (len(s) - 1))] if s else 0.0


class ServingEngine:
    def __init__(
        self,
        plan: Plan,
        *,
        executors: Mapping[str, Callable[[int], None]] | None = None,
        policy: Policy = Policy.TC,
    ):
        """``executors[module](batch_size)`` runs a real batched forward; when
        None the profiled config duration is used (virtual time)."""
        self.plan = plan
        self.executors = executors or {}
        self.policy = policy

    def run(self, n_frames: int, frame_rate: float) -> ServeResult:
        wl: Workload = self.plan.workload
        arrival = [i / frame_rate for i in range(n_frames)]
        # finish time of frame i at module m (0.0 = not processed / dropped)
        finish_at = {m: [0.0] * n_frames for m in wl.app.modules}
        stats = {m: ModuleStats() for m in wl.app.modules}
        for m in self._topo(wl):
            parents = wl.app.parents(m)
            ready = [
                max([arrival[i]] + [finish_at[p][i] for p in parents])
                for i in range(n_frames)
            ]
            drop = [
                any(finish_at[p][i] <= 0.0 for p in parents) for i in range(n_frames)
            ] if parents else [False] * n_frames
            fanout = wl.rates[m] / frame_rate
            self._run_module(m, ready, drop, fanout, finish_at[m], stats[m])
        sinks = [m for m in wl.app.modules if not wl.app.children(m)]
        e2e = [
            max(finish_at[s][i] for s in sinks) - arrival[i]
            for i in range(n_frames)
            if all(finish_at[s][i] > 0 for s in sinks)
        ]
        return ServeResult(e2e, stats, wl.slo)

    def _topo(self, wl: Workload) -> list[str]:
        seen: list[str] = []
        mods = list(wl.app.modules)
        while mods:
            for m in mods:
                if all(p in seen for p in wl.app.parents(m)):
                    seen.append(m)
                    mods.remove(m)
                    break
            else:
                raise RuntimeError("cycle in DAG")
        return seen

    def _run_module(self, m, ready, drop, fanout, finish, stats: ModuleStats):
        sched = self.plan.schedules[m]
        machines = expand_machines(list(sched.allocs))
        n_frames = len(ready)
        # expand frames into module-level request instances by fanout
        order = sorted(range(n_frames), key=lambda i: ready[i])
        instances: list[int] = []  # frame id per instance, in ready order
        acc = 0.0
        for i in order:
            if drop[i]:
                continue
            acc += fanout
            k = int(acc)
            acc -= k
            instances.extend([i] * k)
        n = len(instances)
        if n == 0:
            return
        trace = dispatch_trace(machines, n, self.policy)
        by_machine: dict[int, list[int]] = {mm.mid: [] for mm in machines}
        for slot, mid in trace:
            by_machine[mid].append(instances[slot])
        ex = self.executors.get(m)
        for mm in machines:
            fids = by_machine[mm.mid]
            b, d = mm.config.batch, mm.config.duration
            free = 0.0
            for i in range(0, len(fids), b):
                group = fids[i : i + b]
                t_ready = max(ready[f] for f in group)
                if len(group) < b:
                    # tail batch: flushed on deadline (early-exec semantics)
                    t_ready = max(t_ready, t_ready)
                start = max(t_ready, free)
                dur = d
                if ex is not None:
                    t0 = time.perf_counter()
                    ex(b)
                    dur = time.perf_counter() - t0
                end = start + dur
                free = end
                stats.batches += 1
                for f in group:
                    finish[f] = max(finish[f], end)
                    stats.latencies.append(end - ready[f])
