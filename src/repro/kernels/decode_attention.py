"""Pallas TPU flash-decode: one query token against a long KV cache.

The decode step is memory-bound — the entire KV cache streams HBM -> VMEM
once.  Grid = (B, Hkv, S / BK) with the cache dimension innermost so the
(g, Dv) accumulator for the g = Hq/Hkv grouped queries stays in VMEM.  The
per-sequence valid length arrives via scalar prefetch (SMEM) and masks the
tail block; an optional sliding window masks the head blocks.

Oracle: `repro.kernels.ref.decode_attention`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    lengths_ref,  # scalar prefetch (B,) int32 in SMEM
    q_ref,  # (1, 1, g, Dk)
    k_ref,  # (1, bk, 1, Dk)
    v_ref,  # (1, bk, 1, Dv)
    o_ref,  # (1, 1, g, Dv)
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    window: int | None,
    bk: int,
    nk: int,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    length = lengths_ref[b]

    @pl.when(ki == 0)
    def _init():
        acc_ref[:] = jnp.zeros_like(acc_ref)
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:] = jnp.zeros_like(l_ref)

    k_start = ki * bk
    live = k_start < length
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > length - 1 - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (g, Dk)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bk, Dk)
        v = v_ref[0, :, 0].astype(jnp.float32)  # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (g, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window is not None:
            mask &= kpos > length - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[:] = l_ref[:] * alpha + jnp.sum(p, axis=1)
        acc_ref[:] = acc_ref[:] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[:] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[:] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "block_k", "interpret")
)
def flash_decode(
    q: jax.Array,  # (B, Hq, Dk)
    k_cache: jax.Array,  # (B, S, Hkv, Dk)
    v_cache: jax.Array,  # (B, S, Hkv, Dv)
    lengths: jax.Array,  # (B,) int32
    *,
    window: int | None = None,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Dk = q.shape
    _, S, Hkv, _ = k_cache.shape
    Dv = v_cache.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else Dk ** -0.5
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk

    qr = q.reshape(B, Hkv, g, Dk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, Dk), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, Dk), lambda b, h, ki, lens: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, Dv), lambda b, h, ki, lens: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, Dv), lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, Dv), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, bk=bk, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, Dv), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qr, k_cache, v_cache)
    return out.reshape(B, Hq, Dv)
