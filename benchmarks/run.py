"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the reproduced
metric compared against the paper's claim).

  PYTHONPATH=src python -m benchmarks.run           # all benches
  PYTHONPATH=src python -m benchmarks.run --only fig5 --n 300
  PYTHONPATH=src python -m benchmarks.run --only replay,slo_sweep,shed_sweep --json
    # also writes machine-readable BENCH_serving.json (serving trajectory)
"""
from __future__ import annotations

import argparse
import json
import math
import statistics
import sys
import time

from . import common
from .common import PROFILES, emit, normalized_costs, plan_all, workload_suite

from repro.core import Planner  # noqa: E402
from repro.core import baselines as B  # noqa: E402
from repro.core.bruteforce import optimal_cost  # noqa: E402
from repro.core.dispatch import Policy, module_wcl  # noqa: E402
from repro.core.profiles import TABLE1_M3  # noqa: E402
from repro.core.scheduler import generate_config, generate_config_ktuple  # noqa: E402
from repro.core.residual import apply_dummy  # noqa: E402
from repro.serving import ServingEngine, simulate, simulate_reference  # noqa: E402
from repro.serving.frontend import FrontendConfig, QueueDepth, TokenBucket  # noqa: E402
from repro.workloads.apps import FANOUT  # noqa: E402


def finite_mean(xs):
    f = [x for x in xs if math.isfinite(x)]
    return sum(f) / len(f) if f else math.nan


# ----------------------------------------------------------- Table II
def bench_table2(n: int) -> None:
    """Scheduling methods S1-S4 for M3 @198 req/s, SLO 1 s (paper Table II)."""
    t0 = time.perf_counter()
    _, s1 = generate_config_ktuple(198.0, 1.0, TABLE1_M3, Policy.RR, 2)
    _, s2 = generate_config_ktuple(198.0, 1.0, TABLE1_M3, Policy.TC, 2)
    _, s3 = generate_config(198.0, 1.0, TABLE1_M3, Policy.TC)
    _, s4_allocs = generate_config(198.0, 1.0, TABLE1_M3, Policy.TC)
    dummy, s4 = apply_dummy(198.0, 1.0, TABLE1_M3, s4_allocs, Policy.TC)
    us = (time.perf_counter() - t0) * 1e6 / 4
    cost = lambda a: round(sum(x.cost for x in a), 4)
    derived = (
        f"S1={cost(s1)}|S2={cost(s2)}|S3={cost(s3)}|S4={cost(s4)}|dummy={dummy:g}"
        f"|paper=6.3/5.9/5.3/5.0"
    )
    emit("table2_scheduling", us, derived)


# ----------------------------------------------------------- Fig 5
def bench_fig5_cost(n: int) -> None:
    """Average normalized cost: 4 baselines + optimum (paper Fig. 5)."""
    wls = workload_suite(n)
    t0 = time.perf_counter()
    rows = plan_all(wls, (B.HARPAGON,) + B.BASELINES)
    us = (time.perf_counter() - t0) * 1e6 / max(1, len(wls) * 5)
    norm = normalized_costs(rows, ["harpagon", "nexus", "scrooge", "inferline", "clipper"])
    parts = []
    for k in ("nexus", "scrooge", "inferline", "clipper"):
        xs = norm[k]
        feas = [x for x in xs if math.isfinite(x)]
        parts.append(
            f"{k}={finite_mean(xs):.3f}(max={max(feas):.2f},infeas={len(xs)-len(feas)})"
        )
    derived = "|".join(parts) + "|paper_avg=1.49-2.37"
    emit("fig5_normalized_cost", us, derived)


def bench_fig5_optimal(n: int) -> None:
    """Harpagon vs brute-force optimum: hit rate + worst gap (Fig. 5b)."""
    wls = workload_suite(min(n, 250))
    h = Planner(B.HARPAGON)
    hits = tot = 0
    worst = 1.0
    t0 = time.perf_counter()
    for wl in wls:
        plan = h.plan(wl, PROFILES)
        if not plan.feasible:
            continue
        opt = min(optimal_cost(wl, PROFILES), plan.cost)
        tot += 1
        r = plan.cost / opt
        worst = max(worst, r)
        if r <= 1 + 1e-6:
            hits += 1
    us = (time.perf_counter() - t0) * 1e6 / max(1, tot)
    derived = (
        f"optimal_rate={100*hits/tot:.1f}%|worst=+{100*(worst-1):.1f}%"
        f"|paper=91.5%,+12.1%"
    )
    emit("fig5b_vs_bruteforce", us, derived)


# ----------------------------------------------------------- Fig 6 (ablations)
def bench_fig6_ablations(n: int) -> None:
    wls = workload_suite(n)
    rows = plan_all(wls, (B.HARPAGON,) + B.ABLATIONS)
    names = [o.name for o in B.ABLATIONS]
    norm = normalized_costs(rows, ["harpagon"] + names)
    paper = {
        "harp-2d": 1.796, "harp-dt": 1.441, "harp-1c": 1.665, "harp-2c": 1.030,
        "harp-nb": 1.896, "harp-nhc": 1.232, "harp-nhe": 1.140, "harp-nd": 1.008,
        "harp-0re": 1.010, "harp-1re": 1.006, "harp-tb": 1.353, "harp-q0.01": 1.012,
        "harp-q0.1": 1.306, "harp-nnm": 1.002, "harp-ncd": 1.003,
    }
    for k in names:
        avg = finite_mean(norm[k])
        emit(f"fig6_{k}", 0.0, f"norm_cost={avg:.3f}|paper={paper.get(k, float('nan')):.3f}")


# ----------------------------------------------------------- Fig 7 (dispatch L_wc)
def bench_fig7_dispatch(n: int) -> None:
    """Normalized L_wc of TC vs RR vs DT on fixed configurations (Fig. 7a)."""
    wls = workload_suite(min(n, 400))
    h = Planner(B.HARP_2D)  # configurations derived by Harp-2d, as in the paper
    ratios_rr, ratios_dt = [], []
    t0 = time.perf_counter()
    for wl in wls:
        plan = h.plan(wl, PROFILES)
        if not plan.feasible:
            continue
        for m, s in plan.schedules.items():
            allocs = list(s.allocs)
            tc = module_wcl(allocs, Policy.TC)
            if tc <= 0:
                continue
            ratios_rr.append(module_wcl(allocs, Policy.RR) / tc)
            ratios_dt.append(module_wcl(allocs, Policy.DT_OPT) / tc)
    us = (time.perf_counter() - t0) * 1e6 / max(1, len(ratios_rr))
    derived = (
        f"rr_extra=+{100*(statistics.mean(ratios_rr)-1):.1f}%"
        f"|dt_extra=+{100*(statistics.mean(ratios_dt)-1):.1f}%"
        f"|paper=+90.4%,+42.8%"
    )
    emit("fig7_dispatch_wcl", us, derived)


def bench_fig7_simulation(n: int) -> None:
    """Event-simulated L_wc vs Theorem 1 across planned workloads."""
    wls = workload_suite(60)
    h = Planner(B.HARPAGON)
    gaps = []
    t0 = time.perf_counter()
    checked = 0
    for wl in wls:
        plan = h.plan(wl, PROFILES)
        if not plan.feasible:
            continue
        for m, s in plan.schedules.items():
            allocs = [a for a in s.allocs]
            if any(a.dummy > 0 for a in allocs) or s.dummy:
                continue
            rate = sum(a.rate for a in allocs)
            if rate < 5:
                continue
            sim = simulate(allocs, rate, policy=Policy.TC, n_requests=600)
            if sim.n_requests == 0:
                continue
            theory = module_wcl(allocs, Policy.TC)
            gaps.append(sim.max_latency / theory)
            checked += 1
            if checked >= 40:
                break
        if checked >= 40:
            break
    us = (time.perf_counter() - t0) * 1e6 / max(1, checked)
    derived = f"sim/theory_mean={statistics.mean(gaps):.3f}|max={max(gaps):.3f}|bound~1.0"
    emit("fig7_sim_vs_theorem1", us, derived)


# ----------------------------------------------------------- Fig 8 (multi-config)
def bench_fig8_multiconfig(n: int) -> None:
    wls = workload_suite(n)
    rows = plan_all(wls, (B.HARPAGON, B.HARP_1C, B.HARP_2C))
    norm = normalized_costs(rows, ["harpagon", "harp-1c", "harp-2c"])
    multi = 0
    tot = 0
    for _, plans in rows:
        h = plans["harpagon"]
        if not h.feasible:
            continue
        tot += 1
        if any(len(s.allocs) > 2 for s in h.schedules.values()):
            multi += 1
    derived = (
        f"harp-1c={finite_mean(norm['harp-1c']):.3f}|harp-2c={finite_mean(norm['harp-2c']):.3f}"
        f"|>2cfg={100*multi/max(1,tot):.1f}%|paper=1.665,1.030,32.4%"
    )
    emit("fig8_multiconfig", 0.0, derived)


# ----------------------------------------------------- serving simulator
def bench_slo_sweep(n: int) -> None:
    """SLO attainment / p99 of replayed plans per planner preset under
    uniform vs Poisson vs bursty (MMPP) arrivals, over >= 100 suite
    workloads.  Batches wait to fill (``timeout=None``; tails flush at end
    of stream) so the sweep isolates the arrival-process effect — Harpagon
    runs machines at 100% utilization, where deadline flushing would add a
    second, throughput-collapse effect (see ROADMAP open items)."""
    wls = workload_suite(max(100, min(n, 200)))  # >=100 for coverage, <=200 for runtime
    presets = (B.HARPAGON, B.NEXUS, B.CLIPPER)
    kinds = ("uniform", "poisson", "bursty")
    acc = {(p.name, k): ([], []) for p in presets for k in kinds}
    planned = {p.name: 0 for p in presets}
    t0 = time.perf_counter()
    for wl in wls:
        frame_rate = wl.rates[wl.app.modules[0]] / FANOUT[wl.app.name][wl.app.modules[0]]
        for p in presets:
            plan = Planner(p).plan(wl, PROFILES)
            if not plan.feasible:
                continue
            planned[p.name] += 1
            eng = ServingEngine(plan, policy=p.policy)
            for k in kinds:
                res = eng.run(600, frame_rate, arrivals=k, seed=0)
                att, p99s = acc[(p.name, k)]
                att.append(res.attainment)
                p99s.append(res.p99 / wl.slo)
    us = (time.perf_counter() - t0) * 1e6 / max(1, len(wls))
    for p in presets:
        for k in kinds:
            att, p99s = acc[(p.name, k)]
            emit(
                f"slo_sweep_{p.name}_{k}",
                us,
                f"attain={finite_mean(att):.3f}|p99/slo={finite_mean(p99s):.3f}"
                f"|workloads={planned[p.name]}/{len(wls)}",
                preset=p.name,
                arrivals=k,
                attain=round(finite_mean(att), 4),
                p99_over_slo=round(finite_mean(p99s), 4),
                workloads=planned[p.name],
            )


def bench_shed_sweep(n: int) -> None:
    """Admission control under bursty overload: drive feasible Harpagon plans
    with MMPP arrivals at 1.0x / 1.3x the provisioned rate and compare the
    frontend policies.  Without admission the PR-1 queues (and p99) grow with
    the run length; token-bucket / queue-depth shedding bounds p99 at the
    price of an explicit, reported shed rate.

    A second leg re-runs the 1.3x overload point through the pipelined
    co-simulation with SLO-miss forensics attached
    (`ServeResult.miss_report`): every missed or shed frame classified
    into exactly one cause, so the policy comparison also reports *what
    kind* of miss each admission policy trades into (`shed_causes_*`
    rows, conservation-checked)."""
    wls = workload_suite(max(60, min(n, 120)))
    fes = (
        ("none", FrontendConfig(dummies=True)),
        ("token_bucket", FrontendConfig(dummies=True, admission=TokenBucket(burst=4))),
        ("queue_depth", FrontendConfig(dummies=True, admission=QueueDepth(depth=8))),
    )
    loads = (1.0, 1.3)
    acc = {(a, l): ([], [], []) for a, _ in fes for l in loads}  # att, p99, shed
    planned = 0
    forensic: list = []  # first few (plan, frame_rate) for the causes leg
    t0 = time.perf_counter()
    for wl in wls:
        frame_rate = wl.rates[wl.app.modules[0]] / FANOUT[wl.app.name][wl.app.modules[0]]
        plan = Planner(B.HARPAGON).plan(wl, PROFILES)
        if not plan.feasible:
            continue
        planned += 1
        if len(forensic) < 10:
            forensic.append((plan, frame_rate))
        eng = ServingEngine(plan)
        for name, fe in fes:
            for load in loads:
                res = eng.run(
                    600, frame_rate, arrivals="mmpp", seed=0,
                    timeout="budget", frontend=fe,
                    offered_rate=load * frame_rate,
                )
                att, p99s, sheds = acc[(name, load)]
                att.append(res.attainment)
                p99s.append(res.p99 / wl.slo)
                sheds.append(res.shed / max(1, res.offered))
        if planned >= 40:
            break
    us = (time.perf_counter() - t0) * 1e6 / max(1, planned)
    for name, _ in fes:
        for load in loads:
            att, p99s, sheds = acc[(name, load)]
            emit(
                f"shed_sweep_{name}_{load:g}x",
                us,
                f"attain={finite_mean(att):.3f}|p99/slo={finite_mean(p99s):.3f}"
                f"|shed={100*finite_mean(sheds):.1f}%|workloads={planned}",
                admission=name,
                load=load,
                attain=round(finite_mean(att), 4),
                p99_over_slo=round(finite_mean(p99s), 4),
                shed_rate=round(finite_mean(sheds), 4),
                workloads=planned,
            )

    # -- miss-cause forensics leg (1.3x overload, pipelined co-simulation)
    cause_acc: dict[str, dict[str, int]] = {name: {} for name, _ in fes}
    totals = {name: [0, 0] for name, _ in fes}  # [misses, offered]
    t0 = time.perf_counter()
    for plan, frame_rate in forensic:
        eng = ServingEngine(plan)
        for name, fe in fes:
            res = eng.run(
                600, frame_rate, arrivals="mmpp", seed=0,
                timeout="budget", frontend=fe,
                offered_rate=1.3 * frame_rate, pipeline=True,
            )
            rep = res.miss_report()
            if not rep.conserved:
                print(
                    f"# FAILURE: miss-cause conservation violated for "
                    f"{plan.workload.app.name}/{name}: {rep.counts} vs "
                    f"{rep.offered} offered, {rep.completed_in_slo} in SLO",
                    file=sys.stderr,
                )
                raise SystemExit(1)
            for k, v in rep.counts.items():
                cause_acc[name][k] = cause_acc[name].get(k, 0) + v
            totals[name][0] += rep.total
            totals[name][1] += rep.offered
    us = (time.perf_counter() - t0) * 1e6 / max(1, len(forensic))
    for name, _ in fes:
        counts = cause_acc[name]
        misses, offered = totals[name]
        dominant = (
            max(counts.items(), key=lambda kv: (kv[1], kv[0]))[0]
            if counts
            else "none"
        )
        emit(
            f"shed_causes_{name}",
            us,
            f"dominant={dominant}|misses={misses}/{offered}"
            f"|workloads={len(forensic)}|load=1.3x",
            admission=name,
            load=1.3,
            dominant=dominant,
            misses=misses,
            offered=offered,
            causes={k: counts[k] for k in sorted(counts)},
            workloads=len(forensic),
        )


def bench_pipeline_sweep(n: int) -> None:
    """Pipelined end-to-end p99 vs the analytic critical-path WCL sum, per
    latency splitter.  The multi-module co-simulation (engine
    ``pipeline=True``) is the first honest end-to-end check of the splitter
    budgets: every frame traverses the DAG through real batch formation, so
    p99/WCL-sum near 1.0 means the per-module budget assignment survives
    cross-stage hand-off; the mean sits below it by the batch-collection
    slack."""
    from repro.core.harpagon import PlannerOptions
    from repro.workloads.apps import app_by_name, make_workload

    seeds = (
        ("traffic", 100.0, 2.0), ("face", 150.0, 2.5), ("pose", 60.0, 3.0),
        ("caption", 90.0, 2.5), ("actdet", 80.0, 3.0),
    )
    n_frames = max(200, min(n, 600))
    for split in ("lc", "throughput", "even", "quantized"):
        ratios, means, attains, apps = [], [], [], 0
        t0 = time.perf_counter()
        for name, rate, slo in seeds:
            wl = make_workload(app_by_name(name), rate, slo)
            opts = PlannerOptions(name=f"split-{split}", split=split)
            plan = Planner(opts).plan(wl, PROFILES)
            if not plan.feasible:
                continue
            res = ServingEngine(plan).run(n_frames, rate, pipeline=True)
            wcl_sum = plan.e2e_latency
            ratios.append(res.p99 / wcl_sum)
            means.append(
                sum(res.e2e_latencies) / max(1, len(res.e2e_latencies)) / wcl_sum
            )
            attains.append(res.attainment)
            apps += 1
        us = (time.perf_counter() - t0) * 1e6 / max(1, apps)
        emit(
            f"pipeline_sweep_{split}",
            us,
            f"p99/wcl={finite_mean(ratios):.3f}|mean/wcl={finite_mean(means):.3f}"
            f"|attain={finite_mean(attains):.3f}|apps={apps}/5",
            split=split,
            p99_over_wcl=round(finite_mean(ratios), 4),
            mean_over_wcl=round(finite_mean(means), 4),
            attain=round(finite_mean(attains), 4),
            apps=apps,
        )


def bench_diurnal_sweep(n: int) -> None:
    """Incremental control plane vs static peak provisioning under diurnal
    arrivals (ISSUE-4 acceptance).

    For each 5-app suite seed, one full diurnal period (sinusoidal intensity
    ``1 + 0.8 sin``, peak = 1.8x mean) is served two ways, both planned
    against a derated internal SLO (``slo / 1.25`` — transient-absorbing
    slack, attainment measured at the real SLO) with dummy streaming on and
    ``timeout="budget"`` deadline flushing re-enabled behind the
    burst-aware deadline flag (``FrontendConfig(burst_deadline=True)``
    closes the PR-4 partial-flush collapse downstream of batched stages;
    without it this sweep had to run deadline-less):

    * **static**: one plan provisioned for the diurnal *peak* rate;
    * **replan**: initial plan at the mean rate + the epoch-based control
      loop (windowed trend-forecast rate estimation, ``Planner.replan``
      warm-start repair, live hot-swap) at each replan interval.

    Serving cost for the replanned arm is the time-integral of the active
    plan's cost over the run (`repro.serving.control.serving_cost`).
    Acceptance: at the finer interval, periodic replanning is >= 1.2x
    cheaper at (near-)equal attainment.  A second micro-row times
    ``Planner.replan`` along a two-period epoch walk against a cold
    ``plan()`` at every step: >= 5x faster at matched (<=1%) mean cost.
    """
    import dataclasses as _dc

    import numpy as np

    from repro.core.harpagon import Plan  # noqa: F401  (doc pointer)
    from repro.serving import ControlLoopConfig, FrontendConfig, serving_cost
    from repro.serving.arrivals import trace_arrivals
    from repro.workloads.apps import app_by_name, make_workload

    seeds = (
        ("traffic", 100.0, 2.0), ("face", 150.0, 2.5), ("pose", 60.0, 3.0),
        ("caption", 90.0, 2.5), ("actdet", 80.0, 3.0),
    )
    derate = 1.25
    peak = 1.8
    n_frames = 2400 if SMOKE else max(6000, min(n * 8, 9000))
    intervals = (12, 48)  # replan interval = period / divisor
    agg = {d: ([], [], []) for d in intervals}  # ratio, attain_rp, attain_st
    for name, rate, slo in seeds:
        period = n_frames / rate
        arr = trace_arrivals(n_frames, rate, seed=0, period=period)
        fe = FrontendConfig(dummies=True, burst_deadline=True)
        slo_plan = slo / derate
        wl = make_workload(app_by_name(name), rate, slo_plan)
        plan = Planner(B.HARPAGON).plan(wl, PROFILES)
        wl_pk = make_workload(app_by_name(name), rate * peak, slo_plan)
        plan_pk = Planner(B.HARPAGON).plan(wl_pk, PROFILES)
        if not plan.feasible or not plan_pk.feasible:
            emit(f"diurnal_sweep_{name}", 0.0, "infeasible", app=name, feasible=False)
            continue
        res_pk = ServingEngine(plan_pk).run(
            n_frames, rate * peak, arrivals=arr, frontend=fe, pipeline=True,
            timeout="budget",
        )
        att = lambda r: float(
            (np.asarray(r.e2e_latencies) <= slo + 1e-9).sum() / max(1, r.offered)
        )
        att_st = att(res_pk)
        for div in intervals:
            t0 = time.perf_counter()
            ctrl = ControlLoopConfig(
                interval=period / div, profiles=PROFILES, margin=0.25
            )
            res = ServingEngine(plan).run(
                n_frames, rate, arrivals=arr, frontend=fe, pipeline=True,
                control=ctrl, timeout="budget",
            )
            cost_rp = serving_cost(res.epochs, float(arr[-1]))
            ratio = plan_pk.cost / cost_rp
            a_rp = att(res)
            swaps = sum(1 for e in res.epochs if e.swapped)
            agg[div][0].append(ratio)
            agg[div][1].append(a_rp)
            agg[div][2].append(att_st)
            us = (time.perf_counter() - t0) * 1e6
            emit(
                f"diurnal_sweep_{name}_P{div}",
                us,
                f"cost_ratio={ratio:.2f}|replan_attain={a_rp:.3f}"
                f"|static_attain={att_st:.3f}|replan_cost={cost_rp:.2f}"
                f"|static_cost={plan_pk.cost:.2f}|swaps={swaps}",
                app=name,
                interval_div=div,
                cost_ratio=round(ratio, 4),
                replan_attain=round(a_rp, 4),
                static_attain=round(att_st, 4),
                replan_cost=round(cost_rp, 4),
                static_cost=round(plan_pk.cost, 4),
                swaps=swaps,
            )
    for div in intervals:
        ratios, a_rp, a_st = agg[div]
        emit(
            f"diurnal_sweep_agg_P{div}",
            0.0,
            f"cost_ratio={finite_mean(ratios):.2f}|replan_attain={finite_mean(a_rp):.3f}"
            f"|static_attain={finite_mean(a_st):.3f}|target>=1.2x",
            interval_div=div,
            cost_ratio=round(finite_mean(ratios), 4),
            replan_attain=round(finite_mean(a_rp), 4),
            static_attain=round(finite_mean(a_st), 4),
        )

    # --- Planner.replan vs cold plan(): a two-period diurnal epoch walk ---
    t_warm = t_cold = 0.0
    cost_ratios = []
    epochs = 24 if SMOKE else 48
    for name, rate, slo in seeds:
        pl = Planner(B.HARPAGON)
        wl = make_workload(app_by_name(name), rate, slo)
        cur = pl.plan(wl, PROFILES)
        if not cur.feasible:
            continue
        for k in range(1, 2 * epochs + 1):
            f = 1.0 + 0.35 * math.sin(2.0 * math.pi * k / epochs)
            nr = {m: r * f for m, r in wl.rates.items()}
            t0 = time.perf_counter()
            warm = pl.replan(cur, nr, PROFILES)
            t_warm += time.perf_counter() - t0
            t0 = time.perf_counter()
            cold = Planner(B.HARPAGON).plan(
                _dc.replace(wl, rates=nr), PROFILES
            )
            t_cold += time.perf_counter() - t0
            if warm.feasible and cold.feasible:
                cost_ratios.append(warm.cost / cold.cost)
            cur = warm
    speedup = t_cold / max(t_warm, 1e-12)
    if not cost_ratios:
        emit("diurnal_replan_speed", 0.0, "infeasible: no warm/cold step pair")
        return
    emit(
        "diurnal_replan_speed",
        t_warm * 1e6 / max(1, len(cost_ratios)),
        f"speedup={speedup:.1f}x|warm/cold_cost={finite_mean(cost_ratios):.4f}"
        f"|worst={max(cost_ratios):.4f}|steps={len(cost_ratios)}"
        f"|target>=5x,cost<=1.01",
        speedup=round(speedup, 2),
        cost_ratio_mean=round(finite_mean(cost_ratios), 4),
        cost_ratio_worst=round(max(cost_ratios), 4),
        steps=len(cost_ratios),
    )


def bench_pipeline_speed(n: int) -> None:
    """Macro-event pipeline core vs the event-by-event reference loop
    (ISSUE-5 acceptance): a multi-module app at >= 10^5 frames must replay
    >= 5x faster on the default path (segment fast-path to the vectorized
    flat kernel) with BIT-identical per-frame results.  Under ``--smoke``
    the stream shrinks to 2*10^4 frames and a speedup below 3x, a fast-path
    frame rate below 10^5 frames/s, or any result disagreement FAILS the
    run — the pipeline hot-path regression gate.

    A second matrix leg replays the dummy-streaming ``burst_deadline``
    configuration (budget deadlines + phantom fill — the PR-5 partial-flush
    collapse surface) reference-vs-default and gates on agreement alone:
    that path stays on the event loop, so there is no speed target, but a
    divergence between the two drivers is exactly the regression the plain
    leg cannot see.

    A third leg re-times the fast path with sampled observability attached
    (``ObservabilityConfig(sample=0.1)``): tracing must stay bit-exact and
    — under ``--smoke``, a hard gate — inside a 10% overhead envelope,
    because the telemetry hooks are column-level on the fast path and
    guarded single branches on the event loop.  Smoke mode also exports a
    Perfetto trace from a small diurnal control-plane run
    (``trace_smoke.json``, the CI artifact) and fails if the export is not
    loadable non-empty JSON."""
    import numpy as np

    from repro.serving import ControlLoopConfig, ObservabilityConfig
    from repro.serving.arrivals import trace_arrivals
    from repro.serving.pipeline import PipelineConfig
    from repro.workloads.apps import app_by_name, make_workload

    rate, slo = 150.0, 2.5
    wl = make_workload(app_by_name("face"), rate, slo)
    plan = Planner(B.HARPAGON).plan(wl, PROFILES)
    assert plan.feasible
    eng = ServingEngine(plan)
    n_frames = 20_000 if SMOKE else 100_000
    ref, us_ref = common.timed(
        lambda: eng.run(
            n_frames, rate, arrivals="poisson",
            pipeline=PipelineConfig(reference=True),
        ),
        repeat=1 if SMOKE else 2,
    )
    fast, us_fast = common.timed(
        lambda: eng.run(n_frames, rate, arrivals="poisson", pipeline=True),
        repeat=3,
    )
    t_ref, t_fast = us_ref / 1e6, us_fast / 1e6
    agree = bool(
        np.array_equal(ref.pipeline.e2e, fast.pipeline.e2e, equal_nan=True)
        and all(
            np.array_equal(
                ref.pipeline.finish[m], fast.pipeline.finish[m], equal_nan=True
            )
            for m in ref.pipeline.modules
        )
    )
    speedup = t_ref / t_fast
    # the reference loop's event throughput: how much per-event Python the
    # fast path is buying down (>= 2 instances + free/flush per frame)
    ref_fps = n_frames / t_ref
    fast_fps = n_frames / t_fast
    emit(
        "pipeline_speed",
        t_fast * 1e6,
        f"reference={t_ref:.2f}s|fast={t_fast:.3f}s|speedup={speedup:.1f}x"
        f"|frames/s={fast_fps:,.0f}|n={n_frames:g}|agree={agree}"
        f"|target>={'3x(smoke)' if SMOKE else '5x'}",
        reference_s=round(t_ref, 4),
        fast_s=round(t_fast, 4),
        speedup=round(speedup, 2),
        n_frames=n_frames,
        ref_frames_per_s=round(ref_fps, 1),
        fast_frames_per_s=round(fast_fps, 1),
        agree=agree,
    )
    if SMOKE and (not agree or speedup < 3.0 or fast_fps < 100_000):
        print(
            f"# SMOKE FAILURE: pipeline speedup {speedup:.1f}x < 3x, "
            f"fast path {fast_fps:,.0f} frames/s < 100,000, or result "
            f"disagreement (agree={agree})",
            file=sys.stderr,
        )
        raise SystemExit(1)

    # burst-deadline matrix leg: same reference-vs-default agreement gate
    # on the dummy-streaming budget-deadline path (event loop both ways)
    fe = FrontendConfig(dummies=True, burst_deadline=True)
    n_burst = 6_000 if SMOKE else 20_000
    ref_b, us_ref_b = common.timed(
        lambda: eng.run(
            n_burst, rate, arrivals="poisson", frontend=fe, timeout="budget",
            pipeline=PipelineConfig(reference=True),
        ),
        repeat=1,
    )
    fast_b, us_fast_b = common.timed(
        lambda: eng.run(
            n_burst, rate, arrivals="poisson", frontend=fe, timeout="budget",
            pipeline=True,
        ),
        repeat=1,
    )
    agree_b = bool(
        np.array_equal(ref_b.pipeline.e2e, fast_b.pipeline.e2e, equal_nan=True)
        and all(
            np.array_equal(
                ref_b.pipeline.finish[m], fast_b.pipeline.finish[m],
                equal_nan=True,
            )
            for m in ref_b.pipeline.modules
        )
    )
    emit(
        "pipeline_speed_burst",
        us_fast_b,
        f"reference={us_ref_b / 1e6:.2f}s|default={us_fast_b / 1e6:.2f}s"
        f"|n={n_burst:g}|agree={agree_b}|gate=agreement",
        n_frames=n_burst,
        agree=agree_b,
    )
    if SMOKE and not agree_b:
        print(
            "# SMOKE FAILURE: burst_deadline pipeline leg disagrees "
            "reference vs default",
            file=sys.stderr,
        )
        raise SystemExit(1)

    # observability overhead leg: sampled tracing on the same plain-path
    # run — must stay bit-exact and (smoke gate) within 10% of untraced
    traced, us_obs = common.timed(
        lambda: eng.run(
            n_frames, rate, arrivals="poisson", pipeline=True,
            observability=ObservabilityConfig(sample=0.1),
        ),
        repeat=3,
    )
    agree_t = bool(
        np.array_equal(fast.pipeline.e2e, traced.pipeline.e2e, equal_nan=True)
        and all(
            np.array_equal(
                fast.pipeline.finish[m], traced.pipeline.finish[m],
                equal_nan=True,
            )
            for m in fast.pipeline.modules
        )
    )
    overhead = us_obs / us_fast
    emit(
        "pipeline_speed_traced",
        us_obs,
        f"traced={us_obs / 1e6:.3f}s|overhead={overhead:.3f}x"
        f"|agree={agree_t}|sample=0.1|gate<=1.10x(smoke)",
        traced_s=round(us_obs / 1e6, 4),
        overhead=round(overhead, 3),
        agree=agree_t,
        n_frames=n_frames,
    )
    if SMOKE and (not agree_t or overhead > 1.10):
        print(
            f"# SMOKE FAILURE: sampled tracing overhead {overhead:.3f}x "
            f"> 1.10x or result disagreement (agree={agree_t})",
            file=sys.stderr,
        )
        raise SystemExit(1)

    if SMOKE:
        # Perfetto artifact for CI: a small diurnal control-plane run with
        # full tracing, exported as trace_smoke.json — the gate is only
        # that the export loads as non-empty trace-event JSON
        n_t = 3_000
        period = n_t / rate
        arr = trace_arrivals(n_t, rate, seed=0, period=period)
        res_t = eng.run(
            n_t, rate, arrivals=arr, timeout="budget",
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
            pipeline=True,
            control=ControlLoopConfig(
                interval=period / 6, profiles=PROFILES, margin=0.25
            ),
            observability=True,
        )
        path = res_t.trace.export("trace_smoke.json")
        with open(path) as f:
            doc = json.load(f)
        if not doc.get("traceEvents"):
            print(
                "# SMOKE FAILURE: trace_smoke.json has no traceEvents",
                file=sys.stderr,
            )
            raise SystemExit(1)
        print(
            f"# wrote {len(doc['traceEvents'])} Perfetto trace events to "
            f"{path} ({len(res_t.epochs)} control epochs)",
            file=sys.stderr,
        )


def bench_wallclock_gap(n: int) -> None:
    """Analytic-vs-measured service-time gap per arch at b in {1, 8, 32} —
    the simulator-to-serving calibration row (ISSUE-6).

    Full mode times real jitted reduced-model forwards on CPU through
    `LiveServiceTime` (warmup retires the compile transient) and reports
    the measured/analytic duration ratio's mean and p99 per batch size;
    the analytic side is the same roofline profile the planner consumes,
    so the row tracks exactly the divergence *Beyond Inference*-style host
    overheads introduce.  Under ``--smoke`` the measurements are replayed
    from a seeded recorded trace through `TraceServiceTime` (deterministic,
    no jax compile) — CI exercises the trace backend and the gap
    accounting at zero compile cost."""
    import numpy as np

    from repro.core.dispatch import Config as _Cfg
    from repro.core.dispatch import Machine as _Machine
    from repro.profiling import arch_profile
    from repro.serving import LiveServiceTime, TraceServiceTime

    from repro.configs import get_config

    archs = ("smollm-360m", "gemma3-1b")
    batches = (1, 8, 32)
    seq = 32
    repeats = 5 if SMOKE else max(5, min(n // 10, 20))
    for arch in archs:
        cfg = get_config(arch, smoke=True)
        prof = arch_profile(cfg, seq=seq, batches=batches)
        analytic = {
            b: min(c.duration for c in prof.configs if c.batch == b)
            for b in batches
        }
        if SMOKE:
            # recorded-trace stand-in: a fixed calibration offset plus
            # seeded lognormal scatter, drawn through the trace backend
            rng = np.random.default_rng(0)
            samples = {
                (arch, b): [
                    analytic[b] * 1.2 * float(np.exp(0.05 * rng.standard_normal()))
                    for _ in range(repeats + 1)
                ]
                for b in batches
            }
            src = TraceServiceTime(samples)
            backend = "trace"
        else:
            import jax
            import jax.numpy as jnp

            from repro.models import Model

            model = Model(cfg)
            params = model.init(jax.random.key(0))
            fwd = jax.jit(lambda p, t, m=model: m.forward(p, t).logits)

            def ex(b, fwd=fwd, params=params):
                fwd(params, jnp.zeros((b, seq), jnp.int32)).block_until_ready()

            src = LiveServiceTime({arch: ex}, warmup=1, cache=False)
            backend = "live"
        parts = []
        data = {"backend": backend, "seq": seq}
        for b in batches:
            mach = _Machine(
                mid=0,
                config=_Cfg(batch=b, duration=analytic[b], hardware="tpu-v5e"),
                rate=1.0,
            )
            draws = np.array(
                [src.duration(arch, mach, b) for _ in range(repeats + 1)]
            )[1:]  # first draw = warmup (live) / align the trace cursor
            gaps = draws / analytic[b]
            g_mean, g_p99 = float(gaps.mean()), float(np.percentile(gaps, 99))
            parts.append(f"b{b}={g_mean:.2f}x/p99={g_p99:.2f}x")
            data[f"gap_mean_b{b}"] = round(g_mean, 4)
            data[f"gap_p99_b{b}"] = round(g_p99, 4)
        emit(
            f"wallclock_gap_{arch}",
            0.0,
            "|".join(parts) + f"|backend={backend}",
            **data,
        )


def _plan_fingerprint(plan) -> tuple:
    """Bit-level plan identity: feasibility, cost, and every schedule."""
    return (
        plan.feasible,
        plan.cost,
        tuple(sorted((m, repr(s)) for m, s in plan.schedules.items())),
    )


def bench_planner_speed(n: int) -> None:
    """Planner.plan wall-clock over the workload suite — the paper's
    "millisecond-level planning runtime" claim, tracked as a trajectory row.

    Times both the batched numpy cascade (`vectorized=True`, the default)
    and the scalar `wcl_memo` oracle it replaced, and checks the two
    produce bit-equal plans on every workload.  Under ``--smoke`` (CI)
    this is a hard gate: vectorized ms/plan above the 5 ms paper budget,
    or any plan disagreement, FAILS the run (exit 1)."""
    import dataclasses

    wls = workload_suite(max(60, min(n, 60 if SMOKE else 200)))
    vec = Planner(B.HARPAGON)
    sca = Planner(dataclasses.replace(B.HARPAGON, vectorized=False))
    t0 = time.perf_counter()
    plans = [vec.plan(wl, PROFILES) for wl in wls]
    t = time.perf_counter() - t0
    t1 = time.perf_counter()
    plans_s = [sca.plan(wl, PROFILES) for wl in wls]
    t_s = time.perf_counter() - t1
    agree = all(
        _plan_fingerprint(a) == _plan_fingerprint(b)
        for a, b in zip(plans, plans_s)
    )
    feas = sum(1 for p in plans if p.feasible)
    ms = 1e3 * t / len(wls)
    ms_s = 1e3 * t_s / len(wls)
    emit(
        "planner_speed",
        t * 1e6 / len(wls),
        f"plan={ms:.2f}ms|scalar={ms_s:.2f}ms|speedup={ms_s / ms:.1f}x"
        f"|agree={agree}|feasible={feas}/{len(wls)}|paper=5ms",
        ms_per_plan=round(ms, 3),
        scalar_ms_per_plan=round(ms_s, 3),
        speedup=round(ms_s / ms, 2),
        agree=bool(agree),
        workloads=len(wls),
        feasible=feas,
    )
    if SMOKE and (not agree or ms > 5.0):
        print(
            f"# SMOKE FAILURE: planner {ms:.2f}ms/plan > 5ms budget or "
            f"vectorized/scalar plan disagreement (agree={agree})",
            file=sys.stderr,
        )
        raise SystemExit(1)


def bench_dp_splitter(n: int) -> None:
    """Exact quantized-budget DP splitter (``split="dp"``) vs the four
    heuristic splitters and the brute-force optimum (ROADMAP's fifth
    splitter).

    On the feasible sub-suite (workloads where both the DP grid and the
    heuristics admit a plan) reports each splitter's mean cost normalized
    to the brute-force optimum and the DP's optimality rate (fraction of
    workloads where its plan cost matches the optimum to 1e-6 — the
    paper's Fig. 5b framing puts Harpagon's own cascade at 91.5%).  Under
    ``--smoke`` a DP optimality rate below 91.5% FAILS the run: the DP
    shares the brute-force curves, so falling under the cascade's own
    rate means the budget-recovery walk regressed.

    Also times the module cost-curve pass cold vs warm: curves are
    cached across workloads by quantized (rate, slo) bucket
    (`bruteforce.curve_cache_clear`), so a replayed suite re-prices
    nothing — the ``curve_speedup`` column tracks that win."""
    import dataclasses

    from repro.core.bruteforce import curve_cache_clear, curve_cache_stats

    wls = workload_suite(min(n, 30 if SMOKE else 120))
    splits = ("dp", "lc", "throughput", "even", "quantized")
    planners = {
        s: Planner(dataclasses.replace(B.HARPAGON, split=s)) for s in splits
    }
    # cold vs warm curve pass over the same suite (the cache's whole point:
    # the second pass shares every curve the first one priced)
    curve_cache_clear()
    t0 = time.perf_counter()
    for wl in wls:
        optimal_cost(wl, PROFILES)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for wl in wls:
        optimal_cost(wl, PROFILES)
    t_warm = time.perf_counter() - t0
    curve_speedup = t_cold / max(t_warm, 1e-12)
    cache = curve_cache_stats()

    sums = {s: 0.0 for s in splits}
    hits = tot = 0
    t0 = time.perf_counter()
    for wl in wls:
        opt_grid = optimal_cost(wl, PROFILES)
        if not math.isfinite(opt_grid):
            continue
        plans = {s: planners[s].plan(wl, PROFILES) for s in splits}
        if not all(p.feasible for p in plans.values()):
            continue
        # normalize against the best point any method found (continuous
        # splits can dip a hair below the budget grid); the DP's hit is
        # judged against the grid optimum it shares with brute force
        best = min([opt_grid] + [p.cost for p in plans.values()])
        tot += 1
        for s in splits:
            sums[s] += plans[s].cost / best
        if plans["dp"].cost <= opt_grid * (1 + 1e-6):
            hits += 1
    us = (time.perf_counter() - t0) * 1e6 / max(1, tot)
    rate = 100.0 * hits / max(1, tot)
    norm = {s: sums[s] / max(1, tot) for s in splits}
    emit(
        "dp_splitter_optimality",
        us,
        f"dp={norm['dp']:.4f}|lc={norm['lc']:.4f}|thr={norm['throughput']:.4f}"
        f"|even={norm['even']:.4f}|quant={norm['quantized']:.4f}"
        f"|optimal_rate={rate:.1f}%|feasible={tot}/{len(wls)}"
        f"|curve_cold={t_cold*1e3:.0f}ms|curve_warm={t_warm*1e3:.1f}ms"
        f"|curve_speedup={curve_speedup:.0f}x"
        f"|gate>=91.5%",
        optimal_rate=round(rate, 2),
        feasible=tot,
        workloads=len(wls),
        curve_cold_ms=round(t_cold * 1e3, 2),
        curve_warm_ms=round(t_warm * 1e3, 3),
        curve_speedup=round(curve_speedup, 1),
        curve_hits=cache["hits"],
        curve_misses=cache["misses"],
        **{f"norm_{s}": round(norm[s], 5) for s in splits},
    )
    if SMOKE and rate < 91.5:
        print(
            f"# SMOKE FAILURE: dp splitter optimality {rate:.1f}% < 91.5%",
            file=sys.stderr,
        )
        raise SystemExit(1)


def bench_replay_speed(n: int) -> None:
    """Vectorized replay kernel vs the frozen pure-Python loop at 10^6
    requests on one planned module (acceptance: >= 5x).  Under ``--smoke``
    (CI) the stream shrinks to 2*10^5 requests and a speedup below the
    smoke floor (3x, conservative for noisy shared runners) FAILS the run —
    the hot-path regression gate."""
    profile = PROFILES["ssd_detect"]
    ok, allocs = generate_config(500.0, 2.0, profile, Policy.TC)
    assert ok
    rate = sum(a.rate for a in allocs)
    n_req = 200_000 if SMOKE else 1_000_000
    # best-of-repeats so a transiently loaded machine can't skew the ratio
    ref, us_ref = common.timed(
        lambda: simulate_reference(allocs, rate, n_requests=n_req), repeat=2
    )
    new, us_vec = common.timed(
        lambda: simulate(allocs, rate, n_requests=n_req), repeat=3
    )
    t_ref, t_vec = us_ref / 1e6, us_vec / 1e6
    agree = abs(ref.max_latency - new.max_latency) < 1e-9 and ref.n_requests == new.n_requests
    speedup = t_ref / t_vec
    emit(
        "replay_vectorized_speedup",
        t_vec * 1e6,
        f"python={t_ref:.2f}s|vectorized={t_vec:.3f}s|speedup={speedup:.1f}x"
        f"|n={n_req:g}|agree={agree}|target>={'3x(smoke)' if SMOKE else '5x'}",
        python_s=round(t_ref, 4),
        vectorized_s=round(t_vec, 4),
        speedup=round(speedup, 2),
        n_requests=n_req,
        agree=bool(agree),
    )
    if SMOKE and (not agree or speedup < 3.0):
        print(
            f"# SMOKE FAILURE: replay speedup {speedup:.1f}x < 3x or "
            f"kernel disagreement (agree={agree})",
            file=sys.stderr,
        )
        raise SystemExit(1)


# ------------------------------------------------- multi-tenant shared pool
def bench_multitenant_sweep(n: int) -> None:
    """Consolidated shared pool vs per-app dedicated deployments (ISSUE-8).

    The five paper apps at 1/8 rate — the low-rate regime where every
    plan strands a large fractional machine residue per module — are
    served two ways: per-app dedicated (every fractional allocation
    rounded up to whole devices: the integer bill a real deployment
    pays) and one shared pool (`SharedPool`: FFD co-location of residues
    under the calibrated interference model, co-located batches honestly
    slowed, e2e-SLO feasibility guard on every pairing).

    Acceptance (hard smoke gates): per-app frame accounting conserves;
    aggregate attainment >= 0.97 with interference ON; consolidated pool
    cost >= 1.15x cheaper than the dedicated bill.
    """
    import numpy as np

    from repro.serving import SharedPool
    from repro.serving.tenancy import dedicated_cost
    from repro.workloads.apps import app_by_name, make_workload

    seeds = (
        ("traffic", 100.0, 2.0), ("face", 150.0, 2.5), ("pose", 60.0, 3.0),
        ("caption", 90.0, 2.5), ("actdet", 80.0, 3.0),
    )
    scale = 0.125
    n_frames = 400 if SMOKE else max(800, min(n * 2, 2400))
    plans = {}
    for name, rate, slo in seeds:
        wl = make_workload(app_by_name(name), rate * scale, slo)
        plan = Planner(B.HARPAGON).plan(wl, PROFILES)
        if not plan.feasible:
            emit(
                f"multitenant_{name}", 0.0, "infeasible",
                app=name, feasible=False,
            )
            return
        plans[name] = plan
    pool = SharedPool(plans)
    t0 = time.perf_counter()
    res = pool.run(n_frames)
    dt = time.perf_counter() - t0
    conserved = all(res.conservation().values())
    for name, _, slo in seeds:
        r = res.results[name]
        att = float(
            (np.asarray(r.e2e_latencies) <= slo + 1e-9).sum()
            / max(1, r.offered)
        )
        emit(
            f"multitenant_{name}", 0.0,
            f"attain={att:.4f}|p99={r.p99:.3f}|offered={r.offered}",
            app=name, attainment=round(att, 4), p99=round(r.p99, 4),
            offered=r.offered, shed=r.shed, dropped=r.dropped,
        )
    emit(
        "multitenant_sweep",
        dt * 1e6,
        f"savings={res.savings:.3f}x|attain={res.attainment:.4f}"
        f"|pool={res.pool_cost:.4g}|dedicated={res.dedicated_cost:.4g}"
        f"|shared={res.device_plan.n_shared}/{len(res.device_plan.devices)}"
        f"|conserved={conserved}|target>=1.15x@0.97",
        savings=round(res.savings, 4),
        attainment=round(res.attainment, 4),
        pool_cost=round(res.pool_cost, 4),
        dedicated_cost=round(res.dedicated_cost, 4),
        n_devices=len(res.device_plan.devices),
        n_shared=res.device_plan.n_shared,
        conserved=bool(conserved),
    )
    if SMOKE and not conserved:
        print(
            "# SMOKE FAILURE: shared-pool frame accounting does not "
            f"conserve ({res.conservation()})",
            file=sys.stderr,
        )
        raise SystemExit(1)
    if SMOKE and (res.attainment < 0.97 or res.savings < 1.15):
        print(
            f"# SMOKE FAILURE: multitenant savings {res.savings:.3f}x < 1.15x "
            f"or attainment {res.attainment:.4f} < 0.97 (interference on)",
            file=sys.stderr,
        )
        raise SystemExit(1)


# ------------------------------------------------- failure resilience
def bench_chaos_sweep(n: int) -> None:
    """Failure-resilient serving under seeded fault injection (ISSUE-10).

    The 5-app diurnal preset is served with the full control stack
    (dummy streaming, burst-aware budget deadlines, epoch replans at
    ``margin=0.35``) three ways per app:

    * **baseline**: no fault injector — the no-fault attainment/cost;
    * **fault-off**: a *disabled* ``FaultConfig()`` — must be bit-exact
      with the baseline (the injector's plumbing is free when off);
    * **crash-per-epoch**: one seeded machine crash at every epoch
      midpoint (``detect_k=2`` watchdog), exercising silent-crash
      detection, frame-conserving re-queue, out-of-band failure replans,
      and warm-spare promotion end to end.

    Hard smoke gates: fault-off bit-exactness on every app; exact frame
    conservation (``completed + shed + dropped == offered``) and a
    conserved forensics cascade under the crash schedule; aggregate
    post-recovery attainment >= 0.9 at <= 1.3x the no-fault serving
    cost.  A second block sweeps the MTBF x detection-timeout grid on
    one app (informational rows: attainment / kills / re-queues per
    cell — how detection latency trades against false urgency).
    """
    import numpy as np

    from repro.serving import (
        ControlLoopConfig, FaultConfig, classify_misses, serving_cost,
    )
    from repro.serving.arrivals import trace_arrivals
    from repro.workloads.apps import app_by_name, make_workload

    seeds = (
        ("traffic", 100.0, 2.0), ("face", 150.0, 2.5), ("pose", 60.0, 3.0),
        ("caption", 90.0, 2.5), ("actdet", 80.0, 3.0),
    )
    derate = 1.25
    div = 12  # epochs per diurnal period
    detect_k = 2.0
    n_frames = 2400 if SMOKE else max(2400, min(n * 4, 4800))
    atts, ratios = [], []
    exact_all = conserved_all = True
    t0 = time.perf_counter()
    for name, rate, slo in seeds:
        period = n_frames / rate
        arr = trace_arrivals(n_frames, rate, seed=0, period=period)
        fe = FrontendConfig(dummies=True, burst_deadline=True)
        wl = make_workload(app_by_name(name), rate, slo / derate)
        plan = Planner(B.HARPAGON).plan(wl, PROFILES)
        if not plan.feasible:
            emit(f"chaos_{name}", 0.0, "infeasible", app=name, feasible=False)
            continue
        interval = period / div
        horizon = float(arr[-1])
        ctrl = lambda: ControlLoopConfig(  # noqa: E731
            interval=interval, profiles=PROFILES, margin=0.35
        )
        kw = dict(
            arrivals=arr, frontend=fe, pipeline=True, timeout="budget",
        )
        base = ServingEngine(plan).run(n_frames, rate, control=ctrl(), **kw)
        off = ServingEngine(plan).run(
            n_frames, rate, control=ctrl(), faults=FaultConfig(), **kw
        )
        exact = bool(
            np.array_equal(base.pipeline.e2e, off.pipeline.e2e, equal_nan=True)
        )
        sched = tuple(
            (interval * (k + 0.5), "crash")
            for k in range(int(horizon / interval))
        )
        fr = ServingEngine(plan).run(
            n_frames, rate, control=ctrl(),
            faults=FaultConfig(schedule=sched, seed=3, detect_k=detect_k),
            **kw,
        )
        att = lambda r: float(  # noqa: E731
            (np.asarray(r.e2e_latencies) <= slo + 1e-9).sum()
            / max(1, r.offered)
        )
        pr = fr.pipeline
        conserved = (
            int(pr.completed.sum() + pr.shed.sum() + pr.dropped.sum())
            == fr.offered
        )
        rep = classify_misses(pr, slo, fr.epochs)
        c_base = serving_cost(base.epochs, horizon)
        c_fault = serving_cost(fr.epochs, horizon)
        ratio = c_fault / c_base
        a = att(fr)
        atts.append(a)
        ratios.append(ratio)
        exact_all &= exact
        conserved_all &= conserved and rep.conserved
        emit(
            f"chaos_{name}",
            0.0,
            f"attain={a:.4f}|base={att(base):.4f}|cost_ratio={ratio:.3f}"
            f"|crashes={fr.faults['injected']}|killed={fr.faults['killed']}"
            f"|requeued={fr.faults['requeued']}|conserved={conserved}"
            f"|forensics={rep.conserved}|off_bitexact={exact}",
            app=name,
            attainment=round(a, 4),
            base_attainment=round(att(base), 4),
            cost_ratio=round(ratio, 4),
            crashes=fr.faults["injected"],
            killed=fr.faults["killed"],
            requeued=fr.faults["requeued"],
            machine_failure=rep.counts.get("machine_failure", 0),
            recovery_transient=rep.counts.get("recovery_transient", 0),
            conserved=bool(conserved),
            forensics_conserved=bool(rep.conserved),
            off_bitexact=exact,
        )
    mean_att = finite_mean(atts)
    worst_ratio = max(ratios) if ratios else math.nan
    emit(
        "chaos_sweep",
        (time.perf_counter() - t0) * 1e6,
        f"attain={mean_att:.4f}|worst_cost_ratio={worst_ratio:.3f}"
        f"|off_bitexact={exact_all}|conserved={conserved_all}"
        f"|target>=0.9@<=1.3x",
        attainment=round(mean_att, 4),
        worst_cost_ratio=round(worst_ratio, 4),
        off_bitexact=bool(exact_all),
        conserved=bool(conserved_all),
    )
    if SMOKE and not exact_all:
        print(
            "# SMOKE FAILURE: disabled fault injector is not bit-exact "
            "with the fault-free engine",
            file=sys.stderr,
        )
        raise SystemExit(1)
    if SMOKE and not conserved_all:
        print(
            "# SMOKE FAILURE: frame conservation or forensics cascade "
            "violated under the crash-per-epoch schedule",
            file=sys.stderr,
        )
        raise SystemExit(1)
    if SMOKE and (mean_att < 0.9 or worst_ratio > 1.3):
        print(
            f"# SMOKE FAILURE: chaos attainment {mean_att:.4f} < 0.9 or "
            f"cost ratio {worst_ratio:.3f} > 1.3x no-fault",
            file=sys.stderr,
        )
        raise SystemExit(1)

    # --- MTBF x detection-timeout grid (informational, one app) -----------
    name, rate, slo = seeds[0]
    period = n_frames / rate
    arr = trace_arrivals(n_frames, rate, seed=0, period=period)
    wl = make_workload(app_by_name(name), rate, slo / derate)
    plan = Planner(B.HARPAGON).plan(wl, PROFILES)
    interval = period / div
    for mtbf_mult in (1.0, 2.0):
        for k in (2.0, 4.0):
            fc = FaultConfig(
                mtbf=interval * mtbf_mult, kinds=("crash", "straggler"),
                seed=7, detect_k=k,
            )
            r = ServingEngine(plan).run(
                n_frames, rate,
                arrivals=arr,
                frontend=FrontendConfig(dummies=True, burst_deadline=True),
                pipeline=True, timeout="budget",
                control=ControlLoopConfig(
                    interval=interval, profiles=PROFILES, margin=0.35
                ),
                faults=fc,
            )
            a = float(
                (np.asarray(r.e2e_latencies) <= slo + 1e-9).sum()
                / max(1, r.offered)
            )
            emit(
                f"chaos_grid_m{mtbf_mult:g}_k{k:g}",
                0.0,
                f"attain={a:.4f}|injected={r.faults['injected']}"
                f"|killed={r.faults['killed']}"
                f"|requeued={r.faults['requeued']}",
                app=name,
                mtbf_epochs=mtbf_mult,
                detect_k=k,
                attainment=round(a, 4),
                injected=r.faults["injected"],
                killed=r.faults["killed"],
                requeued=r.faults["requeued"],
            )


# ----------------------------------------------------------- runtime
def bench_runtime(n: int) -> None:
    """Planner runtime vs brute force (paper: 5 ms vs 35.9 s, >7000x)."""
    wls = workload_suite(40)
    h = Planner(B.HARPAGON)
    t_h, t_bf, cnt = 0.0, 0.0, 0
    for wl in wls:
        t0 = time.perf_counter()
        plan = h.plan(wl, PROFILES)
        t_h += time.perf_counter() - t0
        if not plan.feasible:
            continue
        t0 = time.perf_counter()
        optimal_cost(wl, PROFILES)
        t_bf += time.perf_counter() - t0
        cnt += 1
    us = t_h * 1e6 / len(wls)
    derived = (
        f"harpagon={1e3*t_h/len(wls):.2f}ms|bruteforce={1e3*t_bf/max(1,cnt):.1f}ms"
        f"|speedup={t_bf/max(1,cnt)/(t_h/len(wls)):.0f}x|paper=5ms vs 35.9s"
    )
    emit("runtime_planner", us, derived)


BENCHES = {
    "table2": bench_table2,
    "fig5": bench_fig5_cost,
    "fig5b": bench_fig5_optimal,
    "fig6": bench_fig6_ablations,
    "fig7": bench_fig7_dispatch,
    "fig7sim": bench_fig7_simulation,
    "fig8": bench_fig8_multiconfig,
    "slo_sweep": bench_slo_sweep,
    "shed_sweep": bench_shed_sweep,
    "pipeline_sweep": bench_pipeline_sweep,
    "diurnal_sweep": bench_diurnal_sweep,
    "multitenant_sweep": bench_multitenant_sweep,
    "chaos_sweep": bench_chaos_sweep,
    "pipeline_speed": bench_pipeline_speed,
    "wallclock_gap": bench_wallclock_gap,
    "planner_speed": bench_planner_speed,
    "dp_splitter": bench_dp_splitter,
    "replay": bench_replay_speed,
    "runtime": bench_runtime,
}

# serving-subsystem rows tracked across PRs by `--json` (BENCH_serving.json)
_SERVING_PREFIXES = (
    "replay_", "slo_sweep_", "shed_sweep_", "shed_causes_", "pipeline_sweep_",
    "diurnal_", "multitenant_", "chaos_", "pipeline_speed", "planner_speed",
    "dp_splitter_", "wallclock_gap_",
)

# --smoke: CI-sized inputs + hard regression gates (see bench_replay_speed)
SMOKE = False


def main() -> None:
    global SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--n", type=int, default=1131)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI smoke mode: shrink inputs and FAIL (exit 1) on hot-path "
        "regressions (replay speedup / kernel agreement)",
    )
    ap.add_argument(
        "--json",
        nargs="?",
        const="BENCH_serving.json",
        default=None,
        metavar="PATH",
        help="write serving-bench rows (replay/pipeline speedups, SLO sweep, "
        "shed-rate sweep, diurnal control-plane sweep, planner speed) as "
        "machine-readable JSON (default path: BENCH_serving.json)",
    )
    ap.add_argument(
        "--profile",
        type=int,
        nargs="?",
        const=25,
        default=0,
        metavar="N",
        help="run each selected bench under cProfile and print its top-N "
        "functions by cumulative time (default N=25) — e.g. "
        "`--only pipeline_speed --profile` profiles the pipeline loop",
    )
    args = ap.parse_args()
    SMOKE = args.smoke
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name not in args.only.split(","):
            continue
        if args.profile:
            import cProfile
            import pstats

            prof = cProfile.Profile()
            prof.enable()
            try:
                fn(args.n)
            finally:
                prof.disable()
                print(f"# --- cProfile top {args.profile}: {name} ---", file=sys.stderr)
                stats = pstats.Stats(prof, stream=sys.stderr)
                stats.strip_dirs().sort_stats("cumulative").print_stats(args.profile)
        else:
            fn(args.n)
    if args.json:
        rows = [
            r for r in common.RECORDS if r["name"].startswith(_SERVING_PREFIXES)
        ]
        if rows:
            # merge-by-name into the tracked file: partial `--only` runs
            # update their rows in place, the union stays name-sorted
            # (`common.write_bench_json`, schema v2)
            common.write_bench_json(args.json, rows)
            print(
                f"# merged {len(rows)} serving rows into {args.json} "
                f"(schema v{common.SCHEMA_VERSION})",
                file=sys.stderr,
            )
        else:
            # don't clobber a tracked trajectory file with an empty record
            print(
                f"# no serving benches ran (need one of: replay, slo_sweep, "
                f"shed_sweep); {args.json} left untouched",
                file=sys.stderr,
            )


if __name__ == "__main__":
    main()
