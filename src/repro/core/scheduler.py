"""Module scheduling: Algorithm 1 (multi-tuple GenerateConfig) + restricted variants.

Given a module's request rate ``T``, latency budget ``L`` and profile ``P``
(configs ordered by throughput-cost ratio), produce the allocation set.

* ``generate_config``         — paper Algorithm 1 (any number of tuples).
* ``generate_config_ktuple``  — baseline variant limited to K distinct
  configurations (K=1: InferLine/Clipper/Harp-1c, K=2: Nexus/Scrooge/Harp-2c).

Feasibility of a configuration at a point in the greedy walk is checked with
``GetWCL`` under the session's dispatch policy: for TC the batch-collection
rate is the *current unallocated workload* ``rw`` (which, walking in ratio
order, equals Theorem 1's remaining workload ``w_i``).
"""
from __future__ import annotations

import math

from .dispatch import Alloc, Policy, config_wcl
from .profiles import Config, ModuleProfile

_EPS = 1e-9


def get_wcl(
    config: Config, policy: Policy, rw: float, *, full: bool, headroom: float = 0.0,
    burst: float = 0.0,
) -> float:
    """L_wc estimate for a machine at ``config`` when ``rw`` workload remains.

    With ``headroom`` > 0 a full machine is only assigned
    ``(1 - headroom) * throughput`` traffic, so under RR/DT it collects at
    that derated capacity instead of its own throughput (TC collection is the
    remaining *real* workload either way — Theorem 1 is headroom-invariant).

    ``burst`` (seconds) is the burst-aware collection correction downstream
    of batched stages (see `dispatch.config_wcl`).  It applies to every
    machine whose batch actually waits on arrivals: a short-fill machine
    (full or tail) straddles an upstream inter-completion gap just the same.
    """
    if policy is Policy.TC:
        return config_wcl(config, policy, collect_rate=rw, burst=burst)
    if policy in (Policy.RR, Policy.DT):
        # sound model: full machines collect at their own throughput (2d);
        # partial machines cannot collect faster than their assigned rate.
        if headroom > 0.0:
            cap = config.throughput * (1.0 - headroom)
            return config_wcl(
                config, policy, collect_rate=(cap if full else min(rw, cap)),
                full=False, burst=burst,
            )
        rate = config.throughput if full else rw
        if full:
            # 2d short-circuit in config_wcl skips the burst term; a full
            # machine's local collection is still arrival-quantized
            return config_wcl(config, policy, collect_rate=rate, full=True) + burst
        return config_wcl(config, policy, collect_rate=rate, full=False, burst=burst)
    return config_wcl(config, policy, collect_rate=config.throughput)  # DT_OPT


def _merge(allocs: list[Alloc]) -> list[Alloc]:
    """Merge adjacent allocations that share a configuration."""
    out: list[Alloc] = []
    for a in allocs:
        if out and out[-1].config == a.config and out[-1].derate == a.derate:
            prev = out.pop()
            out.append(
                Alloc(
                    a.config,
                    prev.machines + a.machines,
                    prev.rate + a.rate,
                    prev.dummy + a.dummy,
                    derate=a.derate,
                )
            )
        else:
            out.append(a)
    return out


def generate_config(
    T: float,
    L: float,
    profile: ModuleProfile,
    policy: Policy = Policy.TC,
    *,
    headroom: float = 0.0,
    burst: float = 0.0,
) -> tuple[bool, list[Alloc]]:
    """Paper Algorithm 1: greedy multi-tuple configuration generation.

    ``headroom`` provisions machines at ``throughput * (1 - headroom)``: the
    same real workload is spread over proportionally more machines, so each
    machine's batch run period carries slack for timeout-flushed partial
    batches (the paper's zero-slack pacing permanently loses throughput to
    any partial flush).  Feasibility is still checked against the *real*
    collection rates, so the WCL model stays honest.

    ``burst`` (seconds) applies the burst-aware tail correction: a fractional
    tail machine's feasibility is checked at ``d + b/w + burst``, so modules
    fed by upstream batch completions don't get tails whose realized
    collection straddles an upstream inter-batch gap past their budget.
    """
    if not 0.0 <= headroom < 1.0:
        raise ValueError(f"headroom must be in [0, 1), got {headroom}")
    if T <= _EPS:
        return True, []
    derate = 1.0 - headroom
    rw = T
    allocs: list[Alloc] = []
    k = 0
    configs = profile.configs  # ratio-descending
    if not configs:
        return False, []
    c = configs[k]
    while rw > _EPS:
        cap = c.throughput * derate
        n = rw / cap
        full = n >= 1.0 - 1e-12
        if get_wcl(c, policy, rw, full=full, headroom=headroom, burst=burst) <= L + _EPS:
            if full:
                nfull = math.floor(n + 1e-12)
                allocs.append(Alloc(c, float(nfull), nfull * cap, derate=derate))
                rw -= nfull * cap
                if rw < _EPS:
                    rw = 0.0
                # loop re-checks the same c against the smaller rw
            else:
                allocs.append(Alloc(c, n, rw, derate=derate))
                rw = 0.0
        else:
            k += 1
            if k >= len(configs):
                # No configuration can serve the residual fractionally (a tiny
                # rate cannot even fill a batch of 1 within the budget).  Fall
                # back to DUMMY-FILLING one machine: the frontend pads the
                # residual to a full machine's throughput, so the batch
                # collects at rate t (L_wc = 2d) at the price of one machine.
                fill = _dummy_fill(rw, L, configs, policy, headroom=headroom, burst=burst)
                if fill is None:
                    return False, []
                allocs.append(fill)
                rw = 0.0
                break
            c = configs[k]
    return True, _merge(allocs)


def _dummy_fill(
    rw: float, L: float, configs, policy: Policy, *, headroom: float = 0.0,
    burst: float = 0.0,
) -> Alloc | None:
    """Cheapest single machine that can carry ``rw`` when padded with dummies.

    The burst correction applies here too: the padding phantoms are injected
    at the frontend's rate-limited pace, so a bursty upstream still leaves
    the dummy-filled machine's collection quantized by its real arrivals.
    """
    derate = 1.0 - headroom
    best = None
    for c in configs:
        if c.throughput * derate < rw - _EPS:
            continue
        wcl = get_wcl(c, policy, c.throughput * derate, full=True, headroom=headroom)
        if wcl + burst > L + _EPS:
            continue
        if best is None or c.unit_price < best.unit_price:
            best = c
    if best is None:
        return None
    return Alloc(best, 1.0, rw, dummy=best.throughput * derate - rw, derate=derate)


def _cover_with_config(
    c: Config,
    rate: float,
    L: float,
    policy: Policy,
    *,
    collect_rate: float,
    allow_dummy: bool,
) -> list[Alloc] | None:
    """Serve ``rate`` entirely with machines at ``c`` within ``L``, or None.

    With ``allow_dummy`` the fractional tail machine may be dummy-filled when
    its own rate cannot collect a batch in time (prior systems' early-exec /
    over-provisioned residual machine — still one machine's price).
    """
    nfull = math.floor(rate / c.throughput + 1e-12)
    frac_rate = rate - nfull * c.throughput
    if nfull > 0 and get_wcl(c, policy, collect_rate, full=True) > L + _EPS:
        return None
    out = []
    if nfull > 0:
        out.append(Alloc(c, float(nfull), nfull * c.throughput))
    if frac_rate > _EPS:
        if get_wcl(c, policy, frac_rate, full=False) <= L + _EPS:
            out.append(Alloc(c, frac_rate / c.throughput, frac_rate))
        elif allow_dummy and get_wcl(c, policy, c.throughput, full=True) <= L + _EPS:
            out.append(Alloc(c, 1.0, frac_rate, dummy=c.throughput - frac_rate))
        else:
            return None
    return out


def _cover_residual(
    configs, rate: float, L: float, policy: Policy, *, collect_rate: float
) -> list[Alloc] | None:
    """Fractional coverage by the best-ratio config first; dummy-fill last."""
    for allow_dummy in (False, True):
        for c in configs:
            cover = _cover_with_config(
                c, rate, L, policy, collect_rate=collect_rate, allow_dummy=allow_dummy
            )
            if cover is not None:
                return cover
    return None


def generate_config_ktuple(
    T: float,
    L: float,
    profile: ModuleProfile,
    policy: Policy,
    k_tuples: int,
) -> tuple[bool, list[Alloc]]:
    """K-restricted scheduling used by prior systems.

    K=1: one configuration must carry the whole workload (incl. its fractional
    tail machine).  K=2: best-ratio feasible config for the majority
    (``floor(T/t)`` full machines), then ONE further config for the residual.
    """
    if T <= _EPS:
        return True, []
    configs = profile.configs
    if k_tuples <= 1:
        for allow_dummy in (False, True):
            for c in configs:
                cover = _cover_with_config(
                    c, T, L, policy, collect_rate=T, allow_dummy=allow_dummy
                )
                if cover is not None:
                    return True, _merge(cover)
        return False, []
    # K == 2 (the paper's two-tuple <c_opt, c_res>): greedy two-round heuristic
    # of prior systems — first feasible (max-ratio) majority config, then the
    # first config that can carry the residual including its tail machine.
    for c in configs:
        if get_wcl(c, policy, T, full=True) > L + _EPS:
            continue
        nfull = math.floor(T / c.throughput + 1e-12)
        allocs = []
        res = T
        if nfull >= 1:
            allocs.append(Alloc(c, float(nfull), nfull * c.throughput))
            res = T - nfull * c.throughput
        if res <= _EPS:
            return True, _merge(allocs)
        cover = _cover_residual(configs, res, L, policy, collect_rate=res)
        if cover is not None:
            return True, _merge(allocs + cover)
        # greedy majority left an infeasible residual: try next majority config
    return False, []
