"""Seeded fault injection for the pipelined serving loop.

Every machine in the simulator is immortal by default — the exact
platform-reliability blind spot *No DNN Left Behind* raises for shared
cloud inference: one device failure in a consolidated pool silently
stalls multiple tenants.  This package makes failures first-class,
deterministic events:

* :class:`FaultConfig` — the user-facing knob set: an MTBF-driven
  exponential fault process and/or an explicit ``(time, kind)``
  schedule, the fault taxonomy to draw from, and the detection /
  recovery parameters.  A default-constructed config is *disabled* and
  the engine treats it exactly like ``faults=None`` — runs are bit-exact
  with the injector off.
* :class:`FaultRuntime` — the seeded per-run state the event loop
  drives: the fault arrival chain, deterministic victim selection, the
  straggler slowdown table shared with
  `service_time.DegradedServiceTime`, and the suspect→dead escalation
  state of the batch-duration watchdog.

Fault taxonomy (``kind``):

``"crash"``
    The machine dies silently.  Dispatch keeps feeding it (nobody knows
    yet); its in-service batch never completes and its queue never
    drains.  The watchdog heartbeat — armed at every batch close for
    ``detect_k ×`` the machine's modeled service duration — escalates it
    suspect → dead, at which point the stage re-queues every unfinished
    member to surviving siblings (`ModuleStage.fail_machine`) and the
    control plane force-replans the module (`ControlRuntime.on_failure`).
``"straggler"``
    Transient slowdown: the machine's service durations inflate by
    ``straggler_factor`` for ``straggler_duration`` seconds, then
    recover.  A straggler that trips the watchdog once is flagged
    suspect; a completion before the second missed heartbeat clears it
    (no failover churn for transients that self-heal).
``"device_loss"``
    Whole-accelerator death in a shared pool: every co-located machine
    slot on one physical device crashes at once, and the
    `GlobalAllocator` re-packs the evicted residues onto surviving
    devices (`fail_device`).  Outside a shared pool it degrades to a
    single-machine crash.

Determinism: all draws come from one ``np.random.default_rng(seed)``
stream, victims are selected from *sorted* candidate lists, and explicit
schedules fire at their listed times — the same config against the same
workload produces the same fault history, byte for byte.
"""
from .injector import FaultConfig, FaultRuntime, FAULT_KINDS

__all__ = ["FAULT_KINDS", "FaultConfig", "FaultRuntime"]
