"""Shared benchmark plumbing: workload suite, planner set, CSV emission."""
from __future__ import annotations

import json
import math
import sys
import time
from pathlib import Path

sys.path.insert(0, "src")

from repro.core import Planner  # noqa: E402
from repro.core import baselines as B  # noqa: E402
from repro.workloads import synth_profiles, synth_workloads  # noqa: E402

PROFILES = synth_profiles()


def workload_suite(n: int = 1131):
    return synth_workloads(n)


def plan_all(workloads, options_list):
    planners = {o.name: Planner(o) for o in options_list}
    rows = []
    for wl in workloads:
        rows.append((wl, {k: p.plan(wl, PROFILES) for k, p in planners.items()}))
    return rows


def normalized_costs(rows, names):
    """Per-workload cost / Harpagon cost; inf when infeasible."""
    out = {k: [] for k in names}
    for _, plans in rows:
        h = plans["harpagon"]
        if not h.feasible:
            continue
        for k in names:
            p = plans[k]
            out[k].append(p.cost / h.cost if p.feasible else math.inf)
    return out


# structured copies of every emitted row, for `run.py --json` (BENCH_serving.json)
RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str, **data):
    """Print one CSV row; ``data`` keyword fields ride along machine-readable."""
    print(f"{name},{us_per_call:.3f},{derived}")
    rec = {"name": name, "us_per_call": round(us_per_call, 3), "derived": derived}
    if data:
        rec["data"] = data
    RECORDS.append(rec)


def timed(fn, *args, repeat: int = 3):
    best = math.inf
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


# BENCH_serving.json schema: v2 adds the version field itself, merge-write
# semantics (a partial `--only` run updates its rows in place instead of
# clobbering the rest), and deterministic name-sorted row order
SCHEMA_VERSION = 2


def write_bench_json(path: str, rows: "list[dict]") -> None:
    """Merge ``rows`` into the benchmark JSON at ``path``, deterministically.

    Rows are keyed by ``name``: an existing file's rows are kept unless this
    run re-emitted them, and the union is written sorted by name — so
    repeated partial runs converge to the same bytes regardless of which
    subset ran last, and diffs show only rows whose numbers moved.
    """
    p = Path(path)
    merged: dict[str, dict] = {}
    if p.exists():
        try:
            old = json.loads(p.read_text())
        except (OSError, ValueError):
            old = {}
        for r in old.get("benches", []):
            if isinstance(r, dict) and "name" in r:
                merged[r["name"]] = r
    for r in rows:
        merged[r["name"]] = r
    doc = {
        "schema_version": SCHEMA_VERSION,
        "benches": [merged[k] for k in sorted(merged)],
    }
    p.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
