"""repro: Harpagon (INFOCOM'25) serving-cost minimization + JAX/TPU data plane."""
__version__ = "0.1.0"
