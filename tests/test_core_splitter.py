"""Latency splitting: LC example, Algorithm 2, optimizers, baseline splitters."""
import pytest

from repro.core import Leaf, Policy, Workload, par, series
from repro.core.dag import AppDAG
from repro.core.profiles import TABLE1, TABLE1_M1, TABLE1_M2, TABLE1_M3
from repro.core.splitter import (
    split_cost,
    split_even,
    split_lc,
    split_quantized,
    split_throughput,
    split_wcl,
)


def two_module_wl(slo=1.2, t1=100.0, t2=100.0):
    dag = AppDAG("app", series(Leaf("M1"), Leaf("M2")))
    return Workload(dag, {"M1": t1, "M2": t2}, slo)


PROFILES = {"M1": TABLE1_M1, "M2": TABLE1_M2, "M3": TABLE1_M3}


class TestLCExample:
    def test_paper_lc_values(self):
        """Sec. III-D: M1 at T=100 from b2: LC(b4)=50, LC(b8)=18.2."""
        by_batch = {c.batch: c for c in TABLE1_M1.configs}
        T = 100.0
        prev = by_batch[2]
        for b, expect in [(4, 50.0), (8, 18.2)]:
            new = by_batch[b]
            dcost = split_cost(prev, T) - split_cost(new, T)
            dlat = split_wcl(new, T, Policy.TC) - split_wcl(prev, T, Policy.TC)
            assert dcost / dlat == pytest.approx(expect, abs=0.05)


class TestSplitters:
    def test_lc_feasible_budgets(self):
        wl = two_module_wl()
        budgets = split_lc(wl, PROFILES, Policy.TC)
        assert budgets is not None
        assert wl.app.latency(budgets) <= wl.slo + 1e-9

    def test_infeasible_returns_none(self):
        wl = two_module_wl(slo=0.05)
        assert split_lc(wl, PROFILES, Policy.TC) is None

    def test_quantized_close_to_lc(self):
        wl = two_module_wl()
        b_lc = split_lc(wl, PROFILES, Policy.TC)
        b_q = split_quantized(wl, PROFILES, Policy.TC, q=0.01)
        assert b_q is not None
        assert wl.app.latency(b_q) <= wl.slo + 1e-9

    def test_throughput_based_feasible(self):
        wl = two_module_wl()
        b = split_throughput(wl, PROFILES, Policy.TC)
        assert b is not None and wl.app.latency(b) <= wl.slo + 1e-9

    def test_even_split(self):
        wl = two_module_wl(slo=2.0)
        b = split_even(wl, PROFILES, Policy.RR)
        assert b is not None
        assert all(v == pytest.approx(1.0) for v in b.values())


class TestNodeMerger:
    def test_sibling_groups(self):
        dag = AppDAG("t", series(Leaf("M1"), par(Leaf("M2"), Leaf("M3"))))
        groups = dag.sibling_groups()
        assert groups == [("M2", "M3")]

    def test_merger_never_hurts(self):
        dag = AppDAG("t", series(Leaf("M1"), par(Leaf("M2"), Leaf("M3"))))
        wl = Workload(dag, {"M1": 80.0, "M2": 60.0, "M3": 60.0}, 1.0)
        profiles = {"M1": TABLE1_M1, "M2": TABLE1_M2, "M3": TABLE1_M3}

        def total(budgets):
            out = 0.0
            for m, L in budgets.items():
                feas = [
                    c for c in profiles[m].configs
                    if split_wcl(c, wl.rates[m], Policy.TC) <= L + 1e-9
                ]
                out += min(split_cost(c, wl.rates[m]) for c in feas)
            return out

        with_m = split_lc(wl, profiles, Policy.TC, node_merge=True)
        without = split_lc(wl, profiles, Policy.TC, node_merge=False)
        assert with_m is not None and without is not None
        assert total(with_m) <= total(without) + 1e-6


class TestDAG:
    def test_latency_series_parallel(self):
        dag = AppDAG("t", series(Leaf("a"), par(Leaf("b"), Leaf("c")), Leaf("d")))
        lat = dag.latency({"a": 1.0, "b": 2.0, "c": 3.0, "d": 1.0})
        assert lat == 5.0  # 1 + max(2,3) + 1
        assert dag.depth == 3

    def test_edges(self):
        dag = AppDAG("t", series(Leaf("a"), par(Leaf("b"), Leaf("c")), Leaf("d")))
        assert set(dag.edges) == {("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")}
