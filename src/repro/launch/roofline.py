"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds per step:

  compute    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device
program, multiplied back to the full mesh); collective_bytes is parsed from
the partitioned HLO text: operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops, with a ring-algorithm
wire factor (2x for all-reduce, 1x otherwise).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  %all-gather.2 = f32[16,1,192]{1,0,2} all-gather(%copy.27), ...
#       %ar = (f32[8], f32[8]) all-reduce-start(...)
_COLL_RE = re.compile(
    r"=\s*([\w\(\)\[\],{} ]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of (possibly tuple) HLO result type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def wire_bytes(self) -> float:
        """Per-device bytes on the wire (ring model: 2x for all-reduce)."""
        total = 0.0
        for op, b in self.bytes_by_op.items():
            factor = 2.0 if op == "all-reduce" else 1.0
            total += factor * b
        return total

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective bytes, weighting ops inside while bodies (lax.scan over
    layer segments) by the loop trip count."""
    comps = _split_computations(hlo_text)
    trip: dict[str, int] = {}  # body computation -> trip count
    calls: dict[str, list[str]] = {name: [] for name in comps}
    for name, lines in comps.items():
        for line in lines:
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.group("cond"), mw.group("body")
                calls[name].append(body)
                trip[body] = _trip_count(comps.get(cond, []))
            for mc in _CALL_RE.finditer(line):
                callee = mc.group(1)
                if callee in comps:
                    calls[name].append(callee)

    # propagate multipliers from the entry computation
    mult: dict[str, int] = {}
    entry = next((n for n, l in comps.items() if l and l[0].startswith("ENTRY")), None)

    def visit(name: str, m: int):
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        for callee in calls.get(name, []):
            visit(callee, m * trip.get(callee, 1))

    if entry:
        visit(entry, 1)

    stats = CollectiveStats()
    for name, lines in comps.items():
        m_factor = mult.get(name, 1)
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            if m.group(3) == "-done":
                continue  # avoid double counting start/done pairs
            op = m.group(2).lower()
            b = _shape_bytes(m.group(1)) * m_factor
            stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
            stats.count_by_op[op] = stats.count_by_op.get(op, 0) + m_factor
    return stats


_WHILE_RE = re.compile(r"while\(.*?\), condition=%?(?P<cond>[\w.\-]+), body=%?(?P<body>[\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Split HLO text into computation blocks keyed by computation name."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and ("{" in line) and ("(" in line):
            # computation header: '%name (args) -> type {' or 'ENTRY %name ...'
            header = line.split("(")[0].strip()
            name = header.replace("ENTRY", "").strip().lstrip("%").strip()
            cur = name
            comps[cur] = [line.strip()]
            if "ENTRY" in line:
                comps[cur][0] = "ENTRY " + comps[cur][0]
        elif cur is not None:
            comps[cur].append(stripped)
            if stripped == "}":
                cur = None
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Best-effort trip count: the largest integer constant in the condition."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
        }


def roofline_from_compiled(
    compiled, chips: int, *, scan_correction: float = 1.0
) -> tuple[Roofline, CollectiveStats, dict]:
    """``scan_correction`` compensates cost_analysis counting each while-loop
    (lax.scan segment) body once: it is the analytic ratio of true layer work
    to once-per-segment layer work (see launch.dryrun.scan_correction)."""
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0)) * scan_correction
    byts = float(cost.get("bytes accessed", 0.0)) * scan_correction
    text = compiled.as_text()
    colls = parse_collectives(text)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "code_bytes": getattr(ma, "generated_code_size_in_bytes", 0),
        }
        mem["total_bytes"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
        )
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    rl = Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=colls.wire_bytes,
        chips=chips,
    )
    return rl, colls, mem
