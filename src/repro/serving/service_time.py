"""Pluggable service-time sources for the serving co-simulation.

The simulators take a batch's service duration from the plan's profiled
configuration (``machine.config.duration``) — the analytic roofline the
planner optimized against.  A :class:`ServiceTimeSource` makes that choice
explicit and swappable, so the *same* pipelined event loop can co-simulate
against measured executor step times:

* :class:`AnalyticServiceTime` — the profiled constant.  The default
  (``service_time=None``) bypasses the abstraction entirely and is
  **bit-exact** with the pre-existing paths; an explicit analytic source
  routes through the hook but returns the identical float.
* :class:`TraceServiceTime` — recorded per-``(module, batch)`` duration
  sample sequences, consumed in call order (the trace's ``seq`` axis) and
  optionally perturbed by seeded lognormal jitter.  Fully deterministic
  under a fixed seed: per-key RNG streams are derived from
  ``crc32(module) ^ batch`` so replay order across modules cannot leak
  randomness between keys.
* :class:`LiveServiceTime` — actual executor forwards
  (``executors[module](batch_size)``, e.g. the jitted reduced-model
  forwards of ``repro.launch.serve --real``), timed with
  ``time.perf_counter`` per batch start and cached per ``(module, batch)``
  once ``warmup`` timed calls have retired the jit/compile transient.

Sources are consulted at **batch start** (`events.MachineCore.start`'s
``duration`` callable — the single choke point both the single-module event
core and the pipelined `ModuleStage` drive), so every formation/deadline
decision upstream of service is untouched.  The measured duration of every
started batch can additionally be fed to an observer (the control plane's
`ControlRuntime.observe_service`), which is how epochs replan against
reality instead of the analytic roofline.
"""
from __future__ import annotations

import time
import zlib
from typing import Callable, Mapping, Sequence

import numpy as np

from ..core.dispatch import Machine


def _key_stream(seed: int, module: str, batch: int) -> np.random.Generator:
    """A per-(module, batch) RNG stream, stable across call interleavings."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(module.encode("utf-8")), batch])
    )


class ServiceTimeSource:
    """Base protocol: map a batch start to its service duration (seconds).

    ``duration(module, machine, n_members)`` is called once per started
    batch with the full member count (phantom fills included — an executor
    runs the whole batch).  Implementations must be deterministic under
    :meth:`reset` for replayability; the base class is the analytic
    semantics itself.
    """

    kind = "analytic"

    def duration(self, module: str, machine: Machine, n_members: int) -> float:
        return machine.config.duration

    def reset(self) -> None:
        """Rewind any per-run state (sample cursors, RNG streams, caches)."""


class AnalyticServiceTime(ServiceTimeSource):
    """The profiled configuration duration — identical to the default path."""


class TraceServiceTime(ServiceTimeSource):
    """Replay recorded duration samples deterministically.

    ``samples`` maps ``(module, batch) -> [d0, d1, ...]`` — or, on
    heterogeneous pools where the same batch size runs on several hardware
    tiers, ``(module, batch, hardware)``; ``module -> [...]`` is a
    batch-agnostic fallback.  The k-th started batch of a key takes sample
    ``k mod len`` — the trace's sequence axis.  Keys with no samples fall
    back to the profiled duration.  ``jitter`` (relative
    sigma) multiplies each draw by a lognormal factor from the key's own
    seeded stream, so two runs with the same seed are bit-identical
    regardless of how other modules' calls interleave.
    """

    kind = "trace"

    def __init__(
        self,
        samples: "Mapping[tuple[str, int] | str, Sequence[float]]",
        *,
        jitter: float = 0.0,
        seed: int = 0,
    ):
        if jitter < 0.0:
            raise ValueError("jitter must be >= 0")
        self.samples = {
            k: [float(d) for d in v] for k, v in samples.items()
        }
        for k, v in self.samples.items():
            if any(d <= 0.0 for d in v):
                raise ValueError(f"trace durations must be positive ({k!r})")
        self.jitter = float(jitter)
        self.seed = int(seed)
        self.reset()

    def reset(self) -> None:
        self._pos: dict[tuple[str, int], int] = {}
        self._rng: dict[tuple[str, int], np.random.Generator] = {}

    def duration(self, module: str, machine: Machine, n_members: int) -> float:
        b = machine.config.batch
        key = (module, b, machine.config.hardware)
        seq = self.samples.get(key)
        if seq is None:
            key = (module, b)
            seq = self.samples.get(key)
        if seq is None:
            seq = self.samples.get(module)
        if seq:
            i = self._pos.get(key, 0)
            self._pos[key] = i + 1
            d = seq[i % len(seq)]
        else:
            d = machine.config.duration
        if self.jitter > 0.0:
            rng = self._rng.get(key)
            if rng is None:
                rng = self._rng[key] = _key_stream(self.seed, module, b)
            d *= float(np.exp(self.jitter * rng.standard_normal()))
        return d


class InterferenceServiceTime(ServiceTimeSource):
    """Stretch specific machines' durations by co-location slowdown factors.

    ``factors`` maps ``(module, machine_id) -> multiplicative slowdown``
    (>= 1.0) for the residue machines the tenancy allocator packed onto a
    shared device; every other machine runs at the underlying duration.
    The mapping is read *live* on every batch start, so the shared-pool
    runtime can mutate it in place when an epoch repack changes who a
    machine is co-resident with (hot-swapped device plans).

    ``base`` is an optional wrapped source (trace / live measurements);
    ``None`` stretches the profiled constant.  ``kind`` is non-analytic on
    purpose: a co-located tail is *not* the profiled constant the
    vectorized flat kernel replays, so eligible runs stay on the event
    loop where per-machine durations are honored.
    """

    kind = "interference"

    def __init__(
        self,
        factors: "Mapping[tuple[str, int], float]",
        base: "ServiceTimeSource | None" = None,
    ):
        for k, s in factors.items():
            if s < 1.0 - 1e-12:
                raise ValueError(f"slowdown factors must be >= 1 ({k!r}: {s})")
        # held by reference, never copied: the shared-pool repack hook
        # mutates the caller's mapping in place and the next batch start
        # must see the post-repack slowdowns
        self.factors = factors
        self.base = base

    def duration(self, module: str, machine: Machine, n_members: int) -> float:
        d = (
            self.base.duration(module, machine, n_members)
            if self.base is not None
            else machine.config.duration
        )
        return d * self.factors.get((module, machine.mid), 1.0)

    def reset(self) -> None:
        if self.base is not None:
            self.base.reset()


class DegradedServiceTime(ServiceTimeSource):
    """Stretch straggling machines' durations by live fault slowdowns.

    ``slow`` is the fault injector's straggler table
    (`faults.FaultRuntime.slow`), held **by reference**: a ``straggler``
    fault entering a ``(module, machine_id)`` key inflates that machine's
    service durations mid-run, and the recovery event removing the key
    restores them — no stage or plan state is touched.  ``base`` is the
    run's underlying source (trace / live / interference); ``None``
    stretches the profiled constant.

    ``kind`` is non-analytic on purpose: a straggling machine is not the
    profiled constant the vectorized flat kernel replays, so fault runs
    stay on the event loop where per-machine durations are honored.  An
    empty table is a pure pass-through — with the injector disabled the
    wrapper is never installed at all, keeping the default path bit-exact.
    """

    kind = "degraded"

    def __init__(
        self,
        slow: "Mapping[tuple[str, int], float]",
        base: "ServiceTimeSource | None" = None,
    ):
        # held by reference, never copied: the fault runtime mutates the
        # table in place as stragglers come and go
        self.slow = slow
        self.base = base

    def duration(self, module: str, machine: Machine, n_members: int) -> float:
        d = (
            self.base.duration(module, machine, n_members)
            if self.base is not None
            else machine.config.duration
        )
        return d * self.slow.get((module, machine.mid), 1.0)

    def reset(self) -> None:
        if self.base is not None:
            self.base.reset()


class LiveServiceTime(ServiceTimeSource):
    """Measure real executor forwards, cache steady-state per (module, batch).

    Each consulted batch runs ``executors[module](batch_size)`` and times it.
    The first ``warmup`` timed calls of a key are treated as the jit/compile
    transient; once a key has ``warmup + 1`` measurements, the mean of the
    post-warmup ones is cached and returned without re-executing (the
    co-simulation then advances at recorded wall-clock speed).  Modules
    without an executor fall back to the profiled duration.  ``cache=False``
    re-measures every batch (honest but slow — every simulated batch is a
    real forward).
    """

    kind = "live"

    def __init__(
        self,
        executors: Mapping[str, Callable[[int], None]],
        *,
        warmup: int = 1,
        cache: bool = True,
    ):
        if warmup < 0:
            raise ValueError("warmup must be >= 0")
        self.executors = dict(executors)
        self.warmup = int(warmup)
        self.cache = bool(cache)
        self.reset()

    def reset(self) -> None:
        self.measured: dict[tuple[str, int], list[float]] = {}
        self._cached: dict[tuple[str, int], float] = {}

    def duration(self, module: str, machine: Machine, n_members: int) -> float:
        b = machine.config.batch
        key = (module, b)
        hit = self._cached.get(key)
        if hit is not None:
            return hit
        ex = self.executors.get(module)
        if ex is None:
            return machine.config.duration
        t0 = time.perf_counter()
        ex(b)
        d = time.perf_counter() - t0
        obs = self.measured.setdefault(key, [])
        obs.append(d)
        if self.cache and len(obs) > self.warmup:
            steady = obs[self.warmup:]
            self._cached[key] = sum(steady) / len(steady)
        return d

    def to_trace(self, *, jitter: float = 0.0, seed: int = 0) -> TraceServiceTime:
        """Freeze the measurements into a replayable trace (post-warmup)."""
        samples = {
            k: v[self.warmup:] or v for k, v in self.measured.items() if v
        }
        return TraceServiceTime(samples, jitter=jitter, seed=seed)


def resolve_service_time(
    spec: "str | ServiceTimeSource | None",
    executors: "Mapping[str, Callable[[int], None]] | None" = None,
) -> "ServiceTimeSource | None":
    """Normalize a ``run(service_time=...)`` spec.

    ``None`` / ``"analytic"`` resolve to ``None`` — the untouched (bit-exact)
    default path.  ``"live"`` wraps the engine's executors; ``"trace"``
    cannot be named by string (a trace needs its samples — pass a
    `TraceServiceTime`).
    """
    if spec is None or spec == "analytic":
        return None
    if spec == "live":
        if not executors:
            raise ValueError(
                'service_time="live" requires executors '
                "(ServingEngine(..., executors=...))"
            )
        return LiveServiceTime(executors)
    if spec == "trace":
        raise ValueError(
            'service_time="trace" needs its samples: pass a '
            "TraceServiceTime(samples, ...) instance"
        )
    if isinstance(spec, ServiceTimeSource):
        return spec
    raise TypeError(f"unknown service_time spec {spec!r}")
