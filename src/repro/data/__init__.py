from .pipeline import BigramStream, lm_batches

__all__ = ["BigramStream", "lm_batches"]
