"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    arch_type="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    source="hf:HuggingFaceTB/SmolLM-135M",
    head_dim=64,
    tie_embeddings=True,
    rope_theta=10_000.0,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=192,
    n_heads=3,
    n_kv_heads=1,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
)
