"""Module-level dispatch simulator: empirical validation of Theorem 1.

Thin adapter over the unified simulation subsystem: requests arrive under a
pluggable arrival process (`repro.serving.arrivals` — uniform by default,
the paper's streaming-video regime), the dispatcher assigns them to machines
under TC / RR policy via the literal `core.dispatch.dispatch_runs`, and the
numpy-vectorized replay kernel (`repro.serving.replay`) executes batches at
the profiled duration.  The maximum observed request latency is compared
against the analytic worst-case L_wc of `core.dispatch.module_wcl`.

Tail semantics default to the seed behavior (``tail="drop"``: incomplete
tail batches are out of steady state and excluded — Theorem 1 is a
steady-state bound), reproducing the legacy numbers exactly; pass a finite
``timeout`` for real deadline-flush semantics where every request completes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from ..core.dispatch import Alloc, Policy, dispatch_runs, expand_machines
from .arrivals import make_arrivals
from .replay import replay_module


@dataclass
class SimResult:
    max_latency: float
    mean_latency: float
    per_machine_max: dict[int, float]
    n_requests: int
    dropped: int = 0
    p99_latency: float = 0.0
    latencies: np.ndarray | None = field(default=None, repr=False)


def simulate(
    allocs: list[Alloc],
    total_rate: float,
    *,
    policy: Policy = Policy.TC,
    n_requests: int = 2000,
    arrivals: "str | np.ndarray | Sequence[float]" = "uniform",
    seed: int = 0,
    timeout: float | None = None,
    tail: str = "drop",
    method: str = "vectorized",
) -> SimResult:
    machines = expand_machines(allocs)
    t = make_arrivals(arrivals, n_requests, total_rate, seed=seed)
    runs = dispatch_runs(machines, n_requests, policy)
    rep = replay_module(machines, t, runs, timeout=timeout, tail=tail, method=method)
    done = rep.done
    lat = rep.finish[done] - t[done]
    # group latencies by machine with one stable argsort (hot at 10^6 reqs)
    order = np.argsort(rep.assignment, kind="stable")
    sorted_mid = rep.assignment[order]
    lat_all = rep.finish[order] - t[order]  # NaN where dropped
    per_machine_max = {}
    for m in machines:
        lo = int(np.searchsorted(sorted_mid, m.mid, side="left"))
        hi = int(np.searchsorted(sorted_mid, m.mid, side="right"))
        mine = lat_all[lo:hi]
        mine = mine[~np.isnan(mine)]
        per_machine_max[m.mid] = float(mine.max()) if mine.size else 0.0
    n_done = int(done.sum())
    return SimResult(
        max_latency=float(lat.max()) if n_done else 0.0,
        mean_latency=float(lat.mean()) if n_done else 0.0,
        per_machine_max=per_machine_max,
        n_requests=n_done,
        dropped=n_requests - n_done,
        p99_latency=float(np.quantile(lat, 0.99)) if n_done else 0.0,
        latencies=lat,
    )
