"""Incremental serving control plane: rate estimation, replanning, hot-swap.

Harpagon's planner derives one static plan for a fixed per-module rate, but
real arrival processes are diurnal and bursty: a single plan must be
provisioned for the peak and wastes machines the rest of the day — the
exact serving-cost inefficiency the paper targets, one level up.  This
module closes the loop (in the direction of OCTOPINF-style workload-aware
re-scheduling): a :class:`ControlRuntime` lives *inside* the pipelined
event loop, estimates the offered frame rate over a sliding window, calls
`Planner.replan` (warm-start incremental repair, versioned plans) at every
epoch boundary, and applies the resulting `PlanDelta` to the live stages
without dropping an in-flight frame:

* **drained machines finish their open batch** (closed at the swap instant)
  and their queued work, then retire from dispatch;
* **added machines join the dispatch walk immediately** — under
  ``timeout="budget"`` their flush deadlines come from the new schedule's
  per-rank remaining workloads (`dispatch.remaining_workloads`);
* **dummy streamers re-anchor** to the new provisioned collect rate;
* **admission controllers re-bind** their provisioned-rate policies to the
  new plan (`AdmissionController.rebind`), and closed-loop clients with
  ``backoff=None`` re-read the live plan's modeled latency on every retry.

Every epoch appends an :class:`EpochRecord` to :attr:`ControlRuntime.history`
(surfaced as ``ServeResult.epochs``), so a run's serving cost is auditable
as the time-integral of the active plan's cost — the quantity
``benchmarks.run --only diurnal_sweep`` compares against static peak
provisioning.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Callable, Mapping, Sequence

from ..core.dispatch import Machine, expand_machines
from ..core.harpagon import Plan, Planner
from ..core.profiles import ModuleProfile
from .frontend.admission import AdmissionController
from .pipeline.stages import StageUpdate


@dataclass(frozen=True)
class ControlLoopConfig:
    """Engine-facing knobs for ``ServingEngine.run(..., control=...)``.

    ``interval`` is the epoch length in simulated seconds; ``window`` the
    arrival-rate estimation window (default: one interval).  ``forecast``
    extrapolates the windowed estimate's trend one epoch ahead (two
    half-window rates -> slope), so a diurnal ramp is provisioned for where
    the rate *will be* when the next plan is live, not where it was half a
    window ago.  ``margin`` over-provisions on top (``target = est * (1 +
    margin)``) to absorb estimate noise and burn down backlog accumulated
    while under-provisioned.  ``tolerance`` / ``cost_guard`` are forwarded
    to `Planner.replan`.  ``floor`` bounds the estimate from below as a
    fraction of the initially provisioned frame rate, so a lull can never
    replan to a zero-machine cluster.
    """

    interval: float
    profiles: "Mapping[str, ModuleProfile] | None" = None
    window: "float | None" = None
    margin: float = 0.1
    forecast: bool = True
    tolerance: float = 0.02
    cost_guard: float = 0.01
    floor: float = 0.3

    def __post_init__(self):
        if self.interval <= 0.0:
            raise ValueError("control interval must be positive")
        if self.window is not None and self.window <= 0.0:
            raise ValueError("estimation window must be positive")
        if self.margin < 0.0:
            raise ValueError("margin must be >= 0")
        if not 0.0 < self.floor <= 1.0:
            raise ValueError("floor must be in (0, 1]")


@dataclass(frozen=True)
class EpochRecord:
    """One control-loop epoch, auditable: what was observed, what was done."""

    t: float                     # epoch boundary (simulated seconds)
    rate_est: float              # windowed offered frame-rate estimate
    target: float                # provisioned frame rate = est * (1 + margin)
    version: int                 # plan version active from t on
    cost: float                  # that plan's cost (serving-cost integrand)
    feasible: bool               # False: replan failed, previous plan kept
    swapped: bool                # True: a non-empty delta was applied
    actions: Mapping[str, str]   # per-module replan provenance
    machines_added: float = 0.0
    machines_drained: float = 0.0
    delta_summary: str = ""


def plan_e2e_hint(plan: Plan) -> float:
    """A finite, positive latency estimate for ``plan`` (SLO fallback).

    Used as the base for closed-loop clients' live retry backoff — shared
    by the engine (control off) and :attr:`ControlRuntime.e2e_hint` so the
    two paths can never diverge.
    """
    e = plan.e2e_latency
    if math.isfinite(e) and e > 0.0:
        return e
    return max(plan.workload.slo, 1e-3)


def serving_cost(history: Sequence[EpochRecord], horizon: float) -> float:
    """Time-averaged serving cost over ``[history[0].t, horizon]``.

    The active plan's cost integrates piecewise-constantly between epochs —
    the honest trajectory metric a periodic replanner is buying down
    against a static peak plan's flat ``cost * horizon``.
    """
    if not history:
        return math.nan
    total = 0.0
    for rec, t_next in zip(
        history, [r.t for r in history[1:]] + [max(horizon, history[-1].t)]
    ):
        total += rec.cost * max(0.0, t_next - rec.t)
    span = max(horizon, history[-1].t) - history[0].t
    return total / span if span > 0 else history[-1].cost


class ControlRuntime:
    """The live control plane driven by the pipelined event loop.

    The loop calls :meth:`observe` for every offered frame and
    :meth:`on_epoch` at each ``_K_EPOCH`` event; the runtime returns the
    per-stage :class:`StageUpdate` mapping to apply (or ``None`` when the
    replanned schedule is unchanged / infeasible).  ``timeout_of`` resolves
    a new schedule's flush deadlines exactly like the engine resolved the
    initial ones, so swapped-in machines inherit the same ``"budget"``
    semantics (per-rank remaining-workload floors included).
    """

    def __init__(
        self,
        cfg: ControlLoopConfig,
        plan: Plan,
        profiles: Mapping[str, ModuleProfile],
        frame_rate: float,
        *,
        timeout_of: Callable[[object, "list[Machine]", Plan], "float | None | dict"],
        dummies: bool = False,
        admission: "AdmissionController | None" = None,
    ):
        if frame_rate <= 0.0:
            raise ValueError("frame_rate must be positive")
        self.cfg = cfg
        self.planner = Planner(plan.options)
        self.plan = plan
        self.profiles = profiles
        self.frame_rate0 = frame_rate
        wl = plan.workload
        self.fanouts = {m: wl.rates[m] / frame_rate for m in wl.app.modules}
        self.timeout_of = timeout_of
        self.dummies = dummies
        self.admission = admission
        self._issues: deque[float] = deque()
        self.history: list[EpochRecord] = [
            EpochRecord(
                t=0.0,
                rate_est=frame_rate,
                target=frame_rate,
                version=plan.version,
                cost=plan.cost,
                feasible=plan.feasible,
                swapped=False,
                actions=dict(plan.provenance),
            )
        ]

    @property
    def interval(self) -> float:
        return self.cfg.interval

    @property
    def e2e_hint(self) -> float:
        """The live plan's modeled end-to-end latency (clients' backoff base)."""
        return plan_e2e_hint(self.plan)

    def observe(self, t: float) -> None:
        self._issues.append(t)

    def on_epoch(self, t: float) -> "dict[str, StageUpdate] | None":
        """Estimate, replan, and emit the stage updates for epoch ``t``."""
        cfg = self.cfg
        if cfg.window is not None:
            window = cfg.window
        else:
            # the trend extrapolation differentiates the window's two
            # halves, amplifying their Poisson counting noise by the
            # extrapolation distance over the half width — a multi-interval
            # window keeps that below the provisioning margin
            window = cfg.interval * (4.0 if cfg.forecast else 1.0)
        # clamp to the elapsed run: the span before t=0 holds no
        # observations, and treating it as an empty half-window would read
        # a perfectly steady start-up as a 2x ramp
        window = min(window, t) if t > 0.0 else window
        dq = self._issues
        while dq and dq[0] < t - window:
            dq.popleft()
        if cfg.forecast and window > 0.0:
            # trend-aware estimate: rate over each half-window gives the
            # slope; extrapolate from the recent half's center through the
            # coming epoch so a ramp is provisioned at its arrival, not at
            # its observation
            half = window / 2.0
            n2 = sum(1 for x in dq if x >= t - half)
            r2 = n2 / half
            r1 = (len(dq) - n2) / half
            est = r2 + (r2 - r1) / half * (0.5 * half + cfg.interval)
        else:
            est = len(dq) / max(window, cfg.interval)
        est = max(est, cfg.floor * self.frame_rate0)
        target = est * (1.0 + cfg.margin)
        new_rates = {m: target * f for m, f in self.fanouts.items()}
        new_plan = self.planner.replan(
            self.plan,
            new_rates,
            self.profiles,
            tolerance=cfg.tolerance,
            cost_guard=cfg.cost_guard,
        )
        if not new_plan.feasible:
            # keep serving on the previous plan; the failed epoch is recorded
            self.history.append(
                EpochRecord(
                    t=t, rate_est=est, target=target,
                    version=self.plan.version, cost=self.plan.cost,
                    feasible=False, swapped=False,
                    actions=dict(new_plan.provenance),
                )
            )
            return None
        delta = self.plan.diff(new_plan)
        self.plan = new_plan
        updates: dict[str, StageUpdate] = {}
        for m in delta.changed_modules:
            s = new_plan.schedules[m]
            if not s.allocs:
                continue  # never swap a stage down to zero machines
            machines = expand_machines(list(s.allocs))
            updates[m] = StageUpdate(
                machines=machines,
                timeout=self.timeout_of(s, machines, new_plan),
                phantom_target=(
                    sum(a.rate + a.dummy for a in s.allocs) if self.dummies else 0.0
                ),
            )
        if self.admission is not None:
            # admission policies bound to the provisioned rate follow the
            # epoch's plan instead of the run-constant initial rate
            self.admission.rebind(target)
        self.history.append(
            EpochRecord(
                t=t, rate_est=est, target=target,
                version=new_plan.version, cost=new_plan.cost,
                feasible=True, swapped=bool(updates),
                actions=dict(new_plan.provenance),
                machines_added=sum(
                    d.machines_added for d in delta.modules.values()
                ),
                machines_drained=sum(
                    d.machines_drained for d in delta.modules.values()
                ),
                delta_summary=delta.summary() if updates else "",
            )
        )
        return updates or None
