"""Unified event-driven serving subsystem.

Layers, ingress to silicon:

* ``arrivals``  — seeded open-loop arrival processes (uniform / poisson /
  bursty MMPP / diurnal trace).
* ``frontend``  — the overload-aware serving frontend: dummy-request
  streaming (the plan's priced phantom traffic joins batch formation, never
  the statistics), admission control (token-bucket / queue-depth shedding at
  ingress, per-app policies), and closed-loop clients (bounded in-flight
  frames, jittered retry-on-shed) as an alternative to open-loop arrivals.
* ``events``    — priority-queue discrete-event core with real tail-batch
  deadline semantics; reference implementation, supports real executors;
  its per-machine ``MachineCore`` is the composable stage brick.
* ``replay``    — numpy-vectorized per-machine replay kernel (the hot path),
  property-tested against the event core.
* ``engine``    — DAG-level adapter executing a Harpagon ``Plan`` over a
  frame stream (fanout expansion, per-module dispatch, e2e accounting).
* ``pipeline``  — multi-module pipelined co-simulation: frames traverse the
  DAG as tracked entities, downstream ingress fed by upstream batch
  completions, bounded queues exert backpressure, per-frame fanout can be
  stochastic and sibling-correlated, clients/admission live inside the
  event loop.  Selected via ``ServingEngine.run(pipeline=True)``.
* ``control``   — the incremental control plane (pipeline mode only):
  windowed trend-forecast rate estimation, warm-start ``Planner.replan``
  at every epoch, and hot-swap of the resulting ``PlanDelta`` onto the
  live stages without dropping in-flight frames.  Selected via
  ``ServingEngine.run(pipeline=True, control=ControlLoopConfig(...))``;
  the per-epoch audit trail is returned as ``ServeResult.epochs``.
* ``service_time`` — pluggable batch service durations: ``analytic``
  (profiled constant, bit-exact default), ``trace`` (recorded samples,
  deterministic replay), ``live`` (real executors timed per batch).
  Selected via ``ServingEngine.run(service_time=...)``; with a control
  loop, observed durations correct the profiles epochs replan against.
* ``observability`` — the passive telemetry layer: a structured trace
  recorder (ring-buffered, deterministically sampled, Perfetto-exportable),
  a per-epoch metrics registry (occupancy / dummy fill / stalls /
  utilization per module), and SLO-miss forensics (every missed or shed
  frame classified into exactly one cause, conservation-checked).
  Selected via ``ServingEngine.run(observability=True)`` (or an
  ``ObservabilityConfig``); results are bit-identical with it on or off.
* ``faults``    — seeded deterministic fault injection (machine crash,
  transient straggler, whole-device loss) firing as events inside the
  pipelined loop, with watchdog-based detection (suspect → dead on missed
  batch heartbeats), frame-conserving re-queue recovery, out-of-band
  failure replans with warm-spare promotion, and allocator repacks on
  shared-device death.  Selected via ``ServingEngine.run(pipeline=True,
  faults=FaultConfig(...))``; disabled ⇒ bit-exact with the fault-free
  engine.
* ``tenancy``   — the multi-tenant shared pool: a device-centric plan view
  (`DevicePlan`), a global allocator FFD-packing fractional module residues
  onto shared devices under an interference-aware e2e-SLO guard, and
  `SharedPool` running every app on one consolidated pool with co-located
  batches honestly slowed by a calibrated interference model.
* ``simulator`` — module-level Theorem-1 validation harness.
* ``reference`` — the frozen seed loops (golden equivalence baselines).

Frontend usage sketch::

    from repro.serving import ServingEngine
    from repro.serving.frontend import (
        ClosedLoopClients, FrontendConfig, TokenBucket,
    )

    # stream dummy traffic so a dummy-padded plan meets its modeled WCL
    fe = FrontendConfig(dummies=True)
    ServingEngine(plan).run(2000, rate, timeout="budget", frontend=fe)

    # shed at ingress under MMPP overload: bounded p99, reported shed rate
    fe = FrontendConfig(admission=TokenBucket(burst=4))
    r = ServingEngine(plan).run(
        2000, rate, arrivals="mmpp", offered_rate=1.3 * rate, frontend=fe
    )
    r.shed, r.attainment, r.p99   # shed frames count as SLO misses

    # closed-loop clients: offered load self-throttles under overload
    fe = FrontendConfig(clients=ClosedLoopClients(n_clients=16, retry_on_shed=True))
    ServingEngine(plan).run(2000, rate, frontend=fe)

The default path (no frontend, open-loop arrivals, ``timeout=None``)
reproduces the seed engine numbers exactly (`tests/test_golden_equivalence`).
"""
from .arrivals import (
    ARRIVALS,
    make_arrivals,
    mmpp_arrivals,
    poisson_arrivals,
    trace_arrivals,
    uniform_arrivals,
)
from .control import ControlLoopConfig, ControlRuntime, EpochRecord, serving_cost
from .engine import ModuleStats, ServeResult, ServingEngine
from .events import simulate_module_events
from .faults import FAULT_KINDS, FaultConfig, FaultRuntime
from .frontend import (
    ClosedLoopClients,
    FrontendConfig,
    QueueDepth,
    TokenBucket,
    make_admission,
)
from .observability import (
    MISS_CAUSES,
    MetricsSnapshot,
    MissReport,
    Observability,
    ObservabilityConfig,
    TraceRecorder,
    classify_misses,
)
from .pipeline import FanoutSpec, PipelineConfig, PipelineResult
from .replay import ModuleReplay, expand_fanout, replay_machine, replay_module
from .reference import engine_run_reference, simulate_reference
from .service_time import (
    AnalyticServiceTime,
    DegradedServiceTime,
    InterferenceServiceTime,
    LiveServiceTime,
    ServiceTimeSource,
    TraceServiceTime,
    resolve_service_time,
)
from .simulator import SimResult, simulate
from .tenancy import (
    DevicePlan,
    GlobalAllocator,
    PoolResult,
    SharedPool,
    TenancyConfig,
)

__all__ = [
    "ARRIVALS",
    "AnalyticServiceTime",
    "ClosedLoopClients",
    "ControlLoopConfig",
    "ControlRuntime",
    "DegradedServiceTime",
    "EpochRecord",
    "FanoutSpec",
    "FAULT_KINDS",
    "FaultConfig",
    "FaultRuntime",
    "DevicePlan",
    "FrontendConfig",
    "GlobalAllocator",
    "InterferenceServiceTime",
    "LiveServiceTime",
    "MISS_CAUSES",
    "MetricsSnapshot",
    "MissReport",
    "ModuleReplay",
    "Observability",
    "ObservabilityConfig",
    "PipelineConfig",
    "PipelineResult",
    "ModuleStats",
    "PoolResult",
    "QueueDepth",
    "ServeResult",
    "ServiceTimeSource",
    "ServingEngine",
    "SharedPool",
    "SimResult",
    "TenancyConfig",
    "TokenBucket",
    "TraceRecorder",
    "TraceServiceTime",
    "classify_misses",
    "engine_run_reference",
    "expand_fanout",
    "make_admission",
    "make_arrivals",
    "mmpp_arrivals",
    "poisson_arrivals",
    "replay_machine",
    "replay_module",
    "resolve_service_time",
    "serving_cost",
    "simulate",
    "simulate_module_events",
    "simulate_reference",
    "trace_arrivals",
    "uniform_arrivals",
]
