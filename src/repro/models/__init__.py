from .model import Model, ModelOutput, segmentize
from .moe import MoEMeshInfo

__all__ = ["Model", "ModelOutput", "MoEMeshInfo", "segmentize"]
