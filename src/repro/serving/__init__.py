from .arrivals import (
    ARRIVALS,
    make_arrivals,
    mmpp_arrivals,
    poisson_arrivals,
    trace_arrivals,
    uniform_arrivals,
)
from .engine import ModuleStats, ServeResult, ServingEngine
from .events import simulate_module_events
from .replay import ModuleReplay, expand_fanout, replay_machine, replay_module
from .reference import engine_run_reference, simulate_reference
from .simulator import SimResult, simulate

__all__ = [
    "ARRIVALS",
    "ModuleReplay",
    "ModuleStats",
    "ServeResult",
    "ServingEngine",
    "SimResult",
    "engine_run_reference",
    "expand_fanout",
    "make_arrivals",
    "mmpp_arrivals",
    "poisson_arrivals",
    "replay_machine",
    "replay_module",
    "simulate",
    "simulate_module_events",
    "simulate_reference",
    "trace_arrivals",
    "uniform_arrivals",
]
