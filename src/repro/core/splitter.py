"""Latency splitting: Algorithm 2 (latency-cost efficiency) + optimizers + baselines.

Paper Sec. III-D.  During splitting each module is represented by a single
*split configuration* ``c``; its fractional-packing cost is
``C_M(c) = p_c * T_M / t_c`` and its latency contribution is
``GetWCL(c) = d + b / T_M`` under TC dispatch (the whole module rate is the
batch-collection rate for the majority machines).

Splitters implemented:

* ``split_lc``          — Algorithm 2: greedy max latency-cost efficiency
                          ``LC = dCost / dL_wc``; optional *node merger*
                          (sibling joint upgrades) and *cost-direct* (re-do
                          the last R iterations greedily by raw cost delta).
* ``split_throughput``  — Scrooge/InferLine-style: greedy by throughput.
* ``split_even``        — Clipper-style: ``L / depth`` per module.
* ``split_quantized``   — Nexus-style: exact DP over a discretized budget
                          grid on the SP tree (interval ``q``).
* ``split_dp``          — exact quantized-budget DP over the app DAG with the
                          *full* module scheduler as the cost oracle (the
                          brute-force optimum at the splitting level; see
                          `repro.core.bruteforce`).

Each returns ``{module: budget}`` — the per-module latency budget handed to
the module scheduler — and is feasible by construction
(``critical-path latency <= SLO``) or ``None`` when even the least-demanding
configuration cannot meet the SLO.

The greedy splitters run on an array-backed state (`_VecState`) by default:
module rates are fixed during splitting, so every config's split WCL and
fractional-packing cost is precomputed once per module with the batched WCL
kernel, and candidate selection walks a descending sort instead of probing
every candidate's end-to-end latency.  ``vectorized=False`` selects the
scalar reference implementation (`_State`) — the bit-exactness oracle.
"""
from __future__ import annotations

import math
from typing import Mapping

import numpy as np

from .dag import AppDAG, Leaf, Par, Series, SP, Workload
from .dispatch import Policy, config_arrays
from .profiles import Config, ModuleProfile
from .scheduler import get_wcl, get_wcl_batch

_EPS = 1e-9
INF = math.inf


def split_cost(c: Config, T: float) -> float:
    """Fractional-packing cost of carrying rate T entirely on configuration c."""
    return c.unit_price * T / c.throughput


def split_wcl(c: Config, T: float, policy: Policy) -> float:
    """Module-level L_wc when the whole rate T rides configuration c
    (fractional-packing view: the tail machine is ignored)."""
    return get_wcl(c, policy, T, full=T >= c.throughput - _EPS)


def split_wcl_integer(c: Config, T: float, policy: Policy) -> float:
    """Integer-aware L_wc: accounts for the fractional tail machine, which
    either collects at its own small rate or is dummy-filled to a full
    machine (L_wc = 2d).  Budgets derived from this are schedulable by
    construction (the single-config integer cover fits)."""
    t = c.throughput
    if T < t - _EPS:
        # single partial machine — or dummy-filled if collection is too slow
        return min(get_wcl(c, policy, T, full=False), get_wcl(c, policy, t, full=True))
    full = get_wcl(c, policy, T, full=True)
    tail = T - math.floor(T / t + 1e-12) * t
    if tail <= _EPS:
        return full
    tail_wcl = min(
        get_wcl(c, policy, tail, full=False), get_wcl(c, policy, t, full=True)
    )
    return max(full, tail_wcl)


class _State:
    """Mutable Algorithm-2 state: one split config per module."""

    def __init__(
        self,
        wl: Workload,
        profiles: Mapping[str, ModuleProfile],
        policy: Policy,
        *,
        integer_tails: bool = False,
    ):
        self.wl = wl
        self.profiles = profiles
        self.policy = policy
        self.integer_tails = integer_tails
        self._wcl_fn = split_wcl_integer if integer_tails else split_wcl
        # Start at the least cost-efficient / lowest-latency configuration
        # (paper: batch 1 on the priciest hardware).  We pick the minimum-WCL
        # config (tie: highest unit price) so that the start is feasible
        # whenever any single-config assignment is.
        self.cfg: dict[str, Config] = {
            m: min(
                profiles[m].configs,
                key=lambda c: (self._wcl_fn(c, wl.rates[m], policy), -c.unit_price),
            )
            for m in wl.app.modules
        }

    def wcl(self, m: str, c: Config | None = None) -> float:
        return self._wcl_fn(c or self.cfg[m], self.wl.rates[m], self.policy)

    def cost(self, m: str, c: Config | None = None) -> float:
        return split_cost(c or self.cfg[m], self.wl.rates[m])

    def e2e(self, override: Mapping[str, Config] | None = None) -> float:
        def w(m: str) -> float:
            c = override.get(m) if override else None
            return self.wcl(m, c or self.cfg[m])

        return self.wl.app.latency({m: w(m) for m in self.wl.app.modules})

    def total_cost(self) -> float:
        return sum(self.cost(m) for m in self.wl.app.modules)

    def feasible(self) -> bool:
        return self.e2e() <= self.wl.slo + _EPS

    def budgets(self) -> dict[str, float]:
        return {m: self.wcl(m) for m in self.wl.app.modules}


def _candidates(st: _State, m: str) -> list[tuple[float, float, Config]]:
    """Cost-reducing upgrade candidates for module m: (dcost, dlat, config)."""
    out = []
    prev = st.cfg[m]
    c_prev, l_prev = st.cost(m), st.wcl(m)
    for c in st.profiles[m].configs:
        if c == prev:
            continue
        dcost = c_prev - st.cost(m, c)
        if dcost <= 1e-12:
            continue
        dlat = st.wcl(m, c) - l_prev
        out.append((dcost, dlat, c))
    return out


def _lc(dcost: float, dlat: float) -> float:
    """Latency-cost efficiency; free (non-latency-increasing) moves rank first."""
    return INF if dlat <= _EPS else dcost / dlat


# ---------------------------------------------------------------------------
# Vectorized Algorithm-2 machinery.
# ---------------------------------------------------------------------------


def _split_wcl_arr(arrs, T: float, policy: Policy) -> np.ndarray:
    """Elementwise `split_wcl` over a config table."""
    full = T >= arrs.throughput - _EPS
    return get_wcl_batch(arrs, policy, T, full=full)


def _split_wcl_integer_arr(arrs, T: float, policy: Policy) -> np.ndarray:
    """Elementwise `split_wcl_integer` over a config table."""
    t = arrs.throughput
    w_t_full = get_wcl_batch(arrs, policy, t, full=True)
    w_T_full = get_wcl_batch(arrs, policy, T, full=True)
    w_T_part = get_wcl_batch(arrs, policy, T, full=False)
    tail = T - np.floor(T / t + 1e-12) * t
    tail_wcl = np.minimum(get_wcl_batch(arrs, policy, tail, full=False), w_t_full)
    integer = np.where(tail <= _EPS, w_T_full, np.maximum(w_T_full, tail_wcl))
    return np.where(T < t - _EPS, np.minimum(w_T_part, w_t_full), integer)


# (wcl, cost) arrays per (config table, rate, policy, tail model), id-keyed
# like `dispatch.config_arrays` (the stored configs tuple keeps the id
# alive).  Rates are fixed during splitting and repeat across the planner's
# cascade tiers, so the arrays amortize across `_VecState` constructions.
_SPLIT_ARRAYS_CACHE: dict = {}


def _split_arrays(
    configs, T: float, policy: Policy, integer_tails: bool
) -> "tuple[np.ndarray, np.ndarray]":
    key = (id(configs), T, policy, integer_tails)
    hit = _SPLIT_ARRAYS_CACHE.get(key)
    if hit is not None and hit[0] is configs:
        return hit[1], hit[2]
    arrs = config_arrays(configs)
    wcl = (
        _split_wcl_integer_arr(arrs, T, policy)
        if integer_tails
        else _split_wcl_arr(arrs, T, policy)
    )
    cost = arrs.unit_price * T / arrs.throughput
    if len(_SPLIT_ARRAYS_CACHE) > 8192:
        _SPLIT_ARRAYS_CACHE.clear()
    _SPLIT_ARRAYS_CACHE[key] = (configs, wcl, cost)
    return wcl, cost


class _VecState:
    """Array-backed Algorithm-2 state (the vectorized `_State`).

    Every config's split WCL / fractional-packing cost is precomputed per
    module (rates are fixed during splitting), the current pick is tracked
    by config *index*, and the per-module WCL map is maintained
    incrementally so an e2e probe is one `AppDAG.latency` walk.  Candidate
    winners are found by walking a stable descending sort of the key
    (module order × config order on ties — the scalar loop's iteration
    order), stopping at the first e2e-feasible candidate: that is exactly
    the scalar argmax-with-strict-``>`` winner, but e2e probes are paid
    only until the first feasible candidate instead of per candidate.
    """

    __slots__ = (
        "wl", "profiles", "policy", "integer_tails", "modules", "wcl_arr",
        "cost_arr", "idx", "curw", "_sl", "g_lc", "g_dcost", "g_thr",
        "g_mid", "g_cid", "g_tie", "g_infeas",
    )

    def __init__(self, wl, profiles, policy, *, integer_tails=False, _src=None):
        if _src is not None:  # clone: share the immutable arrays
            self.wl, self.profiles, self.policy = _src.wl, _src.profiles, _src.policy
            self.integer_tails = _src.integer_tails
            self.modules = _src.modules
            self.wcl_arr, self.cost_arr = _src.wcl_arr, _src.cost_arr
            self._sl = _src._sl
            self.g_mid, self.g_cid, self.g_tie = _src.g_mid, _src.g_cid, _src.g_tie
            self.g_thr = _src.g_thr
            self.idx = dict(_src.idx)
            self.curw = dict(_src.curw)
            self.g_lc = _src.g_lc.copy()
            self.g_dcost = _src.g_dcost.copy()
            self.g_infeas = _src.g_infeas.copy()
            return
        self.wl, self.profiles, self.policy = wl, profiles, policy
        self.integer_tails = integer_tails
        self.modules = list(wl.app.modules)
        self.wcl_arr, self.cost_arr = {}, {}
        self.idx, self.curw, self._sl = {}, {}, {}
        off = 0
        mids: list[int] = []
        cids: list[int] = []
        thrs: list[np.ndarray] = []
        for mi, m in enumerate(self.modules):
            configs = profiles[m].configs
            w, c = _split_arrays(configs, wl.rates[m], policy, integer_tails)
            self.wcl_arr[m], self.cost_arr[m] = w, c
            price = config_arrays(configs).unit_price
            thrs.append(config_arrays(configs).throughput)
            n = len(configs)
            self._sl[m] = slice(off, off + n)
            mids.extend([mi] * n)
            cids.extend(range(n))
            off += n
            # start at min (wcl, -price): feasible whenever any single-config
            # assignment is (same tie order as the scalar min())
            i = int(np.lexsort((np.arange(n), -price, w))[0])
            self.idx[m] = i
            self.curw[m] = float(w[i])
        self.g_mid = np.array(mids, dtype=np.int64)
        self.g_cid = np.array(cids, dtype=np.int64)
        self.g_tie = np.arange(off)
        self.g_thr = np.concatenate(thrs) if thrs else np.empty(0)
        self.g_lc = np.empty(off)
        self.g_dcost = np.empty(off)
        self.g_infeas = np.zeros(off, dtype=bool)
        for m in self.modules:
            self._refresh(m)

    def clone(self) -> "_VecState":
        return _VecState(None, None, None, _src=self)

    def _refresh(self, m: str) -> None:
        """Recompute module m's candidate keys after its pick changed.

        Invalid candidates (non-cost-reducing, incl. the current pick at
        dcost 0) are encoded as -inf so they sort last; valid LC values are
        positive (or +inf for free moves), so -inf doubles as the walk's
        end-of-valid sentinel.
        """
        sl = self._sl[m]
        i = self.idx[m]
        ca, wa = self.cost_arr[m], self.wcl_arr[m]
        dcost = ca[i] - ca
        dlat = wa - wa[i]
        valid = dcost > 1e-12
        with np.errstate(divide="ignore", invalid="ignore"):
            lc = np.where(dlat <= _EPS, INF, dcost / dlat)
        self.g_lc[sl] = np.where(valid, lc, -INF)
        self.g_dcost[sl] = np.where(valid, dcost, -INF)

    def set_idx(self, m: str, i: int) -> None:
        old = self.curw[m]
        self.idx[m] = i
        w = float(self.wcl_arr[m][i])
        self.curw[m] = w
        if w < old:
            # A budget decreased: cached infeasibility verdicts (valid only
            # while every other module's WCL is >= when they were probed)
            # may be stale.  Drop them all.
            self.g_infeas[:] = False
        self._refresh(m)

    def cfg_of(self, m: str) -> Config:
        return self.profiles[m].configs[self.idx[m]]

    def e2e(self) -> float:
        return self.wl.app.latency(self.curw)

    def e2e_with(self, move: "Mapping[str, int]") -> float:
        w = dict(self.curw)
        for m, i in move.items():
            w[m] = float(self.wcl_arr[m][i])
        return self.wl.app.latency(w)

    def feasible(self) -> bool:
        return self.e2e() <= self.wl.slo + _EPS

    def total_cost(self) -> float:
        return sum(float(self.cost_arr[m][self.idx[m]]) for m in self.modules)

    def budgets(self) -> dict[str, float]:
        return dict(self.curw)

    def _walk(self, order: np.ndarray, keyarr: np.ndarray) -> int | None:
        """First e2e-feasible candidate in ``order`` (descending key); the
        -inf sentinel in ``keyarr`` marks where valid candidates end.

        Infeasible probes are cached in ``g_infeas``: a single-module move's
        e2e latency depends only on the *other* modules' WCLs (the move
        overrides its own), and `AppDAG.latency` is monotone in every leaf
        (sum/max compositions, monotone under IEEE-754 rounding too) — so
        once a move is infeasible it stays infeasible until some budget
        decreases (which clears the cache in `set_idx`).  This turns the
        per-step probe cost from O(rejected candidates) into amortized O(1).
        """
        slo = self.wl.slo
        for pos in order:
            p = int(pos)
            if keyarr[p] == -INF:
                return None
            if self.g_infeas[p]:
                continue
            m = self.modules[self.g_mid[p]]
            if self.e2e_with({m: int(self.g_cid[p])}) <= slo + _EPS:
                return p
            self.g_infeas[p] = True
        return None

    def step_lc(self, groups, history: list) -> bool:
        """One Algorithm-2 iteration: apply the max-(LC, dcost) feasible
        operation over single-module upgrades and sibling-group merges."""
        order = np.lexsort((self.g_tie, -self.g_dcost, -self.g_lc))
        best: "tuple[float, float, dict[str, int]] | None" = None
        p = self._walk(order, self.g_lc)
        if p is not None:
            m = self.modules[self.g_mid[p]]
            best = (float(self.g_lc[p]), float(self.g_dcost[p]), {m: int(self.g_cid[p])})
        for grp in groups:
            move: dict[str, int] = {}
            dcost_sum, dlat_max = 0.0, 0.0
            for m in grp:
                sl = self._sl[m]
                lc_m = self.g_lc[sl]
                j = int(np.argmax(lc_m))  # first-max tie == scalar max()
                if lc_m[j] == -INF:
                    continue
                move[m] = j
                dcost_sum += float(self.g_dcost[sl][j])
                dlat_max = max(dlat_max, float(self.wcl_arr[m][j]) - self.curw[m])
            if len(move) < 2:
                continue
            key = (_lc(dcost_sum, dlat_max), dcost_sum)
            if (best is None or key > (best[0], best[1])) and self.e2e_with(
                move
            ) <= self.wl.slo + _EPS:
                best = (key[0], dcost_sum, move)
        if best is None:
            return False
        history.append({m: (self.idx[m], i) for m, i in best[2].items()})
        for m, i in best[2].items():
            self.set_idx(m, i)
        return True

    def step_cost(self) -> bool:
        """One cost-direct iteration: apply the max-dcost feasible upgrade."""
        p = self._walk(np.lexsort((self.g_tie, -self.g_dcost)), self.g_dcost)
        if p is None:
            return False
        self.set_idx(self.modules[self.g_mid[p]], int(self.g_cid[p]))
        return True

    def step_throughput(self) -> bool:
        """One throughput-greedy iteration: max-(throughput, dcost) feasible."""
        thr = np.where(self.g_dcost == -INF, -INF, self.g_thr)
        p = self._walk(np.lexsort((self.g_tie, -self.g_dcost, -thr)), thr)
        if p is None:
            return False
        self.set_idx(self.modules[self.g_mid[p]], int(self.g_cid[p]))
        return True


def split_lc(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    *,
    node_merge: bool = True,
    cost_direct: bool = True,
    cost_direct_r: tuple[int, ...] = (1, 2, 3),
    integer_tails: bool = False,
    vectorized: bool = True,
) -> dict[str, float] | None:
    """Algorithm 2 + node merger + cost-direct.  Returns per-module budgets."""
    if vectorized:
        return _split_lc_vec(
            wl, profiles, policy, node_merge=node_merge, cost_direct=cost_direct,
            cost_direct_r=cost_direct_r, integer_tails=integer_tails,
        )
    st = _State(wl, profiles, policy, integer_tails=integer_tails)
    if not st.feasible():
        return None
    groups = wl.app.sibling_groups() if node_merge else []
    history: list[dict[str, tuple[Config, Config]]] = []

    def step_lc() -> bool:
        """One Algorithm-2 iteration: apply the max-LC feasible operation."""
        best: tuple[float, float, dict[str, Config]] | None = None  # (lc, dcost, move)
        for m in wl.app.modules:
            for dcost, dlat, c in _candidates(st, m):
                move = {m: c}
                key = (_lc(dcost, dlat), dcost)
                if (best is None or key > (best[0], best[1])) and st.e2e(move) <= wl.slo + _EPS:
                    best = (key[0], dcost, move)
        # node merger: joint upgrade of sibling groups, LC summed
        for grp in groups:
            move: dict[str, Config] = {}
            dcost_sum, dlat_max = 0.0, 0.0
            for m in grp:
                cands = _candidates(st, m)
                if not cands:
                    continue
                dcost, dlat, c = max(cands, key=lambda x: _lc(x[0], x[1]))
                move[m] = c
                dcost_sum += dcost
                dlat_max = max(dlat_max, dlat)
            if len(move) < 2:
                continue
            key = (_lc(dcost_sum, dlat_max), dcost_sum)
            if (best is None or key > (best[0], best[1])) and st.e2e(move) <= wl.slo + _EPS:
                best = (key[0], dcost_sum, move)
        if best is None:
            return False
        record = {m: (st.cfg[m], c) for m, c in best[2].items()}
        st.cfg.update(best[2])
        history.append(record)
        return True

    while step_lc():
        pass

    if cost_direct and history:
        best_cfg = dict(st.cfg)
        best_cost = st.total_cost()
        for r in cost_direct_r:
            if r > len(history):
                continue
            # roll back the final r operations
            trial = _State(wl, profiles, policy, integer_tails=integer_tails)
            trial.cfg = dict(st.cfg)
            for record in reversed(history[-r:]):
                for m, (old, _new) in record.items():
                    trial.cfg[m] = old
            # greedy by raw cost delta
            while True:
                best_mv: tuple[float, dict[str, Config]] | None = None
                for m in wl.app.modules:
                    for dcost, _dlat, c in _candidates(trial, m):
                        if (best_mv is None or dcost > best_mv[0]) and trial.e2e(
                            {m: c}
                        ) <= wl.slo + _EPS:
                            best_mv = (dcost, {m: c})
                if best_mv is None:
                    break
                trial.cfg.update(best_mv[1])
            if trial.total_cost() < best_cost - 1e-12:
                best_cost = trial.total_cost()
                best_cfg = dict(trial.cfg)
        st.cfg = best_cfg

    return st.budgets()


def _split_lc_vec(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy,
    *,
    node_merge: bool,
    cost_direct: bool,
    cost_direct_r: tuple[int, ...],
    integer_tails: bool,
) -> dict[str, float] | None:
    """`split_lc` on the array-backed state: bit-identical budgets."""
    st = _VecState(wl, profiles, policy, integer_tails=integer_tails)
    if not st.feasible():
        return None
    groups = wl.app.sibling_groups() if node_merge else []
    history: list[dict[str, tuple[int, int]]] = []
    while st.step_lc(groups, history):
        pass
    if cost_direct and history:
        best_idx = dict(st.idx)
        best_cost = st.total_cost()
        for r in cost_direct_r:
            if r > len(history):
                continue
            # roll back the final r operations, then greedy by raw cost delta
            trial = st.clone()
            for record in reversed(history[-r:]):
                for m, (old_i, _new_i) in record.items():
                    trial.set_idx(m, old_i)
            while trial.step_cost():
                pass
            tc = trial.total_cost()
            if tc < best_cost - 1e-12:
                best_cost = tc
                best_idx = dict(trial.idx)
        for m, i in best_idx.items():
            if i != st.idx[m]:
                st.set_idx(m, i)
    return st.budgets()


def split_throughput(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    *,
    vectorized: bool = True,
) -> dict[str, float] | None:
    """Scrooge/InferLine-style: greedily upgrade whichever module gains the
    highest throughput, ignoring latency-budget efficiency."""
    if vectorized:
        st = _VecState(wl, profiles, policy)
        if not st.feasible():
            return None
        while st.step_throughput():
            pass
        return st.budgets()
    st = _State(wl, profiles, policy)
    if not st.feasible():
        return None
    while True:
        best: tuple[tuple[float, float], dict[str, Config]] | None = None
        for m in wl.app.modules:
            for dcost, _dlat, c in _candidates(st, m):
                key = (c.throughput, dcost)
                if (best is None or key > best[0]) and st.e2e({m: c}) <= wl.slo + _EPS:
                    best = (key, {m: c})
        if best is None:
            break
        st.cfg.update(best[1])
    return st.budgets()


def split_even(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.RR,
    *,
    integer_tails: bool = False,
    vectorized: bool = True,
) -> dict[str, float] | None:
    """Clipper-style: every module gets SLO / depth."""
    per = wl.slo / wl.app.depth
    budgets = {}
    if vectorized:
        for m in wl.app.modules:
            w, _cost = _split_arrays(
                profiles[m].configs, wl.rates[m], policy, integer_tails
            )
            if not bool((w <= per + _EPS).any()):
                return None
            budgets[m] = per
        return budgets
    wf = split_wcl_integer if integer_tails else split_wcl
    for m in wl.app.modules:
        feas = [
            c
            for c in profiles[m].configs
            if wf(c, wl.rates[m], policy) <= per + _EPS
        ]
        if not feas:
            return None
        budgets[m] = per
    return budgets


def _sp_quantized_dp(
    sp: SP, nq: int, q: float, cost_at: Mapping[str, list[float]]
) -> list[float]:
    """min-cost DP over the SP tree: dp[k] = min cost with latency <= k*q."""
    if isinstance(sp, Leaf):
        return cost_at[sp.name]
    if isinstance(sp, Series):
        dp = _sp_quantized_dp(sp.parts[0], nq, q, cost_at)
        for p in sp.parts[1:]:
            nxt = _sp_quantized_dp(p, nq, q, cost_at)
            out = [INF] * (nq + 1)
            # dp and nxt are monotone non-increasing in k; combine minimally.
            for a in range(nq + 1):
                if dp[a] is INF:
                    continue
                for b in range(nq + 1 - a):
                    v = dp[a] + nxt[b]
                    if v < out[a + b]:
                        out[a + b] = v
            # prefix-min to enforce monotonicity
            for k in range(1, nq + 1):
                out[k] = min(out[k], out[k - 1])
            dp = out
        return dp
    # Par: same budget for every branch
    parts = [_sp_quantized_dp(p, nq, q, cost_at) for p in sp.parts]
    return [sum(p[k] for p in parts) for k in range(nq + 1)]


def _sp_quantized_assign(
    sp: SP, k: int, nq: int, q: float, cost_at: Mapping[str, list[float]]
) -> dict[str, float]:
    """Recover per-module budgets from the DP solution with total budget k*q."""
    if isinstance(sp, Leaf):
        return {sp.name: k * q}
    if isinstance(sp, Par):
        out: dict[str, float] = {}
        for p in sp.parts:
            out.update(_sp_quantized_assign(p, k, nq, q, cost_at))
        return out
    # Series: re-run the pairwise combination tracking the split point
    tails = [_sp_quantized_dp(Series(sp.parts[i:]), nq, q, cost_at) for i in range(len(sp.parts))]
    out = {}
    rem = k
    for i, p in enumerate(sp.parts):
        head = _sp_quantized_dp(p, nq, q, cost_at)
        if i == len(sp.parts) - 1:
            out.update(_sp_quantized_assign(p, rem, nq, q, cost_at))
            break
        tail = tails[i + 1]
        best_a, best_v = 0, INF
        for a in range(rem + 1):
            v = head[a] + tail[rem - a]
            if v < best_v - 1e-15:
                best_v, best_a = v, a
        out.update(_sp_quantized_assign(p, best_a, nq, q, cost_at))
        rem -= best_a
    return out


def split_quantized(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    q: float = 0.01,
    *,
    vectorized: bool = True,
) -> dict[str, float] | None:
    """Nexus-style: exact DP over budgets quantized to multiples of ``q``."""
    nq = int(wl.slo / q)
    if nq < 1:
        return None
    cost_at: dict[str, list[float]] = {}
    ks = np.arange(nq + 1) if vectorized else None
    for m in wl.app.modules:
        T = wl.rates[m]
        if vectorized:
            arrs = config_arrays(profiles[m].configs)
            lw = _split_wcl_arr(arrs, T, policy)
            cst = arrs.unit_price * T / arrs.throughput
            k0 = np.ceil(lw / q - 1e-9)
            per_arr = np.where(ks[:, None] >= k0[None, :], cst[None, :], INF).min(
                axis=1, initial=INF
            )
            # restore the INF singleton for the DP's identity fast path
            per = [v if v < INF else INF for v in per_arr.tolist()]
            cost_at[m] = per
            continue
        per = [INF] * (nq + 1)
        for c in profiles[m].configs:
            lw = split_wcl(c, T, policy)
            k0 = math.ceil(lw / q - 1e-9)
            if k0 > nq:
                continue
            cst = split_cost(c, T)
            for k in range(k0, nq + 1):
                if cst < per[k]:
                    per[k] = cst
        cost_at[m] = per
    dp = _sp_quantized_dp(wl.app.sp, nq, q, cost_at)
    if dp[nq] is INF or dp[nq] == INF:
        return None
    budgets = _sp_quantized_assign(wl.app.sp, nq, nq, q, cost_at)
    # guard: every module must have at least one feasible config at its budget
    for m, b in budgets.items():
        if cost_at[m][min(nq, int(b / q))] == INF:
            return None
    return budgets


def split_dp(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    *,
    n_grid: int = 240,
    use_dummy: bool = True,
) -> dict[str, float] | None:
    """Exact quantized-budget DP over the app DAG (the fifth splitter).

    Unlike `split_quantized`, whose per-budget cost model is the
    fractional-packing estimate of a *single* split configuration, the DP
    here prices every grid budget with the **full module scheduler**
    (Algorithm 1 + dummy generator) — the same curves `bruteforce.
    optimal_cost` composes, so the recovered budgets realize the
    brute-force optimum at the splitting level (state = (module, remaining
    budget), value = total serving cost; series = min-plus convolution,
    parallel = shared budget).

    Exactness caveats: the cost oracle runs at ``headroom=0``/``burst=0``
    (the paper's zero-slack semantics — matching ``optimal_cost``), and
    optimality is up to the ``slo / n_grid`` budget quantum.  At the
    default 240-point grid this derives the brute-force bound for the
    paper's 91.5%-style share of feasible workloads while staying ~10^3x
    cheaper than the paper's 35.9 s/workload exhaustive search.  Still far
    pricier than the greedy splitters, so the planner offers it as the
    selectable ``split="dp"`` tier, not part of the default cascade.
    """
    from .bruteforce import optimal_split  # local: keep module load cheap

    return optimal_split(
        wl, profiles, policy, n_grid=n_grid, use_dummy=use_dummy
    )
