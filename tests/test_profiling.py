"""Analytic profiler: param counts vs published sizes, profile shape sanity."""
import pytest

from repro.configs import ARCHS
from repro.profiling import (
    arch_profile,
    flops_per_token,
    kv_cache_bytes_per_token,
    module_duration,
    param_count,
)
from repro.profiling.hardware import CATALOG, TPU_V5E

# published total / active parameter counts (billions)
PUBLISHED = {
    "deepseek-v3-671b": (671, 37),
    "smollm-360m": (0.36, 0.36),
    "jamba-v0.1-52b": (52, 12),
    "gemma-7b": (8.5, 8.5),  # gemma-7b is 8.5B counting embeddings
    "gemma3-1b": (1.0, 1.0),
    "qwen2-moe-a2.7b": (14.3, 2.7),
    "qwen1.5-4b": (3.95, 3.95),
}


@pytest.mark.parametrize("arch,expect", sorted(PUBLISHED.items()))
def test_param_counts_match_published(arch, expect):
    total, active = expect
    n = param_count(ARCHS[arch]) / 1e9
    na = param_count(ARCHS[arch], active=True) / 1e9
    assert n == pytest.approx(total, rel=0.12), n
    assert na == pytest.approx(active, rel=0.15), na


def test_profiles_are_table1_shaped():
    """Throughput increases with batch; duration increases with batch."""
    for arch in ("smollm-360m", "gemma-7b", "qwen2-moe-a2.7b"):
        prof = arch_profile(ARCHS[arch])
        for hw in prof.hardware_names:
            rows = sorted(
                (c for c in prof.configs if c.hardware == hw), key=lambda c: c.batch
            )
            durs = [c.duration for c in rows]
            thr = [c.throughput for c in rows]
            assert all(a <= b + 1e-9 for a, b in zip(durs, durs[1:]))
            assert all(a <= b + 1e-6 for a, b in zip(thr, thr[1:]))


def test_duration_scales_with_model_size():
    small = module_duration(ARCHS["smollm-360m"], 8, 128, TPU_V5E)
    big = module_duration(ARCHS["gemma-7b"], 8, 128, TPU_V5E)
    assert big > 3 * small


def test_faster_hardware_is_faster():
    for arch in ("gemma3-1b", "qwen1.5-4b"):
        d_e = module_duration(ARCHS[arch], 8, 128, CATALOG["tpu-v5e"])
        d_p = module_duration(ARCHS[arch], 8, 128, CATALOG["tpu-v5p"])
        assert d_p < d_e


def test_kv_cache_bytes():
    # deepseek MLA: 576 bytes-ish per token per layer at bf16
    b = kv_cache_bytes_per_token(ARCHS["deepseek-v3-671b"])
    assert b == 61 * (512 + 64) * 2
    # xlstm: no per-token cache at all
    assert kv_cache_bytes_per_token(ARCHS["xlstm-125m"]) == 0.0
    # gemma3 MQA (kv=1) is ~16x lighter per layer than gemma-7b MHA (kv=16)
    assert kv_cache_bytes_per_token(ARCHS["gemma3-1b"]) < 0.07 * kv_cache_bytes_per_token(
        ARCHS["gemma-7b"]
    )


def test_flops_per_token_decode_vs_prefill():
    cfg = ARCHS["qwen1.5-4b"]
    # decode attends the full context, prefill averages ~S/2
    assert flops_per_token(cfg, 32768, decode=True) > flops_per_token(
        cfg, 32768, decode=False
    )
