"""Residual-workload optimization: dummy generator (Theorem 2) + latency reassigner.

Paper Sec. III-C.  Both act on a module's allocation set produced by
Algorithm 1 and are accepted only if they strictly reduce the module cost.

* Dummy generator: Theorem 2 shows the cost-minimum schedule has leftover
  workload ``u_i < t_i`` for every configuration ``c_i``.  Padding the rate by
  ``dum_i = t_i - u_i`` lets the leftover ride one more machine of the
  higher-ratio configuration ``c_i`` — cheaper despite serving phantom load.
* Latency reassigner: the latency gap left by the splitter/scheduler is handed
  to the *residual* workload (the majority configuration cannot benefit,
  otherwise Algorithm 1 would have chosen differently), re-running Algorithm 1
  on the residual with the enlarged budget.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .dispatch import Alloc, Policy, module_wcl, total_cost
from .profiles import ModuleProfile
from .scheduler import generate_config

_EPS = 1e-9


@dataclass(frozen=True)
class ModuleSchedule:
    """Final per-module scheduling result."""

    module: str
    rate: float          # real request rate
    dummy: float         # extra phantom rate added by the dummy generator
    budget: float        # latency budget the schedule was derived under
    allocs: tuple[Alloc, ...]
    policy: Policy

    @property
    def cost(self) -> float:
        return total_cost(list(self.allocs))

    @property
    def wcl(self) -> float:
        return module_wcl(list(self.allocs), self.policy)


def leftover_workloads(allocs: list[Alloc]) -> list[float]:
    """u_i = total rate assigned to strictly lower-ratio allocations."""
    out = []
    for i, a in enumerate(allocs):
        u = sum(x.rate for x in allocs if x.config.ratio < a.config.ratio - _EPS)
        out.append(u)
    return out


def apply_dummy(
    T: float,
    L: float,
    profile: ModuleProfile,
    allocs: list[Alloc],
    policy: Policy,
    *,
    headroom: float = 0.0,
    burst: float = 0.0,
    vectorized: bool = True,
) -> tuple[float, list[Alloc]]:
    """Try Theorem-2 dummy padding; returns (dummy_rate, allocs) of the best result."""
    best_cost = total_cost(allocs)
    best = (0.0, allocs)
    for a, u in zip(allocs, leftover_workloads(allocs)):
        t_i = a.cap  # per-machine assigned capacity (headroom-derated)
        dum = t_i - u
        if dum <= _EPS or u <= _EPS:
            continue  # nothing below this config, or already saturated
        ok, cand = generate_config(
            T + dum, L, profile, policy, headroom=headroom, burst=burst,
            vectorized=vectorized,
        )
        if ok and total_cost(cand) < best_cost - 1e-12:
            best_cost = total_cost(cand)
            best = (dum, cand)
    return best


def apply_reassign(
    T: float,
    L: float,
    extra: float,
    profile: ModuleProfile,
    allocs: list[Alloc],
    policy: Policy,
    *,
    headroom: float = 0.0,
    burst: float = 0.0,
    vectorized: bool = True,
) -> tuple[list[Alloc], float]:
    """Re-run Algorithm 1 on the residual workload with budget ``L + extra``.

    Keeps the majority allocation (the leading full-capacity group) fixed.
    Returns (allocs, latency_used_beyond_L) of the best cost-reducing result,
    or the input unchanged.
    """
    if extra <= _EPS or len(allocs) < 2 or not allocs[0].full:
        return allocs, 0.0
    majority = allocs[0]
    residual_rate = T - majority.rate
    if residual_rate <= _EPS:
        return allocs, 0.0
    base_cost = total_cost(allocs)
    ok, cand = generate_config(
        residual_rate, L + extra, profile, policy, headroom=headroom, burst=burst,
        vectorized=vectorized,
    )
    if not ok:
        return allocs, 0.0
    new_allocs = [majority] + cand
    if total_cost(new_allocs) >= base_cost - 1e-12:
        return allocs, 0.0
    new_wcl = module_wcl(new_allocs, policy)
    overshoot = max(0.0, new_wcl - L)
    return new_allocs, overshoot


def schedule_module(
    module: str,
    T: float,
    L: float,
    profile: ModuleProfile,
    policy: Policy = Policy.TC,
    *,
    use_dummy: bool = True,
    k_tuples: int | None = None,
    headroom: float = 0.0,
    burst: float = 0.0,
    vectorized: bool = True,
) -> ModuleSchedule | None:
    """Algorithm 1 (+ optional dummy generator) for one module.

    ``headroom`` (utilization slack, multi-tuple scheduler only) provisions
    machines at ``(1 - headroom) * throughput``; the k-tuple baselines ignore
    it (they model prior systems' zero-slack provisioning).
    """
    from .scheduler import generate_config_ktuple  # local: avoid cycle

    if k_tuples is None:
        ok, allocs = generate_config(
            T, L, profile, policy, headroom=headroom, burst=burst,
            vectorized=vectorized,
        )
    else:
        ok, allocs = generate_config_ktuple(
            T, L, profile, policy, k_tuples, vectorized=vectorized
        )
    if not ok:
        return None
    dummy = 0.0
    if use_dummy and k_tuples is None:
        dummy, allocs = apply_dummy(
            T, L, profile, allocs, policy, headroom=headroom, burst=burst,
            vectorized=vectorized,
        )
    return ModuleSchedule(module, T, dummy, L, tuple(allocs), policy)
