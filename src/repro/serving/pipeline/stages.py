"""Composable DAG stages: one module's machines behind a bounded ingress.

A :class:`ModuleStage` wraps the single-machine cores of
`repro.serving.events.MachineCore` into one DAG stage: an *incremental*
dispatcher assigns instances to machines in arrival order (the streaming
form of `core.dispatch.dispatch_runs` — the static run-length walk cannot be
precomputed because the pipelined arrival stream only exists as the
co-simulation unfolds), formation buffers fill/flush exactly like the
single-module reference core, and a bounded ingress backlog exerts
**backpressure**: when ``queue_cap`` instances are already waiting to start
service, further deliveries park FIFO and the *upstream machine that
produced them stays busy* until the stage drains — the cross-stage
interference Harpagon's per-module WCL sums cannot see.

The stage owns no event loop; `repro.serving.pipeline.core` drives every
stage of the app DAG from one global heap.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

from ...core.dispatch import Machine, Policy
from ..events import MachineCore


class Instance:
    """One module-level request of one frame (``frame == -1``: phantom)."""

    __slots__ = ("frame", "ready")

    def __init__(self, frame: int, ready: float = 0.0):
        self.frame = frame
        self.ready = ready

    @property
    def real(self) -> bool:
        return self.frame >= 0


class TCDispatcher:
    """Incremental weighted-fair batch walk (Harpagon TC dispatch).

    Machine *i* owns periodic run slots at ``k * b_i / f_i`` merged by
    ``(slot time, -ratio, index)``; consecutive arrivals fill the current
    run (one batch) before the walk advances — request-for-request identical
    to `core.dispatch.dispatch_runs(policy=TC)` on the same stream.
    """

    def __init__(self, machines: Sequence[Machine]):
        self.machines = list(machines)
        self._next_t = [0.0] * len(self.machines)
        self._cur = 0
        self._left = 0

    def assign(self) -> int:
        if self._left == 0:
            i = min(
                range(len(self.machines)),
                key=lambda j: (self._next_t[j], -self.machines[j].config.ratio, j),
            )
            self._cur = i
            m = self.machines[i]
            self._left = m.config.batch
            self._next_t[i] += m.config.batch / m.rate
        self._left -= 1
        return self.machines[self._cur].mid


class RRDispatcher:
    """Deficit-counter weighted round-robin of individual requests (RR/DT),
    request-for-request identical to `dispatch_runs` under those policies."""

    def __init__(self, machines: Sequence[Machine]):
        self.machines = list(machines)
        self._credit = [0.0] * len(self.machines)
        self._tot = sum(m.rate for m in self.machines)

    def assign(self) -> int:
        for i, m in enumerate(self.machines):
            self._credit[i] += m.rate / self._tot
        j = max(range(len(self.machines)), key=lambda i: self._credit[i])
        self._credit[j] -= 1.0
        return self.machines[j].mid


def make_dispatcher(machines: Sequence[Machine], policy: Policy):
    if policy is Policy.TC:
        return TCDispatcher(machines)
    return RRDispatcher(machines)


@dataclass
class StageStats:
    """Per-stage accounting, mirror of the engine's ``ModuleStats`` fields."""

    latencies: list[float] = field(default_factory=list)
    batches: int = 0
    dropped: int = 0
    phantom: int = 0


class ModuleStage:
    """One DAG module as a pipeline stage: dispatcher + cores + backlog.

    ``timeout`` is a single flush deadline or a per-machine-id mapping (the
    engine's ``"budget"`` resolution).  ``phantom_target`` > 0 streams the
    plan's priced phantom traffic *adaptively*: the stage pads batch
    formation up to that total collect rate (``sum(rate + dummy)``), so a
    phantom is injected only when real traffic has left a gap — the
    event-interleaved analogue of the flat frontend's pad-to-provisioned
    injector (`frontend.dummy.phantom_times`).  ``queue_cap`` bounds the
    number of instances waiting to start service; ``None`` means unbounded
    (no backpressure — the flat-engine regime).
    """

    def __init__(
        self,
        name: str,
        machines: Sequence[Machine],
        policy: Policy,
        *,
        timeout: "float | None | Mapping[int, float]" = None,
        fanout=None,
        phantom_target: float = 0.0,
        queue_cap: "int | None" = None,
    ):
        if queue_cap is not None and queue_cap < 1:
            raise ValueError("queue_cap must be >= 1 (or None for unbounded)")
        if queue_cap is not None:
            # formation buffers count toward the backlog, so a cap below the
            # largest batch size could never form a full batch: floor it
            queue_cap = max(queue_cap, max(m.config.batch for m in machines))
        if isinstance(timeout, Mapping):
            t_of = {m.mid: timeout.get(m.mid) for m in machines}
        else:
            t_of = {m.mid: timeout for m in machines}
        self.name = name
        self.machines = list(machines)
        self.cores = {m.mid: MachineCore(m, t_of[m.mid]) for m in machines}
        self.dispatcher = make_dispatcher(machines, policy)
        self.fanout = fanout
        self.phantom_target = float(phantom_target)
        # phantom pacing state: a phantom is due when `delivered` (real +
        # phantom arrivals since `anchor`) falls behind target * elapsed —
        # total collection is padded up to, and rate-limited at, the target
        self.anchor = 0.0
        self.delivered = 0
        # True while the injection chain is dormant (stage was full): a
        # dormant chain schedules no events, so a wedged pipeline can reach
        # quiescence and flush; the next successful delivery revives it
        self.phantom_paused = False
        self.queue_cap = queue_cap
        self.backlog = 0  # instances delivered but not yet started service
        # deliveries parked by backpressure: (instance, blocker) where
        # blocker is the (stage, mid) whose outputs they are, or None for
        # ingress arrivals (open-loop frames waiting at the source)
        self.parked: deque = deque()
        self.in_service: dict[int, list[Instance]] = {}
        self.stats = StageStats()

    # -- capacity ------------------------------------------------------------
    @property
    def has_space(self) -> bool:
        return self.queue_cap is None or self.backlog < self.queue_cap

    # -- formation / service -------------------------------------------------
    def deliver(self, inst: Instance, now: float, push: Callable) -> None:
        """Hand one instance to the dispatcher at time ``now``.

        ``push(t, kind, stage_name, payload)`` schedules flush/free events on
        the owner's heap.  Caller must have checked :attr:`has_space`.
        """
        inst.ready = now
        self.delivered += 1
        self.backlog += 1
        mid = self.dispatcher.assign()
        core = self.cores[mid]
        deadline = core.add(inst, now, inst.real)
        if deadline is not None:
            push(deadline, _K_FLUSH, self.name, (mid, core.token))
        if core.full:
            self.close(mid, batch_ready=now, now=now, push=push)

    def close(self, mid: int, batch_ready: float, now: float, push: Callable) -> None:
        self.cores[mid].close(batch_ready)
        self.start_next(mid, now, push)

    def start_next(self, mid: int, now: float, push: Callable) -> bool:
        """Start the next queued batch on ``mid`` (unless backpressured)."""
        core = self.cores[mid]
        started = core.start(now, lambda members: core.machine.config.duration)
        if started is None:
            return False
        end, members = started
        self.stats.batches += 1
        self.backlog -= len(members)
        self.in_service[mid] = members
        push(end, _K_FREE, self.name, (mid,))
        return True

    def discard_leftover(self, mid: int) -> list[Instance]:
        """End-of-stream drop of the open buffer; returns real instances."""
        all_members = self.cores[mid].discard()
        self.backlog -= len(all_members)
        dropped = [i for i in all_members if i.real]
        self.stats.dropped += len(dropped)
        return dropped


# event kinds of the pipeline's global heap (core.py re-exports): arrivals
# first (a request landing exactly at a deadline joins the batch), then
# machine-frees (upstream completions must deliver before a downstream flush
# at the same instant fires), then flushes.  FREE-before-FLUSH within one
# stage is outcome-equivalent to the single-module core's FLUSH-before-FREE
# (both orders start the same FIFO batch at the same time).
_K_ARRIVE, _K_FREE, _K_FLUSH = 0, 1, 2
