"""Pluggable request arrival processes for the serving simulator.

Every generator returns a sorted ``float64`` numpy array of ``n`` absolute
arrival times (seconds, starting near 0) with long-run mean rate ``rate``
req/s, and is deterministic under ``seed``.  Processes:

* ``uniform``  — evenly spaced arrivals ``i / rate`` (streaming-video regime,
  the paper's steady-state assumption behind Theorem 1).
* ``poisson``  — homogeneous Poisson process (exponential inter-arrivals).
* ``mmpp`` / ``bursty`` — 2-state Markov-modulated Poisson process: a calm
  state and a burst state whose intensity is ``burst``x higher, with
  exponentially distributed dwell times.  Long-run mean rate is ``rate``.
* ``diurnal`` — inhomogeneous Poisson with a sinusoidal day/night intensity
  profile (``trace_arrivals`` accepts any intensity profile, e.g. one read
  from a production trace).

The non-uniform processes are realized by time-rescaling a unit-rate Poisson
process through the inverse integrated intensity Λ⁻¹ — the standard
construction, vectorized with ``np.interp`` over the piecewise-linear Λ.
"""
from __future__ import annotations

from typing import Callable, Mapping, Sequence

import numpy as np


def uniform_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Evenly spaced arrivals at exactly ``rate`` req/s (seed ignored)."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    return np.arange(n, dtype=np.float64) / rate


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Homogeneous Poisson process: i.i.d. Exp(rate) inter-arrival times."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _rescale(unit_times: np.ndarray, seg_t: np.ndarray, seg_lam: np.ndarray) -> np.ndarray:
    """Map unit-rate Poisson event times through Λ⁻¹ of a piecewise-linear
    integrated intensity given by knots ``(seg_t, seg_lam)`` (both sorted)."""
    return np.interp(unit_times, seg_lam, seg_t)


def mmpp_arrivals(
    n: int,
    rate: float,
    seed: int = 0,
    *,
    burst: float = 8.0,
    frac_burst: float = 0.15,
    mean_dwell: float = 2.0,
) -> np.ndarray:
    """2-state MMPP: calm intensity ``r0`` and burst intensity ``burst * r0``.

    ``frac_burst`` is the long-run fraction of time spent in the burst state
    (so the stationary mean rate is exactly ``rate``); ``mean_dwell`` is the
    mean sojourn (seconds) of one calm+burst cycle.
    """
    if rate <= 0 or burst < 1.0 or not (0.0 < frac_burst < 1.0):
        raise ValueError("need rate>0, burst>=1, 0<frac_burst<1")
    if n == 0:
        return np.zeros(0)
    r0 = rate / (1.0 - frac_burst + frac_burst * burst)
    r1 = burst * r0
    t_calm = mean_dwell * (1.0 - frac_burst)
    t_burst = mean_dwell * frac_burst
    rng = np.random.default_rng(seed)
    unit = np.cumsum(rng.exponential(1.0, size=n))
    target = unit[-1]
    # build Λ knots over alternating calm/burst sojourns until Λ covers target
    knots_t = [0.0]
    knots_lam = [0.0]
    state = 0
    while knots_lam[-1] < target:
        dwell = rng.exponential(t_calm if state == 0 else t_burst)
        lam = r0 if state == 0 else r1
        knots_t.append(knots_t[-1] + dwell)
        knots_lam.append(knots_lam[-1] + dwell * lam)
        state ^= 1
    return _rescale(unit, np.asarray(knots_t), np.asarray(knots_lam))


def trace_arrivals(
    n: int,
    rate: float,
    seed: int = 0,
    *,
    profile: Callable[[np.ndarray], np.ndarray] | Sequence[float] | None = None,
    period: float = 60.0,
    grid: int = 4096,
) -> np.ndarray:
    """Inhomogeneous Poisson driven by a periodic relative-intensity profile.

    ``profile`` maps time (array, seconds) to relative intensity >= 0 — e.g.
    a diurnal curve or a replayed production trace; a sequence is treated as
    evenly spaced samples over one ``period`` and normalized to mean 1 so the
    long-run rate stays ``rate`` (a callable is trusted to have mean ~1; the
    default is a day/night sinusoid with mean exactly 1).
    """
    if n == 0:
        return np.zeros(0)
    if profile is None:
        profile = lambda t: 1.0 + 0.8 * np.sin(2.0 * np.pi * t / period)
    if not callable(profile):
        samples = np.asarray(profile, dtype=np.float64)
        if samples.size == 0 or np.any(samples < 0) or samples.mean() <= 0:
            raise ValueError("profile samples must be non-negative with positive mean")
        samples = samples / samples.mean()
        xs = np.linspace(0.0, period, samples.size, endpoint=False)
        profile = lambda t: np.interp(np.mod(t, period), xs, samples, period=period)
    rng = np.random.default_rng(seed)
    unit = np.cumsum(rng.exponential(1.0, size=n))
    target = unit[-1]
    # integrate rate * profile(t) on a fixed grid, extend until Λ covers target
    dt = period / grid
    knots_t = np.array([0.0])
    knots_lam = np.array([0.0])
    while knots_lam[-1] < target:
        t0 = knots_t[-1]
        ts = t0 + dt * np.arange(1, grid + 1)
        lam = rate * np.clip(profile(ts - 0.5 * dt), 0.0, None)
        knots_t = np.concatenate([knots_t, ts])
        knots_lam = np.concatenate([knots_lam, knots_lam[-1] + np.cumsum(lam * dt)])
    return _rescale(unit, knots_t, knots_lam)


ARRIVALS: Mapping[str, Callable[..., np.ndarray]] = {
    "uniform": uniform_arrivals,
    "poisson": poisson_arrivals,
    "mmpp": mmpp_arrivals,
    "bursty": mmpp_arrivals,
    "diurnal": trace_arrivals,
    "trace": trace_arrivals,
}


def make_arrivals(
    kind: "str | np.ndarray | Sequence[float]",
    n: int,
    rate: float,
    seed: int = 0,
    **kwargs,
) -> np.ndarray:
    """Resolve an arrival spec: a process name, or an explicit time array.

    An explicit array is validated (sorted, length ``n``) and passed through,
    letting callers replay recorded traces directly.
    """
    if isinstance(kind, str):
        try:
            fn = ARRIVALS[kind]
        except KeyError:
            raise ValueError(f"unknown arrival process {kind!r}; have {sorted(ARRIVALS)}")
        return fn(n, rate, seed, **kwargs)
    arr = np.asarray(kind, dtype=np.float64)
    if arr.ndim != 1 or arr.size != n:
        raise ValueError(f"explicit arrivals must be 1-D of length {n}")
    if np.any(np.diff(arr) < 0):
        raise ValueError("explicit arrivals must be sorted")
    return arr
