"""Mixture-of-Experts: dropless sort+ragged_dot local path and an
expert-parallel (EP) shard_map path with capacity-bounded all_to_all.

TPU adaptation notes (DESIGN.md Sec. 3): instead of a CUDA grouped-GEMM port
we sort tokens by expert and use ``jax.lax.ragged_dot`` (MXU-friendly grouped
matmul) for the local computation, and express expert parallelism as an
explicit shard_map: tokens sharded over the EP axes are routed to expert
owners with a single capacity-padded ``all_to_all`` each way — the TPU-native
analogue of the paper-ecosystem's NCCL all-to-all MoE dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import Params, _dense_init, dense, mlp_forward, mlp_init


@dataclasses.dataclass(frozen=True)
class MoEMeshInfo:
    """How experts and tokens are distributed.

    ``ep_axes`` are the mesh axes the expert dim is sharded over (the
    all_to_all group); ``token_axes`` are the axes tokens are sharded over —
    a superset when data-parallel replicas (e.g. the 'pod' axis) each run
    their own expert-parallel group.
    """

    ep_axes: tuple[str, ...]  # e.g. ('model',) or ('data', 'model')
    ep_size: int
    token_axes: tuple[str, ...] = ()  # defaults to ep_axes
    token_size: int = 0
    mesh: Any = None  # jax Mesh; None => caller is already inside shard_map
    all_axes: tuple[str, ...] = ()  # every mesh axis name (for aux pmean)

    def __post_init__(self):
        if not self.token_axes:
            object.__setattr__(self, "token_axes", self.ep_axes)
            object.__setattr__(self, "token_size", self.ep_size)


# --------------------------------------------------------------------- init
def moe_init(key, cfg: ArchConfig, dtype, ep: int = 1) -> Params:
    """Expert weights stored stacked: (E_pad, d, f).  E padded to EP multiple."""
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    E_pad = -(-E // ep) * ep
    ks = jax.random.split(key, 5)
    scale_in = d ** -0.5
    scale_out = f ** -0.5
    p: Params = {
        "router": _dense_init(ks[0], d, E_pad, dtype),
        "w1": (jax.random.normal(ks[1], (E_pad, d, f)) * scale_in).astype(dtype),
        "w3": (jax.random.normal(ks[2], (E_pad, d, f)) * scale_in).astype(dtype),
        "w2": (jax.random.normal(ks[3], (E_pad, f, d)) * scale_out).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, f * cfg.n_shared_experts, dtype)
    return p


def route(p: Params, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Router: top-k ids + renormalized gates + switch-style aux loss.

    x: (N, d) -> ids (N, k) int32, gates (N, k) f32, aux scalar.
    """
    E = cfg.n_experts
    logits = dense(p["router"], x).astype(jnp.float32)
    logits = logits[..., :E]  # drop padding experts
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance loss: E * sum_e (fraction routed to e) * (mean prob of e)
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(1)  # (N, E)
    frac = onehot.mean(0) / cfg.top_k
    aux = E * jnp.sum(frac * probs.mean(0))
    return ids, gates, aux


# ------------------------------------------------------------- local (dropless)
def expert_ffn_local(p: Params, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dropless MoE on one device: sort by expert, grouped matmul, unsort.

    x: (N, d) -> (N, d), aux loss.
    """
    N, d = x.shape
    k = cfg.top_k
    E_pad = p["w1"].shape[0]
    ids, gates, aux = route(p, cfg, x)
    flat_ids = ids.reshape(-1)  # (N*k,)
    order = jnp.argsort(flat_ids)
    token_of = order // k
    xs = x[token_of]  # (N*k, d) sorted by expert
    group_sizes = jnp.bincount(flat_ids, length=E_pad)
    h1 = jax.lax.ragged_dot(xs, p["w1"].astype(x.dtype), group_sizes)
    h3 = jax.lax.ragged_dot(xs, p["w3"].astype(x.dtype), group_sizes)
    act = jax.nn.silu(h1) if cfg.act == "silu" else jax.nn.gelu(h1)
    ys = jax.lax.ragged_dot(act * h3, p["w2"].astype(x.dtype), group_sizes)
    w = gates.reshape(-1)[order].astype(x.dtype)
    out = jnp.zeros((N, d), x.dtype).at[token_of].add(ys * w[:, None])
    return out, aux


# --------------------------------------------------------------- EP shard_map
def expert_ffn_ep(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    mesh_info: MoEMeshInfo,
) -> tuple[jax.Array, jax.Array]:
    """Per-device body (already inside shard_map): route local tokens to the
    expert owners over the flattened EP axes via capacity-padded all_to_all.

    x: (N_loc, d) local tokens.  Expert weights arrive sharded: (E_loc, d, f).
    """
    ep = mesh_info.ep_size
    axes = mesh_info.ep_axes
    N, d = x.shape
    k = cfg.top_k
    E_loc = p["w1"].shape[0]  # local experts per device
    cap = max(1, int(-(-N * k // ep) * cfg.moe_capacity_factor))

    ids, gates, aux = route(p, cfg, x)  # ids are GLOBAL expert ids
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    dest = flat_ids // E_loc  # owner device along EP
    order = jnp.argsort(dest)
    # slot within the destination bucket
    sorted_dest = dest[order]
    pos_in_bucket = jnp.arange(N * k) - jnp.searchsorted(
        sorted_dest, sorted_dest, side="left"
    )
    keep = pos_in_bucket < cap  # capacity drop
    # dropped entries go to a trash slot (ep*cap) that is sliced away
    slot = jnp.where(keep, sorted_dest * cap + pos_in_bucket, ep * cap)

    send_x = jnp.zeros((ep * cap + 1, d), x.dtype)
    send_eid = jnp.full((ep * cap + 1,), -1, jnp.int32)  # local expert id at dest
    send_src = jnp.full((ep * cap + 1,), -1, jnp.int32)  # flat (token*k) slot for return
    tok = order // k
    send_x = send_x.at[slot].set(x[tok])
    send_eid = send_eid.at[slot].set((flat_ids[order] % E_loc).astype(jnp.int32))
    send_src = send_src.at[slot].set(order.astype(jnp.int32))
    send_x, send_eid, send_src = send_x[:-1], send_eid[:-1], send_src[:-1]

    a2a = lambda t: jax.lax.all_to_all(
        t.reshape(ep, cap, *t.shape[1:]), axes, split_axis=0, concat_axis=0, tiled=False
    ).reshape(ep * cap, *t.shape[1:])
    recv_x = a2a(send_x)
    recv_eid = a2a(send_eid)

    # local grouped FFN over received tokens (invalid rows go to a trash group)
    eid = jnp.where(recv_eid < 0, E_loc, recv_eid)
    lorder = jnp.argsort(eid)
    xs = recv_x[lorder]
    group_sizes = jnp.bincount(eid, length=E_loc + 1)[:E_loc]
    # rows beyond sum(group_sizes) fall out of every group -> ragged_dot zeros
    h1 = jax.lax.ragged_dot(xs, p["w1"].astype(x.dtype), group_sizes)
    h3 = jax.lax.ragged_dot(xs, p["w3"].astype(x.dtype), group_sizes)
    act = jax.nn.silu(h1) if cfg.act == "silu" else jax.nn.gelu(h1)
    ys = jax.lax.ragged_dot(act * h3, p["w2"].astype(x.dtype), group_sizes)
    y = jnp.zeros_like(recv_x).at[lorder].set(ys)

    back = a2a(y)  # back to the source device, same slot order as send_x
    out = jnp.zeros((N, d), x.dtype)
    valid = send_src >= 0
    contrib = back * jnp.where(valid, flat_gates[send_src], 0.0)[:, None].astype(x.dtype)
    out = out.at[jnp.where(valid, send_src // k, 0)].add(
        jnp.where(valid[:, None], contrib, 0.0)
    )
    # aux loss averaged over the whole mesh (fully replicated output)
    aux = jax.lax.pmean(aux, mesh_info.all_axes or axes)  # fully replicated
    return out, aux


def moe_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # (B, S, d)
    *,
    mesh_info: MoEMeshInfo | None = None,
) -> tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    flat = x.reshape(B * S, d)
    if mesh_info is None:
        y, aux = expert_ffn_local(p, cfg, flat)
    elif mesh_info.mesh is None:
        y, aux = expert_ffn_ep(p, cfg, flat, mesh_info)
    else:
        y, aux = _moe_shard_mapped(p, cfg, flat, mesh_info)
    if "shared" in p:
        y = y + mlp_forward(p["shared"], flat, cfg.act)
    return y.reshape(B, S, d), aux


def _moe_shard_mapped(
    p: Params, cfg: ArchConfig, flat: jax.Array, info: MoEMeshInfo
) -> tuple[jax.Array, jax.Array]:
    """Wrap the EP body in shard_map over the full mesh.

    Tokens are sharded over the flattened EP axes; expert weights over their
    expert dim; the router is replicated.  Token counts that do not divide
    the EP degree (e.g. single-token decode) are zero-padded.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    N, d = flat.shape
    pad = (-N) % info.token_size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, d), flat.dtype)], 0)
    ep_t = info.ep_axes if len(info.ep_axes) > 1 else info.ep_axes[0]
    tok_t = info.token_axes if len(info.token_axes) > 1 else info.token_axes[0]
    p_ep = {k: p[k] for k in ("router", "w1", "w2", "w3")}
    in_specs = (
        {
            "router": P(None, None),
            "w1": P(ep_t, None, None),
            "w2": P(ep_t, None, None),
            "w3": P(ep_t, None, None),
        },
        P(tok_t, None),
    )
    body = lambda pp, xx: expert_ffn_ep(pp, cfg, xx, info)
    fn = shard_map(
        body,
        mesh=info.mesh,
        in_specs=in_specs,
        out_specs=(P(tok_t, None), P()),
        check_rep=False,
    )
    y, aux = fn(p_ep, flat)
    if pad:
        y = y[:N]
    return y, aux
