"""Multi-tenant shared pool: one device pool serving every app.

Three layers (see each module's docstring for the full story):

* :mod:`.device`    — the device-centric plan view (`DevicePlan` /
  `DeviceSlot`), derived from and diffable against the module-centric
  `core.harpagon.Plan`.
* :mod:`.allocator` — the `GlobalAllocator`: FFD bin-packing of
  fractional module residues onto shared devices with an end-to-end-SLO
  feasibility guard, plus the `submit` epoch-arbitration entry point.
* :mod:`.pool`      — `SharedPool`, the engine wiring: per-app serving
  loops with interference-inflated service times on co-located machines,
  hot-swapped device plans, and the consolidated-vs-dedicated ledger
  (`PoolResult`).
"""
from .allocator import AllocatorConfig, GlobalAllocator, dedicated_cost, plan_slots
from .device import (
    Device,
    DevicePlan,
    DevicePlanDelta,
    DeviceSlot,
    diff_device_plans,
)
from .pool import PoolResult, SharedPool, TenancyConfig

__all__ = [
    "AllocatorConfig",
    "Device",
    "DevicePlan",
    "DevicePlanDelta",
    "DeviceSlot",
    "GlobalAllocator",
    "PoolResult",
    "SharedPool",
    "TenancyConfig",
    "dedicated_cost",
    "diff_device_plans",
    "plan_slots",
]
