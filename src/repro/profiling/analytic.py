"""Analytic model accounting and the analytic TPU profiler.

One module for the whole analytic chain (merged from the former
``profiling.analytics``; its re-export shim was dropped in PR 9):

* parameter / FLOPs / KV-cache accounting per assigned architecture
  (MODEL_FLOPS = 6 N D for training, 2 N_active per token for inference),
  used by `launch.roofline` and the profiler below;
* the analytic TPU profiler ``(arch, batch, seq, hardware) -> duration``,
  replacing the paper's offline GPU profiling pass (Sec. III-A "profiling
  library"): module execution duration is the roofline max of the compute
  and HBM-streaming terms, with a batch-dependent efficiency ramp (small
  batches under-utilize the MXU) — producing Table-I-shaped profiles
  (duration affine-ish in batch, concave throughput).
"""
from __future__ import annotations

from ..configs.base import ArchConfig, LayerSpec
from ..core.profiles import Config, ModuleProfile
from .hardware import CATALOG, TPUSpec

DEFAULT_BATCHES = (1, 2, 4, 8, 16, 32)


# ---------------------------------------------------------------------------
# Parameter / FLOPs accounting
# ---------------------------------------------------------------------------


def _attn_params(cfg: ArchConfig) -> int:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    p = d * H * Dh + 2 * d * Hkv * Dh + H * Dh * d
    if cfg.qkv_bias:
        p += H * Dh + 2 * Hkv * Dh
    return p


def _mla_params(cfg: ArchConfig) -> int:
    d, H = cfg.d_model, cfg.n_heads
    dq, dc, dr = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
    dn, dv = cfg.hdim, cfg.vdim
    p = d * (dc + dr) + dc * H * dn + dc * H * dv + H * dv * d
    if dq:
        p += d * dq + dq * H * (dn + dr)
    else:
        p += d * H * (dn + dr)
    return p


def _mamba_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.d_state
    dtr = max(1, d // 16)
    return 2 * d * di + cfg.d_conv * di + di * (dtr + 2 * N) + dtr * di + di * N + di * d


def _mlstm_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    return 2 * d * di + 4 * di + 3 * di * di + di * 2 * H + di * d


def _slstm_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    H = cfg.n_heads
    Dh = d // H
    dff = -(-(d * 4 // 3) // 8) * 8
    return 4 * d * d + H * Dh * 4 * Dh + 2 * d * dff + dff * d


def _moe_params(cfg: ArchConfig, *, active: bool) -> int:
    d, fe = cfg.d_model, cfg.d_ff_expert
    e = cfg.top_k if active else cfg.n_experts
    p = cfg.d_model * cfg.n_experts + e * 3 * d * fe  # router counted full
    p += 3 * d * fe * cfg.n_shared_experts
    return p


def layer_params(cfg: ArchConfig, spec: LayerSpec, *, active: bool = False) -> int:
    mix = {
        "attn": _attn_params,
        "mla": _mla_params,
        "mamba": _mamba_params,
        "mlstm": _mlstm_params,
        "slstm": _slstm_params,
    }[spec.mixer](cfg)
    ffn = 0
    if spec.ffn == "dense":
        ffn = 3 * cfg.d_model * cfg.d_ff
    elif spec.ffn == "moe":
        ffn = _moe_params(cfg, active=active)
    norms = 2 * cfg.d_model
    return mix + ffn + norms


def param_count(cfg: ArchConfig, *, active: bool = False, embed: bool = True) -> int:
    total = sum(layer_params(cfg, s, active=active) for s in cfg.layer_specs())
    if embed:
        total += cfg.vocab_size * cfg.d_model
        if not cfg.tie_embeddings:
            total += cfg.vocab_size * cfg.d_model
    return total


def layer_flops_per_token(
    cfg: ArchConfig, spec: LayerSpec, seq: int, *, decode: bool = False
) -> float:
    """Forward FLOPs per token of ONE layer: 2 x active params + context term."""
    flops = 2.0 * layer_params(cfg, spec, active=True)
    if spec.mixer in ("attn", "mla"):
        Dh = cfg.hdim + (cfg.rope_head_dim if spec.mixer == "mla" else 0)
        Dv = cfg.vdim if spec.mixer == "mla" else cfg.hdim
        ctx = seq if decode else seq / 2  # causal prefill averages ~S/2
        if spec.window:
            ctx = min(ctx, spec.window)
        flops += 2.0 * cfg.n_heads * (Dh + Dv) * ctx
    elif spec.mixer == "mamba":
        di = cfg.ssm_expand * cfg.d_model
        flops += 6.0 * di * cfg.d_state  # recurrence + output contraction
    elif spec.mixer in ("mlstm", "slstm"):
        di = cfg.ssm_expand * cfg.d_model
        flops += 8.0 * di * (di // max(1, cfg.n_heads))  # state update
    return flops


def flops_per_token(cfg: ArchConfig, seq: int, *, decode: bool = False) -> float:
    """Forward FLOPs per token: active matmuls + attention context + unembed."""
    flops = sum(
        layer_flops_per_token(cfg, s, seq, decode=decode) for s in cfg.layer_specs()
    )
    flops += 2.0 * cfg.d_model * cfg.vocab_size  # unembed
    return flops


def kv_cache_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    total = 0.0
    for s in cfg.layer_specs():
        if s.mixer == "attn":
            total += 2 * cfg.n_kv_heads * cfg.hdim * dtype_bytes
        elif s.mixer == "mla":
            total += (cfg.kv_lora_rank + cfg.rope_head_dim) * dtype_bytes
        # ssm mixers: O(1) state, no per-token cache
    return total


# ---------------------------------------------------------------------------
# Analytic TPU profiler
# ---------------------------------------------------------------------------


def module_duration(
    cfg: ArchConfig,
    batch: int,
    seq: int,
    hw: TPUSpec,
    *,
    mode: str = "prefill",
    base_mfu: float = 0.55,
) -> float:
    """Seconds to run one batched inference of the module on ONE chip."""
    ftok = flops_per_token(cfg, seq, decode=(mode == "decode"))
    tokens = batch * (1 if mode == "decode" else seq)
    flops = ftok * tokens
    # efficiency ramps with batch: tiny batches stall the MXU
    mfu = base_mfu * min(1.0, 0.35 + 0.65 * (batch / 16.0) ** 0.5)
    compute_t = flops / (hw.peak_flops_bf16 * mfu)
    # memory: weights stream once per batch; activations per token
    n_params = param_count(cfg, active=True)
    bytes_moved = 2.0 * n_params + tokens * cfg.d_model * 2.0 * (2 * cfg.n_layers)
    mem_t = bytes_moved / hw.hbm_bw
    fixed = 30e-6  # launch/dispatch overhead
    return fixed + max(compute_t, mem_t)


def arch_profile(
    cfg: ArchConfig,
    *,
    seq: int = 128,
    batches=DEFAULT_BATCHES,
    hardware: tuple[str, ...] = ("tpu-v5e", "tpu-v4", "tpu-v5p"),
    mode: str = "prefill",
) -> ModuleProfile:
    """A Harpagon ModuleProfile for one architecture (the planner's input)."""
    cfgs = []
    for hw_name in hardware:
        hw = CATALOG[hw_name]
        for b in batches:
            d = module_duration(cfg, b, seq, hw, mode=mode)
            cfgs.append(Config(b, round(d, 6), hw.name, hw.unit_price))
    return ModuleProfile(cfg.name, tuple(cfgs))
