"""Incremental control plane (ISSUE-4): versioned plans, warm-start repair,
and epoch-based plan hot-swap in the serving loop.

Covers: Plan versioning/diff/auditable summary, Planner.replan (tolerance
reuse, repair cost parity vs cold on the 5-app suite, cost-regression guard
fallback, quantized-rate plan cache), the swap invariants (conservation
``completed + shed + dropped == offered`` across epoch boundaries, no
in-flight frame lost on a drain), bit-exact equivalence with the control
loop disabled, per-epoch frontend re-reads (admission rebind, live client
backoff), and the serving-cost time integral.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.core.harpagon import PlanDelta
from repro.serving import (
    ControlLoopConfig,
    FrontendConfig,
    QueueDepth,
    ServingEngine,
    TokenBucket,
    serving_cost,
)
from repro.serving.control import EpochRecord
from repro.serving.frontend import ClosedLoopClients, make_admission
from repro.workloads import synth_profiles
from repro.workloads.apps import app_by_name, make_workload

PROFILES = synth_profiles()

SUITE = (
    ("traffic", 100.0, 2.0), ("face", 150.0, 2.5), ("pose", 60.0, 3.0),
    ("caption", 90.0, 2.5), ("actdet", 80.0, 3.0),
)


def suite_plan(name, rate, slo, planner=None):
    pl = planner or Planner(B.HARPAGON)
    plan = pl.plan(make_workload(app_by_name(name), rate, slo), PROFILES)
    assert plan.feasible
    return pl, plan


# ------------------------------------------------- versioned, diffable plans


class TestPlanVersioning:
    def test_cold_plan_is_version_zero(self):
        _, plan = suite_plan("face", 150.0, 2.5)
        assert plan.version == 0
        assert plan.provenance == {}

    def test_replan_bumps_version_and_records_provenance(self):
        pl, plan = suite_plan("face", 150.0, 2.5)
        nr = {m: r * 1.3 for m, r in plan.workload.rates.items()}
        new = pl.replan(plan, nr, PROFILES)
        assert new.version == 1
        assert set(new.provenance) == set(plan.workload.app.modules)
        assert set(new.provenance.values()) <= {"reused", "repaired", "cached", "cold"}
        newer = pl.replan(new, nr, PROFILES)
        assert newer.version == 2

    def test_diff_tracks_machines_rate_and_dummy(self):
        pl, plan = suite_plan("face", 150.0, 2.5)
        nr = {m: r * 1.4 for m, r in plan.workload.rates.items()}
        new = pl.replan(plan, nr, PROFILES)
        delta = plan.diff(new)
        assert isinstance(delta, PlanDelta)
        assert delta.version_from == 0 and delta.version_to == 1
        assert delta.changed_modules  # +40% rate must change machines
        added = sum(d.machines_added for d in delta.modules.values())
        drained = sum(d.machines_drained for d in delta.modules.values())
        assert added > drained  # net growth
        for m, d in delta.modules.items():
            assert d.rate_after == pytest.approx(nr[m])
        assert "add[" in delta.summary()

    def test_diff_rejects_other_app(self):
        _, p1 = suite_plan("face", 150.0, 2.5)
        _, p2 = suite_plan("pose", 60.0, 3.0)
        with pytest.raises(ValueError):
            p1.diff(p2)

    def test_summary_lists_dummy_and_derate_per_alloc(self):
        """Satellite: epoch-by-epoch plan logs are auditable — every alloc
        line carries its dummy rate and headroom derate explicitly."""
        opts = dataclasses.replace(B.HARPAGON, headroom=0.1)
        _, plan = suite_plan("traffic", 100.0, 2.0, Planner(opts))
        text = plan.summary()
        assert f"v{plan.version}" in text
        alloc_lines = [
            l for l in text.splitlines() if "derate=" in l and " x b" not in l
        ]
        n_allocs = sum(len(s.allocs) for s in plan.schedules.values())
        assert len(alloc_lines) == n_allocs
        for line in alloc_lines:
            assert "dummy=" in line and "derate=" in line and "rate=" in line
        # headroom derate is visible, not elided when != 1
        assert any("derate=0.9" in l for l in alloc_lines)


# ------------------------------------------------- warm-start replan


class TestReplan:
    def test_reuse_within_tolerance(self):
        # a small *downward* drift always fits the provisioned capacity; an
        # upward one is reused only when dummy/headroom slack covers it
        pl, plan = suite_plan("pose", 60.0, 3.0)
        nr = {m: r * 0.999 for m, r in plan.workload.rates.items()}
        new = pl.replan(plan, nr, PROFILES, tolerance=0.02)
        assert set(new.provenance.values()) == {"reused"}
        for m in plan.workload.app.modules:
            assert new.schedules[m] is plan.schedules[m]
        assert new.cost == pytest.approx(plan.cost)
        assert plan.diff(new).empty

    def test_shrink_beyond_capacity_is_not_reused(self):
        pl, plan = suite_plan("pose", 60.0, 3.0)
        nr = {m: r * 1.5 for m, r in plan.workload.rates.items()}
        new = pl.replan(plan, nr, PROFILES, tolerance=0.02)
        assert "reused" not in set(new.provenance.values())

    def test_repair_cost_parity_on_suite(self):
        """Acceptance: replan cost within 1% of a cold plan on the 5-app
        suite (mean over up/down ±10% steps; guard-bounded worst case)."""
        ratios = []
        for name, rate, slo in SUITE:
            for f in (0.9, 1.1):
                pl, plan = suite_plan(name, rate, slo, Planner(B.HARPAGON))
                nr = {m: r * f for m, r in plan.workload.rates.items()}
                warm = pl.replan(plan, nr, PROFILES)
                cold = Planner(B.HARPAGON).plan(
                    dataclasses.replace(plan.workload, rates=nr), PROFILES
                )
                assert warm.feasible and cold.feasible
                ratios.append(warm.cost / cold.cost)
        assert np.mean(ratios) <= 1.01
        assert max(ratios) <= 1.06  # single-step worst case is guard-bounded

    def test_cost_guard_falls_back_cold(self):
        pl, plan = suite_plan("caption", 90.0, 2.5)
        nr = {m: r * 1.2 for m, r in plan.workload.rates.items()}
        forced = pl.replan(plan, nr, PROFILES, cost_guard=-0.99)
        cold = Planner(B.HARPAGON).plan(
            dataclasses.replace(plan.workload, rates=nr), PROFILES
        )
        # the guard can only improve on the warm result, never worsen it
        free = Planner(B.HARPAGON).replan(plan, nr, PROFILES, cost_guard=1e9)
        assert forced.cost <= free.cost + 1e-9
        assert forced.cost <= cold.cost * 1.001 + 1e-9

    def test_infeasible_prev_replans_cold(self):
        pl = Planner(B.HARPAGON)
        wl = make_workload(app_by_name("face"), 150.0, 0.001)  # impossible slo
        bad = pl.plan(wl, PROFILES)
        assert not bad.feasible
        nr = {m: r for m, r in wl.rates.items()}
        new = pl.replan(bad, nr, PROFILES)
        assert new.version == 1
        assert set(new.provenance.values()) == {"cold"}

    def test_replan_cache_hits_on_revisited_rates(self):
        """A diurnal walk revisits its rate buckets: the second visit is a
        memo lookup, returned as provenance "cached" with matching cost."""
        pl, plan = suite_plan("face", 150.0, 2.5)
        nr = {m: r * 1.3 for m, r in plan.workload.rates.items()}
        first = pl.replan(plan, nr, PROFILES)
        back = pl.replan(first, plan.workload.rates, PROFILES)
        again = pl.replan(back, nr, PROFILES)
        assert set(again.provenance.values()) == {"cached"}
        assert again.cost == pytest.approx(first.cost)
        assert again.version == back.version + 1


# ------------------------------------------------- hot-swap in the event loop


def _control(interval, **kw):
    kw.setdefault("profiles", PROFILES)
    return ControlLoopConfig(interval=interval, **kw)


class TestHotSwap:
    def test_control_requires_pipeline(self):
        _, plan = suite_plan("face", 150.0, 2.5)
        with pytest.raises(ValueError, match="pipeline"):
            ServingEngine(plan).run(100, 150.0, control=_control(1.0))

    def test_control_requires_profiles(self):
        _, plan = suite_plan("face", 150.0, 2.5)
        with pytest.raises(ValueError, match="profiles"):
            ServingEngine(plan).run(
                100, 150.0, pipeline=True,
                control=ControlLoopConfig(interval=1.0),
            )

    def test_conservation_across_epoch_boundaries(self):
        """Acceptance: completed + shed + dropped == offered under a
        swapping control loop with admission shedding enabled."""
        _, plan = suite_plan("traffic", 100.0, 2.0)
        n = 1500
        fe = FrontendConfig(dummies=True, admission=TokenBucket(burst=4))
        res = ServingEngine(plan).run(
            n, 100.0, arrivals="mmpp", seed=2, frontend=fe, pipeline=True,
            offered_rate=130.0, control=_control(1.5, margin=0.2),
        )
        assert len(res.e2e_latencies) + res.shed + res.dropped == n
        assert res.epochs is not None and len(res.epochs) >= 3
        assert any(e.swapped for e in res.epochs)

    def test_drain_loses_no_inflight_frame(self):
        """Acceptance: a rate drop drains machines mid-run; every admitted
        frame still completes (drained cores finish their open batch)."""
        _, plan = suite_plan("face", 150.0, 2.5)
        n = 1800
        third = n // 3
        hi = np.arange(2 * third) / 150.0
        lo = hi[-1] + np.arange(1, n - 2 * third + 1) / 40.0
        arr = np.concatenate([hi, lo])
        res = ServingEngine(plan).run(
            n, 150.0, arrivals=arr, frontend=FrontendConfig(dummies=True),
            pipeline=True, control=_control(2.0),
        )
        assert res.dropped == 0 and res.shed == 0
        assert len(res.e2e_latencies) == n
        drained = sum(e.machines_drained for e in res.epochs)
        assert drained > 0  # the drop actually shrank the cluster
        versions = [e.version for e in res.epochs]
        assert versions == sorted(versions)

    @pytest.mark.parametrize("kind", ["uniform", "mmpp"])
    def test_disabled_control_is_bit_exact(self, kind):
        """Acceptance: golden equivalence with the control loop off — and a
        loop whose first epoch falls beyond the stream never fires a swap,
        reproducing the uncontrolled run bit-for-bit."""
        _, plan = suite_plan("traffic", 100.0, 2.0)
        eng = ServingEngine(plan)
        base = eng.run(600, 100.0, arrivals=kind, seed=7, pipeline=True)
        idle = eng.run(
            600, 100.0, arrivals=kind, seed=7, pipeline=True,
            control=_control(1e9),
        )
        np.testing.assert_array_equal(
            np.asarray(base.e2e_latencies), np.asarray(idle.e2e_latencies)
        )
        assert idle.epochs is not None and len(idle.epochs) == 1  # t=0 record
        assert not any(e.swapped for e in idle.epochs)

    def test_warmup_fast_start_cadence(self):
        """warmup=w fires the first replans at interval/2^w, ..., interval/2
        before landing back on the regular grid — a cold-start misprovision
        is repaired within a fraction of the first interval."""
        _, plan = suite_plan("traffic", 100.0, 2.0)
        eng = ServingEngine(plan)
        res = eng.run(
            1200, 100.0, arrivals="uniform", pipeline=True,
            control=_control(4.0, warmup=2),
        )
        ts = [e.t for e in res.epochs]
        # t=0 record, then the ladder 1, 2, 4 and the grid 8
        assert ts[:5] == pytest.approx([0.0, 1.0, 2.0, 4.0, 8.0], abs=0.02)
        plain = eng.run(
            1200, 100.0, arrivals="uniform", pipeline=True,
            control=_control(4.0, warmup=0),
        )
        assert [e.t for e in plain.epochs][:3] == pytest.approx(
            [0.0, 4.0, 8.0], abs=0.02
        )
        with pytest.raises(ValueError, match="warmup"):
            ControlLoopConfig(interval=1.0, warmup=-1)

    def test_epoch_records_are_auditable(self):
        _, plan = suite_plan("pose", 60.0, 3.0)
        res = ServingEngine(plan).run(
            1200, 60.0, arrivals="diurnal", seed=1,
            frontend=FrontendConfig(dummies=True),
            pipeline=True, control=_control(3.0, margin=0.25),
        )
        recs = res.epochs
        assert isinstance(recs[0], EpochRecord)
        assert recs[0].t == 0.0 and recs[0].version == plan.version
        for e in recs[1:]:
            assert e.rate_est > 0 and e.target >= e.rate_est
            assert np.isfinite(e.cost)
            if e.swapped:
                assert e.delta_summary

    def test_serving_cost_integral(self):
        recs = [
            EpochRecord(0.0, 1, 1, 0, 10.0, True, False, {}),
            EpochRecord(5.0, 1, 1, 1, 20.0, True, True, {}),
        ]
        # 10 * 5s + 20 * 5s over 10s = 15
        assert serving_cost(recs, 10.0) == pytest.approx(15.0)


# ------------------------------------------------- per-epoch frontend state


class TestFrontendEpochState:
    def test_admission_rebind_follows_provisioned_rate(self):
        ctrl = make_admission(TokenBucket(burst=4), "app", 100.0)
        assert ctrl._rate == 100.0
        ctrl.admit(0.0)  # consume a token: live state
        tokens = ctrl._tokens
        ctrl.rebind(150.0)
        assert ctrl._rate == 150.0
        assert ctrl._tokens == tokens  # bucket level preserved across rebind
        with pytest.raises(ValueError):
            ctrl.rebind(0.0)

    def test_admission_rebind_pins_explicit_rates(self):
        ctrl = make_admission(TokenBucket(rate=42.0, burst=4), "app", 100.0)
        ctrl.rebind(150.0)
        assert ctrl._rate == 42.0  # operator-pinned policy does not move
        qd = make_admission(QueueDepth(depth=4), "app", 100.0)
        qd.rebind(150.0)
        assert qd._drain == 150.0

    def test_client_backoff_none_is_live_latency(self):
        cfg = ClosedLoopClients(backoff=None, retry_on_shed=True)
        assert cfg.backoff is None
        with pytest.raises(ValueError):
            ClosedLoopClients(backoff=-1.0)

    def test_closed_loop_with_control_conserves(self):
        _, plan = suite_plan("face", 150.0, 2.5)
        fe = FrontendConfig(
            dummies=True,
            admission=TokenBucket(burst=2),
            clients=ClosedLoopClients(
                n_clients=64, retry_on_shed=True, max_retries=2, backoff=None
            ),
        )
        res = ServingEngine(plan).run(
            600, 150.0, frontend=fe, pipeline=True,
            control=_control(1.0, margin=0.2),
        )
        assert len(res.e2e_latencies) + res.shed + res.dropped == 600
        assert res.attempts >= 600
