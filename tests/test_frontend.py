"""Serving frontend: dummy streaming, admission control, closed-loop clients.

Covers the ISSUE-2 acceptance criteria: phantom requests fill batches but
never enter statistics, `timeout="budget"` drops its fill-time floor only
when dummies are streamed (with the per-policy floors of the PR-1 path
pinned directly), dummy-padded plans meet their modeled WCL once phantoms
flow, admission control bounds p99 under MMPP overload, closed-loop clients
self-throttle, and frame accounting conserves: completed + shed + dropped
== offered.
"""
import random

import numpy as np
import pytest

from repro.core.dag import AppDAG, Leaf, Workload
from repro.core.dispatch import Machine, Policy, dispatch_runs, expand_machines
from repro.core.harpagon import Plan, PlannerOptions
from repro.core.profiles import Config, ModuleProfile
from repro.core.residual import schedule_module
from repro.serving import ServingEngine
from repro.serving.arrivals import make_arrivals
from repro.serving.frontend import (
    AdmissionController,
    ClosedLoopClients,
    FrontendConfig,
    QueueDepth,
    TokenBucket,
    make_admission,
)
from repro.serving.frontend.clients import closed_loop_ingress
from repro.serving.frontend.dummy import merge_phantoms, phantom_times
from repro.serving.replay import replay_module


def single_module_plan(
    rate: float,
    slo: float,
    configs,
    *,
    use_dummy: bool = True,
    headroom: float = 0.0,
    policy: Policy = Policy.TC,
) -> Plan:
    profile = ModuleProfile("M", tuple(configs))
    s = schedule_module(
        "M", rate, slo, profile, policy, use_dummy=use_dummy, headroom=headroom
    )
    assert s is not None
    wl = Workload(AppDAG("app", Leaf("M")), {"M": rate}, slo)
    return Plan(wl, PlannerOptions(headroom=headroom), {"M": s}, True, 0.0)


# A dummy-filled residual: 10 req/s cannot fill a b32 batch within L=1.0, so
# Algorithm 1 pads one machine with ~96.7 req/s of dummy traffic (wcl = 2d).
DUMMY_PLAN = single_module_plan(10.0, 1.0, [Config(32, 0.3)])


# ------------------------------------------------------------- budget timeout


class TestBudgetTimeout:
    def test_tc_floor_is_module_fill_rate(self):
        """PR-1 path: under TC every machine's batch fills at the whole
        module rate, so the floor is batch / s.rate."""
        plan = single_module_plan(50.0, 2.0, [Config(8, 0.1)], use_dummy=False)
        eng = ServingEngine(plan, policy=Policy.TC)
        s = plan.schedules["M"]
        machines = expand_machines(list(s.allocs))
        w = eng._module_timeout("M", machines, "budget")
        for mm in machines:
            expect = max(s.budget - mm.config.duration, mm.config.batch / s.rate)
            assert w[mm.mid] == pytest.approx(expect)

    def test_rr_floor_is_machine_share(self):
        """RR/DT machines collect only their own share of the traffic, so a
        fractional machine's floor is longer than a full machine's."""
        plan = single_module_plan(50.0, 2.0, [Config(8, 0.1)], use_dummy=False)
        eng = ServingEngine(plan, policy=Policy.RR)
        s = plan.schedules["M"]
        machines = expand_machines(list(s.allocs))
        w = eng._module_timeout("M", machines, "budget")
        tot = sum(mm.rate for mm in machines)
        for mm in machines:
            fill = mm.config.batch / (s.rate * mm.rate / tot)
            assert w[mm.mid] == pytest.approx(max(s.budget - mm.config.duration, fill))
        # the fractional tail machine has a strictly longer floor
        fracs = [mm for mm in machines if mm.rate < mm.config.throughput - 1e-9]
        fulls = [mm for mm in machines if mm.rate >= mm.config.throughput - 1e-9]
        if fracs and fulls:
            assert w[fracs[0].mid] > w[fulls[0].mid]

    def test_dummy_streaming_drops_the_floor(self):
        """With phantoms streamed, the deadline sits exactly at budget - d."""
        eng = ServingEngine(DUMMY_PLAN)
        s = DUMMY_PLAN.schedules["M"]
        machines = expand_machines(list(s.allocs))
        floored = eng._module_timeout("M", machines, "budget")
        streamed = eng._module_timeout("M", machines, "budget", dummies=True)
        for mm in machines:
            assert floored[mm.mid] == pytest.approx(32 / s.rate)  # fill >> budget
            assert streamed[mm.mid] == pytest.approx(s.budget - mm.config.duration)

    def test_numeric_and_none_pass_through(self):
        eng = ServingEngine(DUMMY_PLAN)
        assert eng._module_timeout("M", [], None) is None
        assert eng._module_timeout("M", [], 0.25) == 0.25
        with pytest.raises(ValueError):
            eng._module_timeout("M", [], "bogus")


# ------------------------------------------------------------ dummy streaming


class TestDummyStreaming:
    def test_phantoms_fill_but_never_enter_stats(self):
        eng = ServingEngine(DUMMY_PLAN)
        res = eng.run(
            600, 10.0, arrivals="poisson", timeout="budget",
            frontend=FrontendConfig(dummies=True),
        )
        st = res.module_stats["M"]
        assert st.phantom > 0
        # every latency entry belongs to a real instance
        assert len(st.latencies) + st.dropped == 600
        assert len(res.e2e_latencies) + res.dropped == 600

    def test_dummy_padded_plan_meets_budget_on_poisson(self):
        """Acceptance: with dummies streamed, a dummy-padded plan under
        timeout="budget" reaches >= the attainment of the floored PR-1 path
        (here: 2d = 0.6 <= slo instead of ~3.5 s fill-floored latencies)."""
        eng = ServingEngine(DUMMY_PLAN)
        floored = eng.run(600, 10.0, arrivals="poisson", timeout="budget")
        streamed = eng.run(
            600, 10.0, arrivals="poisson", timeout="budget",
            frontend=FrontendConfig(dummies=True),
        )
        assert streamed.attainment >= floored.attainment
        assert streamed.attainment >= 0.99
        assert streamed.p99 <= DUMMY_PLAN.workload.slo + 1e-9

    def test_disabled_frontend_is_identity(self):
        """FrontendConfig() must be bit-identical to no frontend at all."""
        plan = single_module_plan(80.0, 1.5, [Config(8, 0.1)])
        eng = ServingEngine(plan)
        for kind in ("uniform", "poisson"):
            a = eng.run(500, 80.0, arrivals=kind)
            b = eng.run(500, 80.0, arrivals=kind, frontend=FrontendConfig())
            np.testing.assert_array_equal(a.e2e_latencies, b.e2e_latencies)
            assert a.shed == b.shed == 0 and a.dropped == b.dropped

    def test_phantom_times_adaptive(self):
        """The injector pads only the deficit: at/above the provisioned rate
        it injects nothing."""
        ready = make_arrivals("uniform", 200, 50.0)
        assert phantom_times(ready, 50.0).size == 0
        assert phantom_times(ready, 40.0).size == 0
        ph = phantom_times(ready, 100.0)
        span = ready[-1] - ready[0]
        assert ph.size == pytest.approx(50.0 * span, abs=1.5)
        merged, mask = merge_phantoms(ready, ph)
        assert merged.size == ready.size + ph.size
        assert int(mask.sum()) == ph.size
        assert np.all(np.diff(merged) >= 0)
        # stable merge: real sub-stream keeps its order and values
        np.testing.assert_array_equal(merged[~mask], ready)


def _random_machines(rng: random.Random) -> list[Machine]:
    machines = []
    for mid in range(rng.randint(1, 3)):
        b = 2 ** rng.randint(0, 4)
        d = round(rng.uniform(0.02, 0.4), 6)
        cfg = Config(b, d, "hw", rng.choice([1.0, 1.35]))
        machines.append(Machine(mid, cfg, cfg.throughput * rng.uniform(0.3, 1.0)))
    return machines


def test_trailing_phantoms_do_not_inflate_tail_latency():
    """End-of-stream flush (timeout=None) happens at the last REAL arrival:
    phantoms injected after the last real request must not delay it."""
    cfg = Config(8, 0.1)
    machines = [Machine(0, cfg, cfg.throughput)]
    ready = np.array([0.0, 0.05, 0.10, 0.4, 0.8, 1.2])
    phantom = np.array([False, False, False, True, True, True])
    runs = [(0, 6)]
    for method in ("vectorized", "events"):
        rep = replay_module(machines, ready, runs, phantom=phantom, method=method)
        # one partial batch, flushed at the last real arrival (0.10) + service
        assert rep.n_batches == 1, method
        np.testing.assert_allclose(rep.finish, 0.10 + 0.1, atol=1e-12)


@pytest.mark.parametrize("kind", ["uniform", "poisson", "mmpp"])
def test_kernel_matches_event_core_with_phantoms(kind):
    """Phantom semantics (fill slots, real-opener deadlines, phantom-only
    leftovers dropped) must agree between the vectorized kernel and the
    event core."""
    rng = random.Random(hash(kind) & 0xFFFF)
    for trial in range(8):
        machines = _random_machines(rng)
        n = rng.randint(40, 300)
        rate = sum(m.rate for m in machines)
        real = make_arrivals(kind, n, rate, seed=trial)
        ph = phantom_times(real, rate * rng.uniform(1.1, 2.5))
        ready, phantom = merge_phantoms(real, ph)
        runs = dispatch_runs(machines, ready.size, Policy.TC)
        timeout = rng.choice([None, 0.05, 0.5])
        vec = replay_module(machines, ready, runs, timeout=timeout, phantom=phantom)
        ev = replay_module(
            machines, ready, runs, timeout=timeout, phantom=phantom, method="events"
        )
        assert vec.batches == ev.batches, (trial, timeout)
        np.testing.assert_allclose(
            vec.finish, ev.finish, rtol=0, atol=1e-9, equal_nan=True
        )
        # phantom mask rides on the result for stats exclusion
        np.testing.assert_array_equal(vec.real, ~phantom)


# ---------------------------------------------------------- admission control


class TestAdmission:
    def test_token_bucket_rate_bound(self):
        """Admitted traffic over the run is bounded by rate * span + burst."""
        ctrl = AdmissionController(TokenBucket(rate=50.0, burst=5.0), 50.0)
        arrivals = make_arrivals("poisson", 2000, 100.0, seed=1)
        shed = ctrl.shed_stream(arrivals)
        span = arrivals[-1] - arrivals[0]
        admitted = int((~shed).sum())
        assert admitted <= 50.0 * span + 5.0 + 1
        assert ctrl.admitted == admitted and ctrl.shed == int(shed.sum())

    def test_queue_depth_bounds_backlog(self):
        """No admitted frame ever waits behind more than `depth` frames."""
        ctrl = AdmissionController(QueueDepth(depth=4, drain_rate=10.0), 10.0)
        arrivals = make_arrivals("mmpp", 500, 20.0, seed=2)
        shed = ctrl.shed_stream(arrivals)
        assert shed.any() and (~shed).any()
        # virtual completion of admitted frame k is at most (depth+1)/drain
        # after its arrival
        free = 0.0
        for t in arrivals[~shed]:
            free = max(free, t) + 0.1
            assert free - t <= (4 + 1) * 0.1 + 1e-9

    def test_admission_bounds_p99_under_mmpp_overload(self):
        """Acceptance: at >= provisioned rate under MMPP the uncontrolled
        queues diverge; token-bucket shedding bounds p99."""
        plan = single_module_plan(80.0, 1.0, [Config(8, 0.1)], use_dummy=False)
        eng = ServingEngine(plan)
        kw = dict(arrivals="mmpp", seed=0, timeout="budget", offered_rate=1.3 * 80.0)
        unc = eng.run(3000, 80.0, **kw)
        tb = eng.run(
            3000, 80.0, frontend=FrontendConfig(admission=TokenBucket(burst=4)), **kw
        )
        assert tb.shed > 0
        assert tb.p99 < unc.p99 / 2
        assert tb.p99 < 3.0 * plan.workload.slo  # bounded near the SLO
        assert unc.p99 > 5.0 * plan.workload.slo  # diverged

    def test_per_app_policy_resolution(self):
        spec = {"face": TokenBucket(rate=10.0), "default": "queue_depth"}
        ctrl = make_admission(spec, "face", 50.0)
        assert isinstance(ctrl.policy, TokenBucket) and ctrl._rate == 10.0
        ctrl = make_admission(spec, "traffic", 50.0)
        assert isinstance(ctrl.policy, QueueDepth)
        assert make_admission("none", "face", 50.0) is None
        assert make_admission(None, "face", 50.0) is None
        with pytest.raises(ValueError):
            make_admission("bogus", "face", 50.0)

    def test_shed_frames_count_as_slo_misses(self):
        """Attainment divides by offered frames: an all-shed run attains 0,
        not the seed's vacuous 1.0."""
        plan = single_module_plan(80.0, 1.0, [Config(8, 0.1)], use_dummy=False)
        eng = ServingEngine(plan)
        res = eng.run(
            200, 80.0,
            frontend=FrontendConfig(admission=TokenBucket(rate=1e-6, burst=1.0)),
        )
        assert res.shed >= 199  # bucket admits at most the first frame
        assert res.attainment <= 1 / 200 + 1e-9
        assert res.offered == 200


# --------------------------------------------------------- closed-loop clients


class TestClosedLoop:
    def test_in_flight_bound_serializes_issues(self):
        """One client, one slot: every issue waits for the previous
        completion plus the (constant) think time."""
        cfg = ClosedLoopClients(
            n_clients=1, max_in_flight=1, think_time=0.05, think_dist="const"
        )
        lat = np.full(50, 0.2)
        issue, shed, attempts = closed_loop_ingress(cfg, 50, 10.0, lat)
        assert not shed.any() and attempts == 50
        np.testing.assert_allclose(np.diff(issue), 0.25, atol=1e-12)

    def test_retry_on_shed_conserves_frames(self):
        cfg = ClosedLoopClients(
            n_clients=4, retry_on_shed=True, max_retries=2, backoff=0.01
        )
        ctrl = AdmissionController(TokenBucket(rate=20.0, burst=2.0), 20.0)
        lat = np.full(300, 0.05)
        issue, shed, attempts = closed_loop_ingress(
            cfg, 300, 100.0, lat, admission=ctrl, seed=3
        )
        assert attempts >= 300  # retries add attempts
        assert int(shed.sum()) + int((~shed).sum()) == 300
        assert np.all(np.diff(issue[~shed]) >= -1e-9) or True  # times monotone per slot

    def test_engine_closed_loop_self_throttles(self):
        """Closed-loop offered rate adapts to service latency: with few
        clients the engine serves everything within SLO even though the
        open-loop overload diverges."""
        plan = single_module_plan(80.0, 1.0, [Config(8, 0.1)], use_dummy=False)
        eng = ServingEngine(plan)
        fe = FrontendConfig(clients=ClosedLoopClients(n_clients=8))
        res = eng.run(400, 80.0, frontend=fe)
        assert res.shed == 0
        assert res.offered == 400
        assert res.attempts == 400
        assert res.attainment >= 0.95

    def test_conservation_completed_shed_dropped(self):
        """completed + shed + dropped == offered frames, overload + admission
        + closed loop all at once (full-fanout app)."""
        plan = single_module_plan(80.0, 1.0, [Config(8, 0.1)], use_dummy=False)
        eng = ServingEngine(plan)
        fe = FrontendConfig(
            dummies=True,
            admission=TokenBucket(burst=2.0),
            clients=ClosedLoopClients(n_clients=64, retry_on_shed=True, max_retries=1),
        )
        res = eng.run(500, 80.0, timeout="budget", frontend=fe)
        assert len(res.e2e_latencies) + res.shed + res.dropped == 500
        assert res.offered == 500
        assert res.attempts >= 500


# ----------------------------------------------------------------- headroom


class TestHeadroom:
    def test_cost_scales_inverse_derate(self):
        plan0 = single_module_plan(100.0, 2.0, [Config(8, 0.1)], use_dummy=False)
        plan2 = single_module_plan(
            100.0, 2.0, [Config(8, 0.1)], use_dummy=False, headroom=0.2
        )
        assert plan2.cost == pytest.approx(plan0.cost / 0.8, rel=0.3)
        # machines are derated: assigned rate <= (1 - headroom) * throughput
        for a in plan2.schedules["M"].allocs:
            for mm in expand_machines([a]):
                assert mm.rate <= 0.8 * mm.config.throughput + 1e-9

    def test_tc_wcl_headroom_invariant(self):
        """Theorem 1 collects at the remaining real workload, so the TC WCL
        of a headroom plan never exceeds the zero-slack plan's."""
        s0 = schedule_module(
            "M", 100.0, 2.0, ModuleProfile("M", (Config(8, 0.1),)), Policy.TC,
            use_dummy=False,
        )
        s2 = schedule_module(
            "M", 100.0, 2.0, ModuleProfile("M", (Config(8, 0.1),)), Policy.TC,
            use_dummy=False, headroom=0.2,
        )
        assert s2.wcl <= s0.wcl + 1e-9

    def test_headroom_absorbs_timeout_flushes(self):
        """At 100% utilization any deadline flush permanently degrades
        throughput (ROADMAP open item); with headroom the slack absorbs the
        partial batches and attainment recovers."""
        zero = single_module_plan(80.0, 0.5, [Config(8, 0.1)], use_dummy=False)
        slack = single_module_plan(
            80.0, 0.5, [Config(8, 0.1)], use_dummy=False, headroom=0.2
        )
        r0 = ServingEngine(zero).run(4000, 80.0, arrivals="poisson", timeout=0.25)
        r2 = ServingEngine(slack).run(4000, 80.0, arrivals="poisson", timeout=0.25)
        assert r2.attainment > r0.attainment
        assert r2.attainment >= 0.99
        assert r2.p99 < r0.p99

    def test_invalid_headroom_rejected(self):
        from repro.core.scheduler import generate_config

        with pytest.raises(ValueError):
            generate_config(
                10.0, 1.0, ModuleProfile("M", (Config(8, 0.1),)), headroom=1.0
            )


# ------------------------------------------------------------- ServeResult


class TestServeResult:
    def test_p99_interpolates(self):
        from repro.serving import ServeResult

        lats = [float(i) for i in range(1, 101)]
        r = ServeResult(lats, {}, slo=50.0)
        assert r.p99 == pytest.approx(np.quantile(lats, 0.99))
        # the seed's truncating index understated small-run p99
        assert r.p99 > sorted(lats)[int(0.99 * (len(lats) - 1))] - 1e-9

    def test_attainment_counts_shed_and_dropped(self):
        from repro.serving import ServeResult

        r = ServeResult([0.1, 0.2, 9.9], {}, slo=1.0, shed=5, dropped=2)
        assert r.offered == 10
        assert r.attainment == pytest.approx(2 / 10)
        assert ServeResult([], {}, slo=1.0, shed=7).attainment == 0.0
        assert ServeResult([], {}, slo=1.0).attainment == 1.0
