"""Module profiles: the (batch, duration, hardware) -> throughput/cost tables Harpagon plans over.

A *configuration* is one row of a module's offline profile: running the module at
batch size ``b`` on hardware ``hw`` takes ``d`` seconds per batch, i.e. throughput
``t = b / d`` req/s at unit price ``p`` $/machine.  The *throughput-cost ratio*
``r = t / p`` is the paper's ranking key: covering a request rate ``f`` with a
configuration costs ``p * f / t = f / r`` machines-worth of money (frame-rate
proportionality, paper Sec. III-A), so higher ``r`` is strictly cheaper.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class Hardware:
    """A hardware type in the heterogeneous pool (paper: P100/V100; here: TPU tiers)."""

    name: str
    unit_price: float  # $ per machine per unit time (relative)


# TPU catalog used by the analytic profiler (price ratios ~ GCP on-demand).
TPU_V5E = Hardware("tpu-v5e", 1.0)
TPU_V4 = Hardware("tpu-v4", 1.35)
TPU_V5P = Hardware("tpu-v5p", 1.75)
HARDWARE_CATALOG = (TPU_V5E, TPU_V4, TPU_V5P)


@dataclass(frozen=True)
class Config:
    """One profiled configuration of a module."""

    batch: int
    duration: float  # seconds per batch at this batch size
    hardware: str = "default"
    unit_price: float = 1.0

    @property
    def throughput(self) -> float:
        return self.batch / self.duration

    @property
    def ratio(self) -> float:
        """Throughput-cost ratio r = t / p."""
        return self.throughput / self.unit_price

    def __repr__(self) -> str:  # compact: (b=8@tpu-v5e t=32.0)
        return f"(b={self.batch}@{self.hardware} t={self.throughput:.4g})"


@dataclass(frozen=True)
class ModuleProfile:
    """All candidate configurations for one DNN module, sorted by ratio desc."""

    name: str
    configs: tuple[Config, ...]

    def __post_init__(self):
        ordered = tuple(sorted(self.configs, key=lambda c: -c.ratio))
        object.__setattr__(self, "configs", ordered)

    def restrict(
        self,
        *,
        max_batch: int | None = None,
        hardware: Sequence[str] | None = None,
    ) -> "ModuleProfile":
        """Filtered copy (used by ablations Harp-nb / Harp-nhc / Harp-nhe).

        Unfiltered calls return ``self``: the planner restricts profiles on
        every `plan()`, and a stable ``configs`` tuple identity keeps the
        batched-WCL array caches (keyed by that identity) hot across calls.
        """
        if max_batch is None and hardware is None:
            return self
        cfgs = [
            c
            for c in self.configs
            if (max_batch is None or c.batch <= max_batch)
            and (hardware is None or c.hardware in hardware)
        ]
        return dataclasses.replace(self, configs=tuple(cfgs))

    @property
    def hardware_names(self) -> tuple[str, ...]:
        return tuple(sorted({c.hardware for c in self.configs}))

    def cheapest_hardware(self) -> str:
        return min(self.configs, key=lambda c: c.unit_price).hardware

    def most_expensive_hardware(self) -> str:
        return max(self.configs, key=lambda c: c.unit_price).hardware

    def least_efficient(self) -> Config:
        """Starting point of Algorithm 2: the minimum throughput-cost-ratio config."""
        return self.configs[-1]


def _mk(name: str, rows: Sequence[tuple[int, float]]) -> ModuleProfile:
    return ModuleProfile(name, tuple(Config(b, d) for b, d in rows))


# Paper Table I (homogeneous hardware, unit price 1.0). Used verbatim in tests.
TABLE1_M1 = _mk("M1", [(2, 0.160), (4, 0.200), (8, 0.320)])
TABLE1_M2 = _mk("M2", [(2, 0.125), (4, 0.160), (8, 0.250)])
TABLE1_M3 = _mk("M3", [(2, 0.100), (8, 0.250), (32, 0.800)])

# Paper Sec. III-B worked example: module M4 (A/B at b=6 d=2.0, C at b=2 d=1.0).
TABLE_M4 = _mk("M4", [(6, 2.0), (2, 1.0)])

TABLE1 = {"M1": TABLE1_M1, "M2": TABLE1_M2, "M3": TABLE1_M3}
