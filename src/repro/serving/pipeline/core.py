"""Multi-module pipelined co-simulation: one event loop over the whole DAG.

The flat engine (`repro.serving.engine._serve`) replays modules one at a
time in topological order: each module's full request stream is known before
the module runs, and downstream only sees per-frame *finish times*.  That is
exact while queues are unbounded and fanout is deterministic — and blind to
everything else.  This core instead pushes each frame through the app DAG as
a tracked entity inside one global discrete-event loop:

* per-module **ingress is fed by upstream batch completions** (not by an
  independent arrival process): a detector batch finishing at ``t`` lands
  its frames' classifier crops at ``t``, in frame order;
* **bounded queues exert backpressure**: a stage at ``queue_cap`` parks
  deliveries FIFO and the upstream machine that produced them *stays busy*
  until the stage drains — upstream throughput degrades exactly like a real
  pipeline with finite inter-stage buffers;
* **fanout is per-frame** (`.fanout.FanoutSpec`): deterministic accumulator
  (flat-engine-identical) or seeded stochastic draws correlated across
  sibling modules;
* **clients and admission live inside the loop**: closed-loop slots issue
  the next frame when the previous one actually resolves, and queue-depth
  admission sheds against the true number of frames in flight — no
  fixed-point iteration, no latency oracle from a previous pass.

Event ordering at equal timestamps mirrors the single-module reference core:
arrivals join batches at their deadline instant, and upstream machine-frees
deliver before a downstream flush at the same instant fires (see
`stages._K_*`).  All same-time machine-frees are collected before their
outputs are delivered, sorted by ``(stage topo index, frame id)`` — the same
order the flat engine's stable ready-sort produces, which is what makes the
co-simulation cross-validate bit-for-bit against the vectorized kernel on
unbounded queues with deterministic fanout.

**Macro-event hot path.**  The event-by-event loop is the semantics oracle,
not the speed target.  Three layers sit on top of it:

* per-frame state lives in preallocated struct-of-arrays columns
  (`result.FrameTable`) indexed by frame id — no per-frame dicts;
* same-instant work is drained in macro-events: all machine-frees at one
  timestamp deliver together (pre-existing), and a frame's whole fanout
  enters a stage through one `ModuleStage.deliver_run` walk advance instead
  of per-instance dispatcher calls;
* when the run is **quiescent of everything only the event loop can
  express** — open-loop issue, unbounded queues, deterministic fanout, no
  phantom streaming, no admission, no control epochs — the entire segment
  (here: the whole run) is delegated to the vectorized flat kernel
  (`.fastpath`), a cache of the PR-3 equivalence theorem.  The event loop
  would be re-entered at the segment boundary; with run-constant
  eligibility there is exactly one segment.

``PipelineConfig(reference=True)`` pins the original event-by-event loop
(global heapq, scalar delivery, no fast path) as the bit-exactness oracle.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping

import numpy as np

from ...core.dag import AppDAG
from ..frontend.admission import AdmissionController
from ..frontend.clients import ClosedLoopClients
from .equeue import make_queue
from .fanout import FanoutSpec
from .result import FrameTable, PipelineResult
from .stages import (
    Instance, ModuleStage, _K_ARRIVE, _K_EPOCH, _K_FAULT, _K_FLUSH, _K_FREE,
)


@dataclass(frozen=True)
class PipelineConfig:
    """Engine-facing knobs for ``ServingEngine.run(pipeline=...)``.

    ``queue_cap`` bounds every stage's ingress backlog (instances waiting to
    start service); ``None`` disables backpressure and reproduces the flat
    engine's unbounded-queue numbers.  ``fanout`` selects deterministic or
    correlated-stochastic per-frame fanout.

    Performance knobs (results are invariant to all of them):

    * ``reference`` — run the original event-by-event loop (global heapq,
      scalar per-instance delivery, no segment fast-path): the bit-exactness
      oracle the macro-event path is property-tested against.
    * ``fast_path`` — allow delegating a control-quiescent run to the
      vectorized flat kernel (`repro.serving.pipeline.fastpath`); setting it
      ``False`` keeps the macro-event general loop even when eligible
      (useful for benchmarking the loop itself).
    * ``event_queue`` — ``"heap"`` (single global heap, default) or
      ``"calendar"`` (bucketed calendar queue); both serve the identical
      ``(t, kind, seq)`` order.  ``quantum`` overrides the calendar bucket
      width (default: mean issue spacing).  The calendar's O(1)-amortized
      promise does not survive CPython at this event population: the
      C-implemented global heap measures ~10-40% faster than pure-Python
      bucket bookkeeping across quantum settings (see the README speedup
      table), so the heap stays the default and the calendar remains the
      selectable, equivalence-pinned alternative.
    """

    fanout: FanoutSpec = FanoutSpec()
    queue_cap: "int | None" = None
    reference: bool = False
    fast_path: bool = True
    event_queue: str = "heap"
    quantum: "float | None" = None


def run_pipeline(
    dag: AppDAG,
    stages: Mapping[str, ModuleStage],
    n_frames: int,
    *,
    issue: "np.ndarray | None" = None,
    clients: "ClosedLoopClients | None" = None,
    pace: float = 1.0,
    admission: "AdmissionController | None" = None,
    tail: str = "flush",
    seed: int = 0,
    control=None,
    e2e_hint: float = 0.05,
    reference: bool = False,
    fast_path: bool = True,
    event_queue: str = "heap",
    quantum: "float | None" = None,
    obs=None,
    faults=None,
) -> PipelineResult:
    """Co-simulate ``n_frames`` frames through ``stages`` along ``dag``.

    Exactly one of ``issue`` (open-loop: pre-drawn sorted issue times) and
    ``clients`` (event-interleaved closed loop paced by completions; ``pace``
    staggers the initial slot starts) must be given.  ``admission`` sheds at
    the issue instant against live state.  ``tail`` governs end-of-stream
    leftovers on timeout-less machines (``"flush"`` / ``"drop"``).

    ``control`` (a `repro.serving.control.ControlRuntime`) runs the
    incremental control plane *inside* the loop: it observes every issued
    frame, fires at epoch boundaries (``_K_EPOCH`` events, after all
    same-instant arrivals/frees/flushes), and hot-swaps the stage machine
    sets via :meth:`ModuleStage.apply_update` without dropping in-flight
    frames.  The epoch chain dies once the whole stream has been issued, so
    end-of-stream quiescence (and golden equivalence with the control loop
    disabled) is untouched.  ``e2e_hint`` is the fallback latency estimate
    for clients whose retry ``backoff`` re-reads live plan state.

    ``obs`` (a `repro.serving.observability.Observability`, or None) is the
    passive telemetry sink: the loop reports batch spans, flush causes,
    sheds, parks, and epoch boundaries to it but never reads it back —
    results are bit-identical with observability on or off.

    ``faults`` (a `repro.serving.faults.FaultRuntime`, or None) arms the
    seeded fault injector: ``_K_FAULT`` events crash machines silently
    (dispatch keeps feeding them — nobody knows yet), slow stragglers, and
    drive the batch-duration watchdog that escalates a machine suspect →
    dead; a dead machine's unfinished members are re-queued to surviving
    siblings and the control plane (when present) force-replans the module
    out of band.  Frame conservation holds under any fault schedule:
    every frame still resolves completed, shed, or dropped.
    """
    if tail not in ("flush", "drop"):
        raise ValueError(f"unknown tail policy {tail!r}")
    if (issue is None) == (clients is None):
        raise ValueError("need exactly one of issue= (open loop) or clients=")
    if issue is not None:
        issue = np.asarray(issue, dtype=np.float64)
        if issue.shape != (n_frames,):
            raise ValueError("issue times must have one entry per frame")
    if (
        not reference
        and fast_path
        and issue is not None
        and admission is None
        and control is None
        and faults is None
    ):
        from . import fastpath

        if fastpath.eligible(dag, stages):
            # the whole run is one quiescent segment: delegate to the
            # vectorized flat kernel (the PR-3 equivalence theorem, cached;
            # streams run in the event loop's causal order, backdated
            # end-of-stream tails included — see fastpath docstring)
            return fastpath.run_flat_segment(
                dag, stages, n_frames, issue, tail, obs=obs
            )
    rng = np.random.default_rng(seed)
    topo = dag.topo_order()
    torder = {m: i for i, m in enumerate(topo)}
    parents = {m: sorted(dag.parents(m), key=torder.__getitem__) for m in topo}
    children = {m: sorted(dag.children(m), key=torder.__getitem__) for m in topo}
    sources = [m for m in topo if not parents[m]]
    sink_set = {m for m in topo if not children[m]}
    ancestors = dag.ancestor_closure()

    def holds_real_work(st: ModuleStage) -> bool:
        """True while the stage can still emit completions downstream:
        parked deliveries, busy/queued cores (backpressure-blocked machines
        stay busy with no pending free event), or real formation members.
        Phantom-only buffers are excluded — they discard, never deliver."""
        if st.parked:
            return True
        for core in st.cores.values():
            if core.busy or core.queue:
                return True
            if core.buf and any(i.real for i in core.buf):
                return True
        return False

    # -- per-frame state: preallocated SoA columns indexed by frame id ------
    ft = FrameTable(n_frames, topo, parents, len(sink_set))
    issue_t, shed, lost, resolved = ft.issue, ft.shed, ft.lost, ft.resolved
    sink_bad, sink_max, sinks_left, e2e = (
        ft.sink_bad, ft.sink_max, ft.sinks_left, ft.e2e,
    )
    avail, finish, pend = ft.avail, ft.finish, ft.pend
    parents_left, child_void, child_avail = (
        ft.parents_left, ft.child_void, ft.child_avail,
    )
    stalled, fan = ft.stalled, ft.fan
    # wire the stages' telemetry sinks: the always-on partial-flush forensic
    # column, and the optional observability hooks
    for st_ in stages.values():
        st_.flushed_col = ft.flushed
        st_.obs = obs

    attempts = 0
    next_frame = 0      # closed-loop global frame counter
    issued = 0          # distinct frames offered so far (first attempts)
    # per-stage stream accounting, so phantom injection knows when a stage's
    # real stream is over: a stage is *done* once every frame is accounted
    # there (entered, voided upstream, or shed at ingress) and no instance
    # is still pending — a real frontend stops injecting dummies into a
    # stage whose traffic has ended, and a self-perpetuating phantom chain
    # would otherwise keep the heap non-empty forever
    acc_count = {m: 0 for m in topo}
    pend_total = {m: 0 for m in topo}

    def stage_stream_done(m: str) -> bool:
        return acc_count[m] >= n_frames and pend_total[m] == 0

    if quantum is None and event_queue == "calendar" and not reference:
        # default calendar bucket = mean issue spacing (events cluster at
        # the arrival timescale); correctness is quantum-invariant.  The
        # heap queue never reads it, so skip the O(n) scan there.
        if issue is not None and n_frames > 1:
            span = float(np.max(issue)) - float(np.min(issue))
            quantum = max(span / n_frames, 1e-9)
        else:
            quantum = max(e2e_hint / 8.0, 1e-9)
    heap = make_queue("heap" if reference else event_queue, quantum)
    heap_push = heap.push
    _seq = 0

    def push(t: float, kind: int, stage: "str | None", payload) -> None:
        nonlocal _seq
        heap_push((t, kind, _seq, stage, payload))
        _seq += 1

    # upstream machines held busy by undelivered outputs: (stage, mid) -> count
    blocked: dict[tuple[str, int], int] = {}

    def think() -> float:
        if clients is None or clients.think_time <= 0.0:
            return 0.0
        if clients.think_dist == "const":
            return clients.think_time
        return float(rng.exponential(clients.think_time))

    def revive_phantoms(st: ModuleStage, now: float) -> None:
        """Restart a dormant injection chain (paid-up through ``now``).

        A chain goes dormant when the stage cannot take a phantom (full,
        parked deliveries, or queued real batches); it must be revived by
        whatever clears that condition — a delivery (the pre-existing hook)
        or a machine freeing (drains the service backlog).  A stage whose
        real stream has ended but whose tail batch still needs phantom fill
        depends on the free-side revival: no further delivery will come.
        """
        if st.phantom_paused and st.phantom_target > 0.0:
            st.phantom_paused = False
            period = 1.0 / st.phantom_target
            st.anchor = now - st.delivered * period
            push(now + period, _K_ARRIVE, None, ("phantom", st.name, st.phantom_token))

    def deliver_to(st: ModuleStage, inst: Instance, now: float) -> None:
        """Deliver one instance and revive a dormant phantom chain."""
        st.deliver(inst, now, push)
        revive_phantoms(st, now)

    def finish_frame(f: int, t: float) -> None:
        if resolved[f]:
            return
        resolved[f] = True
        if not sink_bad[f] and not lost[f]:
            e2e[f] = sink_max[f] - issue_t[f]
        if clients is not None:
            push(t + think(), _K_ARRIVE, None, ("issue", -1, 0))

    def stage_resolved(m, f, t, done, entries, blocker) -> None:
        """Frame ``f`` resolved at stage ``m`` (``done`` or void); propagate."""
        if m in sink_set:
            if done:
                sink_max[f] = max(sink_max[f], t)
            else:
                sink_bad[f] = True
            sinks_left[f] -= 1
            if sinks_left[f] == 0:
                finish_frame(f, t)
        for c in children[m]:
            if done:
                child_avail[c][f] = max(child_avail[c][f], t)
            else:
                child_void[c][f] = True
            parents_left[c][f] -= 1
            if parents_left[c][f] == 0:
                if child_void[c][f]:
                    # a skipped/lost parent voids the child: the frame never
                    # traverses it (seed semantics: finish 0 propagates drop)
                    acc_count[c] += 1
                    stage_resolved(c, f, t, False, entries, blocker)
                else:
                    entries.append((c, f, child_avail[c][f], blocker))

    def enter_stage(m, f, t, blocker, entries, now) -> None:
        """Frame ``f`` becomes available at ``m``; materialize its instances."""
        acc_count[m] += 1
        st = stages[m]
        c = st.fanout.count(f)
        if c == 0:
            # zero-fanout skip: vacuously resolved, excluded downstream
            stage_resolved(m, f, t, False, entries, blocker)
            return
        avail[m][f] = t
        pend[m][f] = c
        fan[m][f] = c
        pend_total[m] += c
        if (
            not reference
            and st.queue_cap is None
            and not st.parked
            and st.phantom_target <= 0.0
            and st.machines
        ):
            # macro-event delivery: the whole fanout enters through one
            # dispatcher walk advance (scalar-identical; see deliver_run) —
            # backpressure parks per-instance and phantom pacing counts
            # per-delivery, so those regimes keep the scalar path
            st.deliver_run(f, c, t, push)
            return
        for _ in range(c):
            inst = Instance(f, t)
            if st.parked or not st.has_space or not st.machines:
                # a stage with NO machines (every one declared dead, no
                # replacement yet) parks blocker-less: a recovery update
                # rescues the queue, and frames still parked at end of run
                # wedge into ``dropped`` (graceful degradation, conserved)
                if not st.machines and faults is not None:
                    ft.failed[f] = True  # victim of the failure, for forensics
                st.parked.append((inst, blocker))
                stalled[f] = True
                if obs is not None:
                    obs.park(t, m)
                if blocker is not None:
                    blocked[blocker] = blocked.get(blocker, 0) + 1
            else:
                deliver_to(st, inst, t)

    def deliver_entries(entries, now) -> None:
        """Deliver newly-available frames, frame-ordered within each stage —
        the order the flat engine's stable ready-sort would produce."""
        for c, f, t, blocker in sorted(
            entries, key=lambda e: (torder[e[0]], e[1])
        ):
            enter_stage(c, f, t, blocker, entries_out := [], now)
            if entries_out:
                deliver_entries(entries_out, now)

    def drain_parked(st: ModuleStage, now: float) -> bool:
        delivered = False
        while st.parked and st.has_space and st.machines:
            inst, blocker = st.parked.popleft()
            deliver_to(st, inst, now)
            delivered = True
            if blocker is not None:
                unblock(blocker, now)
        return delivered

    def unblock(key: tuple, now: float) -> None:
        blocked[key] -= 1
        if blocked[key] == 0:
            del blocked[key]
            um, umid = key
            ust = stages[um]
            ucore = ust.cores.get(umid)
            if ucore is None or ucore.failed:
                return  # the producer was declared dead while blocked
            ucore.free(now)
            if ust.start_next(umid, now, push):
                drain_parked(ust, now)
            revive_phantoms(ust, now)

    def handle_instance_drop(m, f, t, entries) -> None:
        pend[m][f] -= 1
        pend_total[m] -= 1
        if pend[m][f] == 0:
            if math.isnan(finish[m][f]):
                lost[f] = True
                if obs is not None:
                    obs.shed(t, "pipeline_drop")
                stage_resolved(m, f, t, False, entries, None)
            else:
                # partial completion: the frame proceeds with the instances
                # that did finish (seed semantics: finish = max over done)
                stage_resolved(m, f, float(finish[m][f]), True, entries, None)

    # -- fault injection / detection / recovery ------------------------------
    def active_machines() -> "list[tuple[str, int]]":
        """Crash candidates: every dispatching (non-draining, non-fenced)
        machine, in deterministic (topo, mid) order."""
        out = []
        for m in topo:
            st = stages[m]
            for mach in st.machines:
                core = st.cores.get(mach.mid)
                if core is not None and not core.failed:
                    out.append((m, mach.mid))
        return out

    def declare_dead(m: str, mid: int, t: float) -> None:
        """Failure verdict: fence the machine, re-queue its work, recover.

        The stage surrenders the dead machine's unfinished real members
        (`ModuleStage.fail_machine`); each is marked in the forensic
        ``failed`` column and re-delivered to surviving siblings — or
        parked (blocker-less) when none survive, to be rescued by the
        recovery update's replacement machines.  With a control runtime,
        the module is force-replanned out of band against the reduced
        machine set (`ControlRuntime.on_failure`); without one, recovery
        is requeue-only.  A machine an epoch swap already retired from
        dispatch is reclaimed without the replan (its capacity was already
        replaced by the swap — only its stranded members need rescue).
        """
        st = stages[m]
        faults.forget(m, mid)
        if (m, mid) in faults.dead or st.cores.get(mid) is None:
            return  # verdict already delivered, or the core fully retired
        faults.dead.add((m, mid))
        in_dispatch = any(mach.mid == mid for mach in st.machines)
        reals = st.fail_machine(mid, t)
        faults.n_killed += 1
        if obs is not None:
            obs.fail(t, m, mid)
        faults.n_requeued += len(reals)
        if reals:
            for inst in reals:
                ft.failed[inst.frame] = True
            if obs is not None:
                obs.requeue(t, m, mid, len(reals))
        for inst in reals:
            if st.machines and st.has_space and not st.parked:
                deliver_to(st, inst, t)
            else:
                st.parked.append((inst, None))
                if obs is not None:
                    obs.park(t, m)
        if control is not None and in_dispatch and issued < n_frames:
            updates = control.on_failure(t, m)
            if updates:
                for um, upd in updates.items():
                    stages[um].apply_update(upd, t, push)
                for um in updates:
                    drain_parked(stages[um], t)

    def inject_fault(fkind: str, t: float) -> None:
        """Fire one fault.  Crashes are *silent* — the core is fenced but
        stays in the dispatch walk until the watchdog declares it dead —
        because nobody in a real cluster learns of a crash except through
        missed heartbeats.  Device loss crashes every co-located slot of
        one physical device at once and repacks the shared pool
        immediately (the hardware monitor's out-of-band signal)."""
        cfg = faults.cfg
        if fkind == "device_loss" and cfg.device_map:
            did = faults.pick(sorted(set(cfg.device_map.values())))
            hit = False
            for (m, mid), d in sorted(cfg.device_map.items()):
                if d != did:
                    continue
                st = stages.get(m)
                core = st.cores.get(mid) if st is not None else None
                if core is not None and not core.failed:
                    core.failed = True
                    hit = True
            if hit:
                faults.n_injected += 1
                if cfg.on_device_loss is not None:
                    cfg.on_device_loss(t, did)
            return
        cand = active_machines()
        if fkind == "straggler":
            victim = faults.pick(cand)
            if victim is not None:
                m, mid = victim
                faults.slow[(m, mid)] = cfg.straggler_factor
                faults.n_injected += 1
                push(t + cfg.straggler_duration, _K_FAULT, m, ("recover", mid))
            return
        # "crash" (and device_loss outside a shared pool): without a control
        # plane no replacement ever comes, so prefer a stage that keeps at
        # least one survivor — a single-machine stage would wedge its whole
        # app until end-of-stream
        if control is None:
            multi = [(m, mid) for m, mid in cand if len(stages[m].machines) > 1]
            cand = multi or cand
        victim = faults.pick(cand)
        if victim is not None:
            m, mid = victim
            stages[m].cores[mid].failed = True
            faults.n_injected += 1

    def issue_frame(f: int, t: float, tries: int) -> None:
        nonlocal attempts, issued
        if clients is not None:
            attempts += 1
        if tries == 0:
            issued += 1
            if control is not None:
                # the control plane estimates demand from *offered* frames:
                # shed traffic is still demand the next plan should cover
                control.observe(t)
        if admission is not None:
            # live ingress occupancy: instances waiting (formation + queued
            # + parked) at the source stages — the real quantity the PR-2
            # virtual drain-rate queue approximated
            backlog = sum(
                stages[src].backlog + len(stages[src].parked) for src in sources
            )
            # interim denials the closed-loop client will re-issue are
            # tagged "shed_retry", never "shed": trace/metrics "shed"
            # instants stay summable as terminal sheds in both loop shapes
            will_retry = (
                clients is not None
                and clients.retry_on_shed
                and tries < clients.max_retries
            )
            if will_retry:
                cause = "shed_retry"
            elif clients is not None and clients.retry_on_shed and tries > 0:
                cause = "retry_exhausted"  # the bounded-retry budget ran out
            else:
                cause = "shed"
            admitted = admission.admit_live(t, backlog, cause=cause)
        else:
            admitted = True
        if admitted:
            issue_t[f] = t
            entries = []
            for src in sources:
                enter_stage(src, f, t, None, entries, t)
            deliver_entries(entries, t)
            return
        if (
            clients is not None
            and clients.retry_on_shed
            and tries < clients.max_retries
        ):
            # backoff=None re-reads the *live* plan's modeled e2e latency at
            # every retry (per-epoch state under a control loop, not a
            # run-constant): a client waits about one service round
            if clients.backoff is not None:
                base = clients.backoff
            elif control is not None:
                base = control.e2e_hint
            else:
                base = e2e_hint
            delay = base * (2.0 ** tries) * float(rng.uniform(0.5, 1.5))
            push(t + delay, _K_ARRIVE, None, ("issue", f, tries + 1))
            return
        issue_t[f] = t
        exhausted = clients is not None and clients.retry_on_shed and tries > 0
        if exhausted:
            # the bounded retry budget ran out: the frame was offered and
            # re-offered but never entered the pipeline — it counts as
            # *dropped* (admitted demand the system failed), not shed
            # (a first-sight rejection), under its own trace cause
            lost[f] = True
        else:
            shed[f] = True
        if obs is not None and (admission is None or admission.obs is None):
            # a wired admission controller already emitted this terminal
            # denial at decision resolution (interim retry denials carry
            # the distinct "shed_retry" cause); only emit here when the
            # terminal denial would otherwise go unseen
            obs.shed(t, "retry_exhausted" if exhausted else "shed")
        resolve_shed(f, t)

    def resolve_shed(f: int, t: float) -> None:
        resolved[f] = True
        for m in topo:
            acc_count[m] += 1  # a shed frame's stream position is spent
        if clients is not None:
            push(t + think(), _K_ARRIVE, None, ("issue", -1, 0))

    # -- prime the loop ------------------------------------------------------
    t_first = 0.0
    if issue is not None:
        for i in range(n_frames):
            push(float(issue[i]), _K_ARRIVE, None, ("issue", i, 0))
        t_first = float(issue[0]) if n_frames else 0.0
    else:
        slots = clients.n_clients * clients.max_in_flight
        for k in range(min(slots, n_frames)):
            push(k / pace, _K_ARRIVE, None, ("issue", -1, 0))
    for m in topo:
        st = stages[m]
        if st.phantom_target > 0.0:
            st.anchor = t_first
            push(
                t_first + 1.0 / st.phantom_target, _K_ARRIVE, None,
                ("phantom", m, st.phantom_token),
            )
    if faults is not None:
        # one pending injection event at a time; each fired fault chains
        # the next (explicit schedule first, then the seeded MTBF process).
        # The chain retires with the stream, like the epoch chain.
        nf = faults.next_fault(t_first)
        if nf is not None:
            push(nf[0], _K_FAULT, None, ("inject", nf[1]))
        wd_k = faults.cfg.detect_k

        def arm_watchdog(m: str, mid: int, core, now: float) -> None:
            # heartbeat: batch #n_closed must complete (n_done reaches it)
            # within k x the machine's modeled service, else escalate
            push(
                now + wd_k * core.machine.config.duration,
                _K_FAULT, m, ("watchdog", mid, core.n_closed, core.n_done),
            )

        for st_ in stages.values():
            st_.watchdog = arm_watchdog
            st_.keep_spare = faults.cfg.spare

    epoch_armed = False
    relax_armed = False
    relax_every = control.relax_interval if control is not None else None
    if control is not None:
        push(control.next_epoch(t_first), _K_EPOCH, None, None)
        epoch_armed = True
        if relax_every is not None:
            # mid-epoch staleness ticks: transient-aware deadline relaxation
            # (same event kind as epochs — a swap at the same instant must
            # observe everything — distinguished by payload)
            push(t_first + relax_every, _K_EPOCH, None, ("relax",))
            relax_armed = True

    # -- main loop -----------------------------------------------------------
    t_now = 0.0
    while True:
        if not heap:
            # stream quiescent: resolve leftover partial batches (the flat
            # core does this once at end of stream; interleaved clients can
            # also quiesce mid-run when every slot waits on a stuck frame —
            # flushing is then the only causally-consistent way forward).
            # Per round, flush every stage whose ANCESTORS hold no more
            # real work: an upstream tail flush can still deliver members
            # that complete a downstream batch, so a stage must not flush
            # until everything above it has fully drained (the flat engine
            # replays whole modules in topo order for exactly this reason).
            # Sibling stages, however, must flush in the SAME round: their
            # tail completions re-enter the heap and process in global time
            # order, so a shared child receives them in availability order
            # — flushing one sibling per round delivered a later-flushed
            # sibling's EARLIER completion after an earlier-flushed
            # sibling's later one, silently reordering the child's dispatch
            # stream relative to the flat engine's stable ready-sort.
            acted = False
            # frozen per round: a child must not flush in the round its
            # ancestor's tail closed — that tail's completion still has to
            # travel through the heap and may complete the child's batch
            stage_busy = {m: holds_real_work(stages[m]) for m in topo}
            for m in topo:
                if any(stage_busy[a] for a in ancestors[m]):
                    continue  # an upstream tail can still feed this stage
                st = stages[m]
                entries: list = []
                for mid, core in st.cores.items():
                    if not core.buf:
                        continue
                    reals = [i for i in core.buf if i.real]
                    if reals and core.timeout is not None:
                        continue  # an armed deadline event is still coming
                    if reals and tail == "flush":
                        # flush at the last REAL member's ready time: the
                        # frontend stops injecting phantoms once the stream
                        # ends (single-module reference semantics)
                        t_last = max(i.ready for i in reals)
                        st.close(
                            mid, batch_ready=t_last, now=t_last, push=push,
                            cause="eos",
                        )
                    else:
                        for inst in st.discard_leftover(mid):
                            handle_instance_drop(m, inst.frame, t_now, entries)
                    acted = True  # the non-empty buffer was emptied either way
                if entries:
                    deliver_entries(entries, t_now)
                acted |= drain_parked(st, t_now)
            if not acted and not heap:
                break
            if acted and control is not None and issued < n_frames:
                # the wedge is resolved and the run continues: re-arm the
                # epoch/relax chains that lapsed to let this flush happen
                if not epoch_armed:
                    push(control.next_epoch(t_now), _K_EPOCH, None, None)
                    epoch_armed = True
                if relax_every is not None and not relax_armed:
                    push(t_now + relax_every, _K_EPOCH, None, ("relax",))
                    relax_armed = True
            continue
        t, kind, _s, stage_name, payload = heap.pop()
        t_now = max(t_now, t)
        if kind == _K_ARRIVE:
            what = payload[0]
            if what == "issue":
                _, f, tries = payload
                if f == -1:
                    if next_frame >= n_frames:
                        continue  # stream exhausted: slot retires
                    f, tries = next_frame, 0
                    next_frame += 1
                issue_frame(f, t, tries)
            else:  # adaptive phantom injection at one stage
                _, m, token = payload
                st = stages[m]
                if token != st.phantom_token or st.phantom_target <= 0.0:
                    continue  # a hot-swap re-anchored the streamer: stale chain
                if stage_stream_done(m):
                    continue  # this stage's real stream is over: chain dies
                period = 1.0 / st.phantom_target
                if st.delivered == 0:
                    # pad only from the first real arrival onward (the flat
                    # injector spans the real stream): go dormant rather
                    # than warm an idle stage — or keep the heap alive while
                    # an upstream wedge waits for the quiescence flush; the
                    # first delivery revives the chain (deliver_to)
                    st.phantom_paused = True
                    continue
                # half-slot grace: upstream batch completions land in bursts
                # that tie with the slot boundary (arrivals pop before
                # same-time frees), so only a genuine >1.5-slot lag pads
                due = st.anchor + (st.delivered + 1.5) * period
                if t >= due - 1e-12:
                    # collection fell behind target * elapsed: pad with one
                    # phantom (the flat injector's deficit-padding expressed
                    # causally), then resync the anchor so the stage is
                    # considered paid-up through now — old deficit is
                    # forgiven rather than burst-injected, and total
                    # arrivals stay rate-limited at the target.  A stage
                    # with queued real batches gets no phantoms: idle-slot
                    # filling must not eat the capacity that drains backlog
                    if (st.has_space and not st.parked and st.machines
                            and not st.service_backlog):
                        st.stats.phantom += 1
                        if obs is not None:
                            obs.phantom(t, m)
                        st.deliver(Instance(-1, t), t, push)
                    else:
                        # full stage: go dormant instead of re-pushing — a
                        # self-perpetuating chain would keep the heap alive
                        # forever while the wedged stage waits for the
                        # quiescence flush that only an empty heap triggers;
                        # the next delivery revives the chain (deliver_to)
                        st.phantom_paused = True
                        continue
                    st.anchor = t - st.delivered * period
                    push(t + period, _K_ARRIVE, None, ("phantom", m, st.phantom_token))
                else:
                    # real arrivals kept the collect rate at target: check
                    # again when the next slot comes due
                    push(due, _K_ARRIVE, None, ("phantom", m, st.phantom_token))
        elif kind == _K_FREE:
            # collect every machine-free at this instant before delivering,
            # so cross-machine outputs land downstream in frame order
            frees = [(stage_name, payload[0])]
            nxt = heap.peek()
            while nxt is not None and nxt[0] == t and nxt[1] == _K_FREE:
                heap.pop()
                frees.append((nxt[3], nxt[4][0]))
                nxt = heap.peek()
            if faults is not None:
                # fence dead machines: a fenced core's "completion" never
                # happened (its members are re-queued at the failure
                # verdict); live completions advance the watchdog heartbeat
                # and clear any straggler suspicion
                live = []
                for m, mid in frees:
                    core = stages[m].cores.get(mid)
                    if core is None or core.failed:
                        continue
                    core.n_done += 1
                    faults.clear(m, mid)
                    live.append((m, mid))
                frees = live
                if not frees:
                    continue
            entries = []
            finished: list[tuple[str, int, int]] = []
            for m, mid in frees:
                st = stages[m]
                members = st.in_service.pop(mid)
                for inst in members:
                    if not inst.real:
                        continue
                    f = inst.frame
                    st.stats.latencies.append(t - inst.ready)
                    pend[m][f] -= 1
                    pend_total[m] -= 1
                    fm = finish[m]
                    fm[f] = t if math.isnan(fm[f]) else max(fm[f], t)
                    if pend[m][f] == 0:
                        finished.append((m, mid, f))
            for m, mid, f in finished:
                stage_resolved(m, f, float(finish[m][f]), True, entries, (m, mid))
            deliver_entries(entries, t)
            # two passes: free every machine whose outputs fully delivered,
            # THEN drain backpressured stages.  A drain can unblock (free +
            # restart) a machine whose own free event sits in this very
            # batch — freeing it again afterwards would double-start it.
            for m, mid in frees:
                st = stages[m]
                if blocked.get((m, mid), 0) == 0:
                    st.cores[mid].free(t)
                    st.start_next(mid, t, push)
                # else: outputs parked downstream — the machine stays busy
                # until the backpressured stage drains (see unblock)
            for m, mid in frees:
                drain_parked(stages[m], t)
            for m in {m for m, _ in frees}:
                # a free may have cleared the service backlog that paused
                # the stage's phantom chain — the last real tail batch can
                # only fill if the chain comes back without a new delivery
                revive_phantoms(stages[m], t)
        elif kind == _K_FLUSH:
            st = stages[stage_name]
            mid, token = payload
            core = st.cores.get(mid)  # None: the core retired after a drain
            if core is not None and token == core.token and core.buf:
                st.close(mid, batch_ready=t, now=t, push=push, cause="deadline")
                drain_parked(st, t)
        elif kind == _K_FAULT:
            what = payload[0]
            if what == "inject":
                if issued >= n_frames:
                    continue  # stream fully issued: the injector retires
                inject_fault(payload[1], t)
                nf = faults.next_fault(t)
                if nf is not None:
                    push(nf[0], _K_FAULT, None, ("inject", nf[1]))
            elif what == "watchdog":
                _, mid, seq, done_at_arm = payload
                m = stage_name
                st = stages[m]
                core = st.cores.get(mid)
                if core is None or (m, mid) in faults.dead:
                    continue  # retired, or verdict already delivered
                if not core.failed and all(mc.mid != mid for mc in st.machines):
                    # a *healthy* machine drained out of dispatch serves its
                    # queue to completion: unwatched.  A crashed one stays
                    # watched even after an epoch swap retires it — its
                    # stranded members still need the failure verdict to be
                    # reclaimed and re-queued.
                    continue
                if core.n_done >= seq:
                    faults.clear(m, mid)  # heartbeat satisfied in time
                elif core.n_done > done_at_arm:
                    # progress since arming — the watched batch is queued
                    # behind earlier work, not stuck: extend the deadline
                    push(
                        t + wd_k * core.machine.config.duration,
                        _K_FAULT, m, ("watchdog", mid, seq, core.n_done),
                    )
                elif faults.escalate(m, mid) == "suspect":
                    if obs is not None:
                        obs.suspect(t, m, mid)
                    push(
                        t + wd_k * core.machine.config.duration,
                        _K_FAULT, m, ("watchdog", mid, seq, core.n_done),
                    )
                else:  # second missed heartbeat while suspect: dead
                    declare_dead(m, mid, t)
            else:  # "recover": a straggler's transient slowdown expires
                faults.slow.pop((stage_name, payload[1]), None)
        else:  # _K_EPOCH: control-plane boundary (after same-instant events)
            if payload is not None and payload[0] == "relax":
                # mid-epoch staleness tick: when arrivals run well below the
                # active plan's provisioned rate, re-resolve every stage's
                # flush deadlines with the collect rate scaled to observed
                # (open batches keep their members and arming instants)
                relax_armed = False
                if issued >= n_frames:
                    continue  # the tick chain retires with the stream
                if control.on_tick(t) is not None:
                    for m in topo:
                        st = stages[m]
                        st.retime(control.relax_timeout(m, st.machines), t, push)
                if heap:
                    push(t + relax_every, _K_EPOCH, None, ("relax",))
                    relax_armed = True
                continue
            epoch_armed = False
            if issued >= n_frames:
                continue  # stream fully issued: the epoch chain retires,
                #           end-of-stream quiescence proceeds untouched
            updates = control.on_epoch(t)
            if obs is not None:
                # flush the closing window's metrics under the machine set
                # that served it (the swap below applies the next window's)
                obs.epoch(
                    t, control.history[-1],
                    {m: len(stages[m].machines) for m in topo},
                )
            if updates:
                for m, upd in updates.items():
                    stages[m].apply_update(upd, t, push)
                for m in updates:
                    # swapped-in machines are idle: parked/backpressured
                    # deliveries may proceed immediately
                    drain_parked(stages[m], t)
            if heap:
                push(control.next_epoch(t), _K_EPOCH, None, None)
                epoch_armed = True
            # an otherwise-empty heap means the run is wedged on a partial
            # batch that only the quiescence flush (which requires an empty
            # heap) can resolve: let the chain lapse; the flush re-arms it

    return ft.finalize(dag, {m: stages[m].stats for m in topo}, attempts)
