"""Application DAGs (series-parallel) and sessions/workloads.

Paper Sec. III-A terminology: a *session* = one DNN-based application
registration = (DAG of modules, per-module request rate, end-to-end latency
objective).  We represent DAGs as series-parallel (SP) trees — every paper
workload (traffic/face/pose/caption/actdet pipelines) is series-parallel —
which both the latency-splitting heuristics and the exact Pareto-DP brute
force exploit.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping, Union

SP = Union["Leaf", "Series", "Par"]


@dataclass(frozen=True)
class Leaf:
    name: str


@dataclass(frozen=True)
class Series:
    parts: tuple[SP, ...]


@dataclass(frozen=True)
class Par:
    parts: tuple[SP, ...]


def series(*parts: SP) -> Series:
    return Series(tuple(parts))


def par(*parts: SP) -> Par:
    return Par(tuple(parts))


def _leaves(sp: SP) -> list[str]:
    if isinstance(sp, Leaf):
        return [sp.name]
    out: list[str] = []
    for p in sp.parts:
        out.extend(_leaves(p))
    return out


def _sources(sp: SP) -> list[str]:
    if isinstance(sp, Leaf):
        return [sp.name]
    if isinstance(sp, Series):
        return _sources(sp.parts[0])
    return [s for p in sp.parts for s in _sources(p)]


def _sinks(sp: SP) -> list[str]:
    if isinstance(sp, Leaf):
        return [sp.name]
    if isinstance(sp, Series):
        return _sinks(sp.parts[-1])
    return [s for p in sp.parts for s in _sinks(p)]


def _edges(sp: SP) -> list[tuple[str, str]]:
    if isinstance(sp, Leaf):
        return []
    out: list[tuple[str, str]] = []
    for p in sp.parts:
        out.extend(_edges(p))
    if isinstance(sp, Series):
        for a, b in zip(sp.parts, sp.parts[1:]):
            for u in _sinks(a):
                for v in _sources(b):
                    out.append((u, v))
    return out


def sp_latency(sp: SP, weight: Mapping[str, float] | Callable[[str], float]) -> float:
    """End-to-end (longest-path) latency with per-module weights.

    The recursive reference; `AppDAG.latency` evaluates the same tree via a
    precompiled postorder program (`compile_sp`, bit-equal by construction)
    so hot callers pay no per-call recursion or isinstance dispatch.
    """
    w = weight if callable(weight) else weight.__getitem__
    if isinstance(sp, Leaf):
        return w(sp.name)
    if isinstance(sp, Series):
        return sum(sp_latency(p, weight) for p in sp.parts)
    return max(sp_latency(p, weight) for p in sp.parts)


# postorder program opcodes (`compile_sp` / `sp_latency_program`)
_OP_LEAF, _OP_SERIES, _OP_PAR = 0, 1, 2


def compile_sp(sp: SP) -> "tuple[tuple[int, object], ...]":
    """Flatten an SP tree into a postorder evaluation program.

    The program is a tuple of ``(opcode, arg)`` pairs: ``LEAF`` pushes the
    module's weight, ``SERIES``/``PAR`` pop their ``arg`` most recent child
    values and push the sum/max.  Children appear left-to-right, so an
    explicit-stack evaluation performs float additions and max-comparisons
    in exactly the order `sp_latency`'s recursion does — the two are
    bit-equal, not merely close (pinned by ``tests/test_dag``).
    """
    prog: list[tuple[int, object]] = []
    stack: list[tuple[SP, bool]] = [(sp, False)]
    while stack:
        node, visited = stack.pop()
        if isinstance(node, Leaf):
            prog.append((_OP_LEAF, node.name))
        elif visited:
            op = _OP_SERIES if isinstance(node, Series) else _OP_PAR
            prog.append((op, len(node.parts)))
        else:
            stack.append((node, True))
            for p in reversed(node.parts):
                stack.append((p, False))
    return tuple(prog)


def sp_latency_program(
    prog: "tuple[tuple[int, object], ...]",
    weight: Mapping[str, float] | Callable[[str], float],
) -> float:
    """Evaluate a `compile_sp` program (see there for the bit-equality
    contract with `sp_latency`)."""
    w = weight if callable(weight) else weight.__getitem__
    vals: list[float] = []
    for op, arg in prog:
        if op == _OP_LEAF:
            vals.append(w(arg))
        else:
            i = len(vals) - arg
            combined = sum(vals[i:]) if op == _OP_SERIES else max(vals[i:])
            del vals[i:]
            vals.append(combined)
    return vals[0]


def sp_critical_masks(
    sp: SP, sojourn: Mapping[str, "np.ndarray"]
) -> tuple["np.ndarray", dict[str, "np.ndarray"]]:
    """Vectorized per-sample longest-path decomposition over the SP tree.

    ``sojourn[m]`` is an array of per-sample latency contributions (one entry
    per frame; NaN where the frame never traversed ``m``).  Returns
    ``(latency, masks)``: the realized critical-path latency per sample and a
    per-module boolean mask marking membership on that sample's critical path
    — the per-frame traversal state the pipelined co-simulation attributes
    budget overruns with.  Identity: ``latency == sum_m sojourn[m] * masks[m]``
    (NaN-traversal entries excluded), because a Series keeps every member on
    the path while a Par keeps only the argmax branch.
    """
    import numpy as np

    if isinstance(sp, Leaf):
        s = np.asarray(sojourn[sp.name], dtype=np.float64)
        return s, {sp.name: ~np.isnan(s)}
    if isinstance(sp, Series):
        parts = [sp_critical_masks(p, sojourn) for p in sp.parts]
        lat = parts[0][0].copy()
        masks: dict[str, "np.ndarray"] = dict(parts[0][1])
        for p_lat, p_masks in parts[1:]:
            lat = lat + p_lat
            masks.update(p_masks)
        return lat, masks
    # Par: the argmax branch carries the path; ties go to the earliest part
    # (matching `sp_latency`'s max). NaN branches (never traversed) lose.
    parts = [sp_critical_masks(p, sojourn) for p in sp.parts]
    stack = np.stack([np.where(np.isnan(p[0]), -np.inf, p[0]) for p in parts])
    arg = np.argmax(stack, axis=0)
    lat = np.max(stack, axis=0)
    lat = np.where(np.isinf(lat), np.nan, lat)
    masks = {}
    for i, (_, p_masks) in enumerate(parts):
        on = arg == i
        for m, pm in p_masks.items():
            masks[m] = pm & on
    return lat, masks


def sp_depth(sp: SP) -> int:
    """Number of modules on the longest chain (for Clipper's even split)."""
    if isinstance(sp, Leaf):
        return 1
    if isinstance(sp, Series):
        return sum(sp_depth(p) for p in sp.parts)
    return max(sp_depth(p) for p in sp.parts)


def topo_sort(
    nodes: Iterable[str], edges: Iterable[tuple[str, str]]
) -> list[str]:
    """Kahn's algorithm over an explicit edge list, O(V + E).

    Deterministic: among ready nodes the one earliest in ``nodes`` order is
    emitted first (matching the legacy first-fit scan of the serving engine).
    Raises ``ValueError`` on a cycle, naming the nodes left unordered.
    """
    order = list(nodes)
    index = {m: i for i, m in enumerate(order)}
    indeg = {m: 0 for m in order}
    children: dict[str, list[str]] = {m: [] for m in order}
    for u, v in edges:
        if u not in indeg or v not in indeg:
            raise ValueError(f"edge ({u}, {v}) references unknown node")
        indeg[v] += 1
        children[u].append(v)
    ready = [index[m] for m in order if indeg[m] == 0]
    heapq.heapify(ready)
    out: list[str] = []
    while ready:
        m = order[heapq.heappop(ready)]
        out.append(m)
        for c in children[m]:
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(ready, index[c])
    if len(out) != len(order):
        stuck = sorted(set(order) - set(out))
        raise ValueError(f"cycle in DAG: unordered nodes {stuck}")
    return out


@dataclass(frozen=True)
class AppDAG:
    name: str
    sp: SP
    modules: tuple[str, ...] = field(default=())

    def __post_init__(self):
        object.__setattr__(self, "modules", tuple(_leaves(self.sp)))
        # latency() runs in allocator/control hot loops: evaluate the SP
        # tree through a precompiled postorder program instead of per-call
        # recursion (bit-equal to `sp_latency` — see `compile_sp`)
        object.__setattr__(self, "_latency_prog", compile_sp(self.sp))

    @property
    def edges(self) -> list[tuple[str, str]]:
        return _edges(self.sp)

    def parents(self, m: str) -> frozenset[str]:
        return frozenset(u for u, v in self.edges if v == m)

    def children(self, m: str) -> frozenset[str]:
        return frozenset(v for u, v in self.edges if u == m)

    def sibling_groups(self) -> list[tuple[str, ...]]:
        """Module groups sharing the same parents AND children (node merger)."""
        buckets: dict[tuple[frozenset, frozenset], list[str]] = {}
        for m in self.modules:
            buckets.setdefault((self.parents(m), self.children(m)), []).append(m)
        return [tuple(v) for v in buckets.values() if len(v) > 1]

    def topo_order(self) -> list[str]:
        return topo_sort(self.modules, self.edges)

    def ancestor_closure(self) -> dict[str, set[str]]:
        """Per-module transitive ancestor sets, built in one topo pass.

        Shared by the pipelined core's quiescence gating and the segment
        fast-path's causal-boundary check — both must agree on what counts
        as "upstream" or their tail-flush orderings desynchronize.
        """
        out: dict[str, set[str]] = {}
        for m in self.topo_order():
            anc: set[str] = set()
            for p in self.parents(m):
                anc.add(p)
                anc |= out[p]
            out[m] = anc
        return out

    def latency(self, weights: Mapping[str, float]) -> float:
        return sp_latency_program(self._latency_prog, weights)

    @property
    def depth(self) -> int:
        return sp_depth(self.sp)


@dataclass(frozen=True)
class Workload:
    """One session: an app DAG, per-module request rates, and a latency SLO."""

    app: AppDAG
    rates: Mapping[str, float]
    slo: float
    tag: str = ""

    def __post_init__(self):
        missing = set(self.app.modules) - set(self.rates)
        if missing:
            raise ValueError(f"rates missing for modules {missing}")
