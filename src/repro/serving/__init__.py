from .engine import ServeResult, ServingEngine
from .simulator import SimResult, simulate

__all__ = ["ServeResult", "ServingEngine", "SimResult", "simulate"]
