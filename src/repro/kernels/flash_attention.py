"""Pallas TPU flash-attention (prefill/train): blocked online-softmax GQA.

Tiling: grid = (B * Hq, Sq / BQ, Sk / BK); the KV dimension is the innermost
(sequential / "arbitrary") grid axis so the (BQ, Dv) f32 accumulator, the
running max and the running denominator live in VMEM scratch across KV steps.
Q/K/V tiles are multiples of 128 on the lane dimension for the MXU; causal and
sliding-window masking skip fully-masked KV blocks via pl.when.

Oracle: `repro.kernels.ref.attention`.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    nk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[:] = jnp.zeros_like(acc_ref)
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level skip: fully-masked KV blocks do no work
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0].astype(jnp.float32)  # (BK, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[:] = l_ref[:] * alpha + jnp.sum(p, axis=1)
        acc_ref[:] = acc_ref[:] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[:] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:], 1e-30)[:, None]
        o_ref[0] = (acc_ref[:] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, Dk)
    k: jax.Array,  # (B, Sk, Hkv, Dk)
    v: jax.Array,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, Dk = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else Dk ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nk = Sk // bk

    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, Dk)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, Dk)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, Dv)

    def kv_index(bh, qi, ki):
        return ((bh // Hq) * Hkv + (bh % Hq) // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk, nk=nk
        ),
        grid=(B * Hq, Sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, Dk), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, Dk), kv_index),
            pl.BlockSpec((1, bk, Dv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, Dv).transpose(0, 2, 1, 3)
