"""Production meshes: 16x16 (one pod, 256 chips) and 2x16x16 (two pods).

Functions, not module-level constants, so importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, tp: int = 16):
    """Physical pods are fixed (256 chips each); the LOGICAL (data, model)
    factorization is per-model: small dense models want less tensor
    parallelism (fewer TP all-reduces) and more data parallelism."""
    assert 256 % tp == 0, tp
    dp = 256 // tp
    shape = (2, dp, tp) if multi_pod else (dp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run entrypoint must "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import"
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for tests (requires a matching host-device override)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh ('pod' included if present)."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
