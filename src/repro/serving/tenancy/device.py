"""Device-centric plan view: what each *physical* accelerator runs.

Harpagon's `Plan` is module-centric: every module owns a fractional
machine count per configuration, and nothing says which physical device a
fractional tail lives on.  That is the right view for the per-app planner
— and exactly the wrong one for paying the bill: you cannot rent 0.37 of
a device, so a dedicated per-app deployment pays ``ceil(machines)`` per
allocation and strands the residue.

The tenancy layer re-expresses a set of per-app plans as a
:class:`DevicePlan`: a list of :class:`Device`, each a physical
accelerator of one hardware class hosting one or two :class:`DeviceSlot`
(MPS-style co-location of module residues).  The view is *derived* —
every slot corresponds one-to-one to a machine of
`core.dispatch.machine_fractions` over the plan's allocations, so it
round-trips back to the module-centric machine multiset exactly — and
*diffable*: `diff_device_plans` yields the colocate/evict instants the
observability layer records when an epoch repack changes who shares a
device with whom.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Mapping

from ...core.profiles import Config

_EPS = 1e-9


@dataclass(frozen=True)
class DeviceSlot:
    """One module residue (or full cover) placed on a physical device.

    ``fraction`` is the capacity share of the device this slot occupies
    (1.0 = a full integer-cover machine; <1 = the fractional tail of an
    allocation).  ``mid`` is the machine id of the corresponding machine
    in the module's `expand_machines` order — the hook the shared pool
    uses to stretch exactly this machine's service durations.
    ``collect_rate`` is the rate the slot's batch fills at (the Theorem-1
    tail fill rate) and ``budget`` the module's latency budget; both are
    carried so the allocator's feasibility guard can re-evaluate WCL
    under interference without reaching back into the plan.
    """

    app: str
    module: str
    config: Config
    fraction: float
    mid: int
    rate: float = 0.0
    dummy: float = 0.0
    collect_rate: float = 0.0
    budget: float = float("inf")

    @property
    def key(self) -> tuple[str, str, int]:
        """Stable identity of the underlying machine: (app, module, mid)."""
        return (self.app, self.module, self.mid)


@dataclass(frozen=True)
class Device:
    """A physical accelerator hosting up to ``max_coresident`` slots."""

    did: int
    hardware: str
    unit_price: float
    slots: tuple[DeviceSlot, ...]
    dedicated: bool = False  # feasibility guard forced exclusivity

    @property
    def occupancy(self) -> float:
        return sum(s.fraction for s in self.slots)

    @property
    def shared(self) -> bool:
        return len(self.slots) > 1

    @property
    def cost(self) -> float:
        """A device is paid for whole, however little of it is occupied."""
        return self.unit_price

    def coresident(self, slot: DeviceSlot) -> float:
        """The OTHER tenants' occupancy — what slows ``slot`` down."""
        return max(0.0, self.occupancy - slot.fraction)


@dataclass(frozen=True)
class DevicePlan:
    """The whole pool: every physical device and what it runs.

    ``cost`` is the honest integer-device bill — the quantity the
    consolidation story minimizes.  ``version`` counts repacks (epoch
    arbitration bumps it), mirroring `Plan.version`.
    """

    devices: tuple[Device, ...]
    version: int = 0
    apps: tuple[str, ...] = ()

    @property
    def cost(self) -> float:
        return sum(d.cost for d in self.devices)

    @property
    def n_shared(self) -> int:
        return sum(1 for d in self.devices if d.shared)

    def occupancy(self) -> dict[int, float]:
        return {d.did: d.occupancy for d in self.devices}

    def slots_of(self, app: str) -> list[tuple[Device, DeviceSlot]]:
        return [
            (d, s) for d in self.devices for s in d.slots if s.app == app
        ]

    def module_machines(self, app: str) -> dict[str, list[tuple[Config, float]]]:
        """Round-trip to the module-centric view: per module, the machine
        multiset ``(config, capacity fraction)`` in machine-id order —
        comparable 1:1 against ``machine_fractions`` of the plan's
        allocations."""
        out: dict[str, list[tuple[Config, float, int]]] = {}
        for d in self.devices:
            for s in d.slots:
                if s.app == app:
                    out.setdefault(s.module, []).append(
                        (s.config, s.fraction, s.mid)
                    )
        return {
            m: [(c, f) for c, f, _ in sorted(rows, key=lambda r: r[2])]
            for m, rows in out.items()
        }

    def interference_factors(
        self, model, app: "str | None" = None
    ) -> dict[tuple[str, str, int], float]:
        """Per-machine slowdown factors under ``model`` (an
        `InterferenceModel`): ``(app, module, mid) -> factor`` for every
        slot sharing its device; slots alone on a device are omitted
        (factor 1.0 — bit-exact with the profiled duration)."""
        out: dict[tuple[str, str, int], float] = {}
        for d in self.devices:
            if not d.shared:
                continue
            for s in d.slots:
                if app is not None and s.app != app:
                    continue
                f = model.slowdown(d.coresident(s), d.hardware)
                if f > 1.0 + _EPS:
                    out[(s.app, s.module, s.mid)] = f
        return out

    def summary(self) -> str:
        lines = [
            f"device-plan v{self.version} apps={','.join(self.apps)}"
            f" devices={len(self.devices)} shared={self.n_shared}"
            f" cost={self.cost:.4g}"
        ]
        for d in self.devices:
            tag = " [shared]" if d.shared else (
                " [dedicated]" if d.dedicated else ""
            )
            lines.append(
                f"  dev{d.did}@{d.hardware} occ={d.occupancy:.3g}{tag}"
            )
            for s in d.slots:
                lines.append(
                    f"    {s.app}/{s.module} b{s.config.batch}"
                    f" frac={s.fraction:.3g} mid={s.mid}"
                )
        return "\n".join(lines)

    def diff(self, other: "DevicePlan") -> "DevicePlanDelta":
        return diff_device_plans(self, other)


def _placements(plan: DevicePlan) -> dict[tuple[str, str, int], tuple[int, tuple]]:
    """slot key -> (device id, frozenset of co-resident slot keys)."""
    out = {}
    for d in plan.devices:
        keys = [s.key for s in d.slots]
        for s in d.slots:
            partners = tuple(sorted(k for k in keys if k != s.key))
            out[s.key] = (d.did, partners)
    return out


@dataclass(frozen=True)
class DevicePlanDelta:
    """What an epoch repack changed, in observability-event terms.

    ``colocated``: slots that now share a device with a partner set they
    did not have before (new pairings — one ``colocate`` instant each).
    ``evicted``: slots that lost their shared placement (moved to a
    dedicated device, repartnered, or left the pool — one ``evict``
    instant each, recorded against the device they left).
    """

    version_from: int
    version_to: int
    cost_before: float
    cost_after: float
    colocated: tuple[tuple[int, tuple[str, str, int]], ...]
    evicted: tuple[tuple[int, tuple[str, str, int]], ...]

    @property
    def empty(self) -> bool:
        return not (self.colocated or self.evicted)

    def summary(self) -> str:
        head = (
            f"device-delta v{self.version_from}->v{self.version_to}"
            f" cost {self.cost_before:.4g}->{self.cost_after:.4g}"
        )
        lines = [head]
        for did, (app, module, mid) in self.colocated:
            lines.append(f"  colocate dev{did} <- {app}/{module}#{mid}")
        for did, (app, module, mid) in self.evicted:
            lines.append(f"  evict dev{did} -> {app}/{module}#{mid}")
        return "\n".join(lines)


def diff_device_plans(prev: DevicePlan, new: DevicePlan) -> DevicePlanDelta:
    """Pairing-level delta between two packings of the pool."""
    p0, p1 = _placements(prev), _placements(new)
    colocated = []
    evicted = []
    for key, (did, partners) in p1.items():
        if not partners:
            continue
        before = p0.get(key)
        if before is None or before[1] != partners:
            colocated.append((did, key))
    for key, (did, partners) in p0.items():
        if not partners:
            continue
        after = p1.get(key)
        if after is None or after[1] != partners:
            evicted.append((did, key))
    return DevicePlanDelta(
        version_from=prev.version,
        version_to=new.version,
        cost_before=prev.cost,
        cost_after=new.cost,
        colocated=tuple(sorted(colocated)),
        evicted=tuple(sorted(evicted)),
    )


__all__ = [
    "Device",
    "DevicePlan",
    "DevicePlanDelta",
    "DeviceSlot",
    "diff_device_plans",
]
