"""Overload-aware serving frontend: the layer between arrivals and dispatch.

The PR-1 simulator modeled a serving cluster that can only be driven *at*
its provisioned rate: dummy traffic was priced but never streamed, every
arrival was admitted no matter the backlog, and clients were open-loop.
This package adds the three frontend behaviors real inference clouds hinge
on, all opt-in via :class:`FrontendConfig` (the default reproduces PR-1 /
seed numbers exactly):

* **dummy streaming** (`.dummy`) — the plan's priced ``Alloc.dummy`` traffic
  is injected as phantom requests into batch formation, so dummy-padded
  plans hit their modeled WCL and ``timeout="budget"`` no longer needs a
  fill-time floor; phantom slots count toward batch fill but never toward
  latency/attainment statistics.
* **admission control** (`.admission`) — token-bucket or queue-depth
  shedding at ingress (per-app policies supported) bounds p99 under bursty
  overload at the price of an explicit, reported shed rate.
* **closed-loop clients** (`.clients`) — bounded in-flight frames per
  client with optional jittered retry-on-shed, run to a fixed point with
  the engine's simulated per-frame latencies.

Under the incremental control plane (``repro.serving.control``) the
frontend re-reads *per-epoch plan state* instead of run constants: an
admission policy bound to the provisioned rate follows each hot-swapped
plan (`AdmissionController.rebind`), and clients with ``backoff=None``
wait about one *live* modeled service round between shed retries.

Usage sketch::

    from repro.serving import ServingEngine
    from repro.serving.frontend import FrontendConfig, TokenBucket

    fe = FrontendConfig(dummies=True, admission=TokenBucket(burst=4))
    res = ServingEngine(plan).run(
        2000, frame_rate, arrivals="mmpp", timeout="budget", frontend=fe,
        offered_rate=1.3 * frame_rate,   # drive past provisioning
    )
    res.attainment, res.shed, res.p99    # shed frames count as SLO misses
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

from .admission import (
    AdmissionController,
    AdmissionPolicy,
    QueueDepth,
    TokenBucket,
    make_admission,
)
from .clients import ClosedLoopClients, closed_loop_ingress
from .dummy import merge_phantoms, phantom_times


@dataclass(frozen=True)
class FrontendConfig:
    """Frontend behavior knobs for one `ServingEngine.run`.

    The default instance is the identity frontend: no dummy streaming, admit
    everything, open-loop arrivals — bit-identical to running without one.

    ``burst_deadline`` (opt-in, meaningful with ``dummies=True`` and
    ``timeout="budget"``) extends each machine's flush deadline by one
    upstream batch-arrival quantum (`repro.serving.engine.plan_burst`) —
    the deadline-side mirror of the burst-aware WCL correction, closing the
    PR-4 finding where zero-slack deadlines downstream of batched stages
    flush partial batches on every straddled inter-completion gap and
    attainment collapses below 0.5 at 1.0x provisioning.
    """

    dummies: bool = False
    admission: "AdmissionPolicy | Mapping[str, AdmissionPolicy]" = None
    clients: ClosedLoopClients | None = None
    burst_deadline: bool = False


__all__ = [
    "AdmissionController",
    "AdmissionPolicy",
    "ClosedLoopClients",
    "FrontendConfig",
    "QueueDepth",
    "TokenBucket",
    "closed_loop_ingress",
    "make_admission",
    "merge_phantoms",
    "phantom_times",
]
