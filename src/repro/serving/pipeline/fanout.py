"""Per-frame fanout realization: deterministic accumulator or correlated draws.

The flat engine scales every module's instance stream by the fixed ratio
``rates[m] / frame_rate`` through a fractional accumulator
(`repro.serving.replay.expand_fanout`): frame *i*'s instance count at module
*m* depends only on its position in the module's ready order.  Real video
pipelines are not that regular — a busy detector frame yields many crops,
and it yields them for *every* downstream classifier at once (the
cross-sibling load correlation OCTOPINF and Edge-Assisted DNN Serving
measure dominating tail latency).  :class:`FanoutSpec` selects the regime:

* ``"deterministic"`` (default) — the accumulator, instance-stream-identical
  to the flat engine path, so the pipelined co-simulation cross-validates
  against the vectorized kernel bit-for-bit.
* ``"stochastic"`` — per-frame counts ``Poisson(phi_m * B[f, m])`` where the
  *busyness factor* mixes one mean-1 Gamma draw shared by the whole frame
  with an idiosyncratic per-module draw::

      B[f, m] = rho * G[f] + (1 - rho) * H[f, m]

  ``rho = correlation`` steers sibling coupling (1.0: a crowded frame loads
  every classifier at once; 0.0: independent module jitter) and ``cv`` is
  the busyness coefficient of variation.  Counts at *source* modules clamp
  to >= 1 — a frame must physically exist to enter the DAG.  All draws are
  seeded and drawn up front, so counts are position-independent and
  reproducible regardless of event interleaving.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, Mapping

import numpy as np


@dataclass(frozen=True)
class FanoutSpec:
    """How many module-level instances one frame spawns at each module."""

    mode: str = "deterministic"  # "deterministic" | "stochastic"
    cv: float = 0.5              # busyness coefficient of variation
    correlation: float = 1.0     # share of busyness common to the whole frame

    def __post_init__(self):
        if self.mode not in ("deterministic", "stochastic"):
            raise ValueError(f"unknown fanout mode {self.mode!r}")
        if self.cv < 0.0:
            raise ValueError("cv must be >= 0")
        if not 0.0 <= self.correlation <= 1.0:
            raise ValueError("correlation must lie in [0, 1]")


class AccumulatorFanout:
    """Stateful accumulator: the k-th frame *arriving at the stage* spawns
    ``floor(k * phi) - floor((k - 1) * phi)`` instances — exactly
    `expand_fanout`'s per-position semantics (including its exact-binary
    fast path for half-integer fanouts), so the pipelined co-simulation
    reproduces the flat engine's instance streams."""

    def __init__(self, phi: float):
        self.phi = float(phi)
        self._exact = float(2.0 * phi).is_integer()
        self._k = 0
        self._acc = 0.0

    def count(self, frame: int) -> int:
        self._k += 1
        if self._exact:
            return int(
                math.floor(self.phi * self._k) - math.floor(self.phi * (self._k - 1))
            )
        self._acc += self.phi
        c = int(self._acc)
        self._acc -= c
        return c


class DrawnFanout:
    """Pre-drawn per-frame counts (stochastic mode): position-independent."""

    def __init__(self, counts: np.ndarray):
        self.counts = np.asarray(counts, dtype=np.int64)

    def count(self, frame: int) -> int:
        return int(self.counts[frame])


def draw_counts(
    spec: FanoutSpec,
    n_frames: int,
    fanouts: Mapping[str, float],
    sources: Iterable[str],
    seed: int = 0,
) -> dict[str, np.ndarray]:
    """Draw correlated per-frame instance counts for every module.

    One shared busyness draw per frame plus one idiosyncratic draw per
    (frame, module), mixed by ``spec.correlation``; module means stay at
    ``fanouts[m]`` (sources slightly above, from the >= 1 clamp).
    """
    rng = np.random.default_rng(seed)
    modules = list(fanouts)
    if spec.cv <= 0.0:
        shared = np.ones(n_frames)
        own = np.ones((n_frames, len(modules)))
    else:
        k = 1.0 / (spec.cv * spec.cv)
        shared = rng.gamma(k, 1.0 / k, size=n_frames)
        own = rng.gamma(k, 1.0 / k, size=(n_frames, len(modules)))
    rho = spec.correlation
    src = set(sources)
    out: dict[str, np.ndarray] = {}
    for j, m in enumerate(modules):
        busy = rho * shared + (1.0 - rho) * own[:, j]
        counts = rng.poisson(fanouts[m] * busy).astype(np.int64)
        if m in src:
            counts = np.maximum(counts, 1)
        out[m] = counts
    return out


def make_stage_fanouts(
    spec: FanoutSpec,
    fanouts: Mapping[str, float],
    sources: Iterable[str],
    n_frames: int,
    seed: int = 0,
) -> dict[str, "AccumulatorFanout | DrawnFanout"]:
    """Resolve one per-stage fanout realizer for every module."""
    if spec.mode == "deterministic":
        return {m: AccumulatorFanout(phi) for m, phi in fanouts.items()}
    counts = draw_counts(spec, n_frames, fanouts, sources, seed)
    return {m: DrawnFanout(counts[m]) for m in fanouts}
