"""Ingress admission control: shed frames *before* they enter the pipeline.

Under overload (bursty MMPP arrivals at or above the provisioned rate) the
PR-1 simulator's queues — and therefore p99 — grow without bound, because
Harpagon paces machines with zero slack.  A real serving frontend sheds at
ingress instead ("No DNN Left Behind" / OCTOPINF): a bounded admitted rate
keeps queueing delay bounded, trading a shed-rate for a p99 guarantee.

Policies (resolved per app via :func:`make_admission`):

* ``None`` / ``"none"``      — admit everything (PR-1 behavior).
* :class:`TokenBucket`       — sustained ``rate`` frames/s with ``burst``
  bucket depth; admitted traffic over any window ``[t, t+w]`` is bounded by
  ``rate * w + burst``.
* :class:`QueueDepth`        — shed when a virtual ingress queue, draining at
  the provisioned frame rate, already holds ``depth`` frames (the classic
  bounded-buffer frontend).

Controllers are *stateful sequential* objects: `admit(t)` must be called in
non-decreasing time order (the engine feeds it the sorted arrival stream;
the closed-loop client simulation feeds it its own monotone event clock).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Mapping, Union

import numpy as np


@dataclass(frozen=True)
class TokenBucket:
    """Token-bucket shedding: ``rate`` frames/s sustained, ``burst`` depth.

    ``rate=None`` binds to the provisioned frame rate at engine time — the
    natural operating point: admit exactly what the plan paid machines for.
    """

    rate: float | None = None
    burst: float = 8.0


@dataclass(frozen=True)
class QueueDepth:
    """Bounded virtual ingress queue: shed when ``depth`` frames are waiting.

    The virtual queue drains FIFO at ``drain_rate`` (``None`` = provisioned
    frame rate), approximating the pipeline's first-stage service capacity.
    """

    depth: int = 16
    drain_rate: float | None = None


AdmissionPolicy = Union[None, str, TokenBucket, QueueDepth]


class AdmissionController:
    """Sequential admission over a time-ordered frame stream."""

    def __init__(self, policy: "TokenBucket | QueueDepth", frame_rate: float):
        if frame_rate <= 0:
            raise ValueError("frame_rate must be positive")
        self.policy = policy
        self.frame_rate = frame_rate
        if isinstance(policy, TokenBucket):
            self._rate = policy.rate if policy.rate is not None else frame_rate
            if self._rate <= 0 or policy.burst < 1.0:
                raise ValueError("token bucket needs rate>0 and burst>=1")
        elif isinstance(policy, QueueDepth):
            self._drain = (
                policy.drain_rate if policy.drain_rate is not None else frame_rate
            )
            if self._drain <= 0 or policy.depth < 1:
                raise ValueError("queue-depth needs drain_rate>0 and depth>=1")
        else:
            raise TypeError(f"unknown admission policy {policy!r}")
        # passive telemetry sink (`observability.Observability`): both
        # engine paths wire it — the flat path before shed_stream, the
        # pipelined loop before run_pipeline — so every admission denial
        # lands in the trace/metrics at decision resolution.  Closed-loop
        # interim denials the client will re-issue carry the distinct
        # "shed_retry" cause, so summing "shed" instants always equals
        # terminal sheds; the pipelined loop's terminal shed emit defers
        # to a wired controller to avoid double counts.  Survives
        # reset() — a reset clears admission state, not the observer.
        self.obs = None
        self.reset()

    def rebind(self, frame_rate: float) -> None:
        """Re-read the provisioned frame rate (control-plane plan hot-swap).

        Policies whose ``rate`` / ``drain_rate`` is ``None`` are bound to
        the *provisioned* rate; under an epoch-based control loop that rate
        is per-epoch plan state, not a run constant.  Rebinding preserves
        the live bucket level / virtual queue — only the refill / drain
        pace follows the new plan.  Explicit numeric policies are pinned by
        the operator and do not move.
        """
        if frame_rate <= 0:
            raise ValueError("frame_rate must be positive")
        self.frame_rate = frame_rate
        if isinstance(self.policy, TokenBucket):
            if self.policy.rate is None:
                self._rate = frame_rate
        elif self.policy.drain_rate is None:
            self._drain = frame_rate

    def reset(self) -> None:
        """Restore initial state (full bucket / empty queue)."""
        self.admitted = 0
        self.shed = 0
        if isinstance(self.policy, TokenBucket):
            self._tokens = float(self.policy.burst)
            self._last: float | None = None
        else:
            self._finish: deque[float] = deque()
            self._free = 0.0

    def admit(self, t: float, cause: str = "shed") -> bool:
        """Admit or shed one frame arriving at time ``t`` (non-decreasing).

        ``cause`` labels the observer instant emitted on denial — callers
        that will re-issue a denied frame pass a non-terminal cause.
        """
        if isinstance(self.policy, TokenBucket):
            if self._last is not None:
                self._tokens = min(
                    float(self.policy.burst),
                    self._tokens + (t - self._last) * self._rate,
                )
            self._last = t
            if self._tokens >= 1.0 - 1e-12:
                self._tokens -= 1.0
                self.admitted += 1
                return True
            self.shed += 1
            if self.obs is not None:
                self.obs.shed(t, cause)
            return False
        # queue depth: retire virtually-served frames, then check occupancy
        q = self._finish
        while q and q[0] <= t + 1e-12:
            q.popleft()
        if len(q) >= self.policy.depth:
            self.shed += 1
            if self.obs is not None:
                self.obs.shed(t, cause)
            return False
        self._free = max(self._free, t) + 1.0 / self._drain
        q.append(self._free)
        self.admitted += 1
        return True

    def admit_live(self, t: float, backlog: int, cause: str = "shed") -> bool:
        """Admit or shed against *live* pipeline state (event-interleaved).

        ``backlog`` is the caller-observed ingress occupancy at time ``t`` —
        the pipelined engine passes the number of source-stage *instances*
        waiting to start service (formation + queued + parked; equal to
        frames whenever the source fanout is 1, as in every seed app).  A
        :class:`TokenBucket` is purely time-based and behaves exactly like
        :meth:`admit`; a :class:`QueueDepth` policy compares this real
        occupancy against ``depth`` instead of its virtual drain-rate queue
        — the whole point of the pipelined co-simulation is that shedding
        reacts to actual instantaneous backlog rather than a modeled one
        (so the same ``depth`` is a *different*, more honest threshold than
        in the flat path's virtual queue).
        """
        if isinstance(self.policy, TokenBucket):
            return self.admit(t, cause)
        if backlog >= self.policy.depth:
            self.shed += 1
            if self.obs is not None:
                self.obs.shed(t, cause)
            return False
        self.admitted += 1
        return True

    def shed_stream(self, arrivals: np.ndarray) -> np.ndarray:
        """Vector form: boolean shed mask for a sorted arrival-time array."""
        return np.fromiter(
            (not self.admit(float(t)) for t in arrivals), dtype=bool, count=arrivals.size
        )


def make_admission(
    spec: "AdmissionPolicy | Mapping[str, AdmissionPolicy]",
    app_name: str,
    frame_rate: float,
) -> AdmissionController | None:
    """Resolve an admission spec (possibly a per-app mapping) to a controller.

    A mapping is keyed by app name with an optional ``"default"`` entry;
    string shorthands ``"none" | "token_bucket" | "queue_depth"`` select the
    default-parameter policies.
    """
    if isinstance(spec, Mapping):
        spec = spec.get(app_name, spec.get("default"))
    if spec is None or spec == "none":
        return None
    if isinstance(spec, str):
        try:
            spec = {"token_bucket": TokenBucket(), "queue_depth": QueueDepth()}[spec]
        except KeyError:
            raise ValueError(
                f"unknown admission policy {spec!r}; "
                "have none | token_bucket | queue_depth"
            )
    return AdmissionController(spec, frame_rate)
