"""Serving engine: executes a Harpagon Plan over a request stream.

Thin adapter over the unified simulation subsystem: arrival processes come
from `repro.serving.arrivals` (uniform / poisson / bursty MMPP / diurnal
trace), per-module batch replay runs on the numpy-vectorized kernel
(`repro.serving.replay`) in virtual time, and on the discrete-event core
(`repro.serving.events`) when real jitted executors are attached (wall-clock
measured, used by the end-to-end example).

Requests flow through the app DAG (Kahn toposort, `core.dag.topo_sort`) with
per-module *fanout* (a detector emits several crops per frame; a decoder
consumes every other frame): module m sees ``rates[m] / frame_rate``
instances per frame, exactly the rates the plan provisioned for.

Tail-batch semantics are real: with ``timeout`` set (seconds, or ``"budget"``
to derive a per-module collection deadline from the plan), partial batches
flush when their opener has waited that long — mid-stream under bursty
arrivals and at end of stream.  The default (``timeout=None, tail="flush"``)
reproduces the seed engine's numbers on uniform arrivals exactly (see
`repro.serving.reference`).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np

from ..core.dag import Workload, topo_sort
from ..core.dispatch import Machine, Policy, dispatch_runs, expand_machines
from ..core.harpagon import Plan
from .arrivals import make_arrivals
from .events import simulate_module_events
from .replay import ModuleReplay, expand_fanout, replay_module, runs_to_assignment


@dataclass
class ModuleStats:
    latencies: list[float] = field(default_factory=list)
    batches: int = 0
    dropped: int = 0

    @property
    def max_latency(self) -> float:
        return max(self.latencies) if self.latencies else 0.0


@dataclass
class ServeResult:
    e2e_latencies: list[float]
    module_stats: dict[str, ModuleStats]
    slo: float

    @property
    def attainment(self) -> float:
        if not self.e2e_latencies:
            return 1.0
        ok = sum(1 for l in self.e2e_latencies if l <= self.slo + 1e-9)
        return ok / len(self.e2e_latencies)

    @property
    def p99(self) -> float:
        s = sorted(self.e2e_latencies)
        return s[int(0.99 * (len(s) - 1))] if s else 0.0


class ServingEngine:
    def __init__(
        self,
        plan: Plan,
        *,
        executors: Mapping[str, Callable[[int], None]] | None = None,
        policy: Policy = Policy.TC,
    ):
        """``executors[module](batch_size)`` runs a real batched forward; when
        None the profiled config duration is used (virtual time)."""
        self.plan = plan
        self.executors = executors or {}
        self.policy = policy

    def run(
        self,
        n_frames: int,
        frame_rate: float,
        *,
        arrivals: "str | np.ndarray | Sequence[float]" = "uniform",
        seed: int = 0,
        timeout: "float | str | None" = None,
        tail: str = "flush",
    ) -> ServeResult:
        wl: Workload = self.plan.workload
        arrival = make_arrivals(arrivals, n_frames, frame_rate, seed=seed)
        # finish time of frame i at module m (0.0 = not processed / dropped)
        finish_at = {m: np.zeros(n_frames) for m in wl.app.modules}
        stats = {m: ModuleStats() for m in wl.app.modules}
        for m in topo_sort(wl.app.modules, wl.app.edges):
            parents = wl.app.parents(m)
            if parents:
                pf = np.stack([finish_at[p] for p in parents])
                ready = np.maximum(arrival, pf.max(axis=0))
                drop = (pf <= 0.0).any(axis=0)
            else:
                ready = np.asarray(arrival, dtype=np.float64)
                drop = np.zeros(n_frames, dtype=bool)
            fanout = wl.rates[m] / frame_rate
            self._run_module(
                m, ready, drop, fanout, finish_at[m], stats[m],
                timeout=timeout, tail=tail,
            )
        sinks = [m for m in wl.app.modules if not wl.app.children(m)]
        sf = np.stack([finish_at[s] for s in sinks])
        ok = (sf > 0).all(axis=0)
        e2e = (sf.max(axis=0) - arrival)[ok]
        return ServeResult(e2e.tolist(), stats, wl.slo)

    def _module_timeout(
        self, m: str, machines: "list[Machine]", timeout: "float | str | None"
    ) -> "float | None | dict[int, float]":
        """Resolve the batch-collection deadline for module ``m``.

        ``"budget"`` derives a per-machine deadline from the plan: each
        machine must flush early enough that collection + its own service
        duration still fits the module's latency budget.
        """
        if timeout is None or isinstance(timeout, (int, float)):
            return timeout
        if timeout == "budget":
            s = self.plan.schedules[m]
            # floor at the real-rate fill time: dummy-padded plans assume the
            # frontend injects phantom requests to speed collection, which the
            # engine does not simulate — flushing faster than real traffic can
            # fill a batch would silently overload the machine instead.  Under
            # TC a machine's batch is a consecutive slice of the stream (fills
            # at the whole module rate); under RR/DT it fills only at the
            # machine's own share of the traffic.
            tot = sum(mm.rate for mm in machines)
            def fill(mm: Machine) -> float:
                rate = s.rate
                if self.policy is not Policy.TC and tot > 0:
                    rate *= mm.rate / tot
                return mm.config.batch / max(rate, 1e-12)
            return {
                mm.mid: max(s.budget - mm.config.duration, fill(mm))
                for mm in machines
            }
        raise ValueError(f"unknown timeout spec {timeout!r}")

    def _run_module(
        self,
        m: str,
        ready: np.ndarray,
        drop: np.ndarray,
        fanout: float,
        finish_frame: np.ndarray,
        stats: ModuleStats,
        *,
        timeout: "float | str | None",
        tail: str,
    ) -> None:
        sched = self.plan.schedules[m]
        machines = expand_machines(list(sched.allocs))
        # expand frames into module-level request instances by fanout,
        # in ready order, skipping frames dropped upstream
        order = np.argsort(ready, kind="stable")
        frames = order[~drop[order]]
        instances = expand_fanout(frames, fanout)
        n = instances.size
        if n == 0:
            return
        ready_inst = ready[instances]
        runs = dispatch_runs(machines, n, self.policy)
        w = self._module_timeout(m, machines, timeout)
        ex = self.executors.get(m)
        if ex is None:
            rep = replay_module(machines, ready_inst, runs, timeout=w, tail=tail)
        else:
            def _measured(machine: Machine, _group: int) -> float:
                t0 = time.perf_counter()
                ex(machine.config.batch)
                return time.perf_counter() - t0

            finish, batches = simulate_module_events(
                machines,
                ready_inst,
                runs_to_assignment(runs, n),
                timeout=w,
                tail=tail,
                executor=_measured,
            )
            rep = ModuleReplay(finish, runs_to_assignment(runs, n), batches)
        done = rep.done
        stats.batches += rep.n_batches
        stats.dropped += int(n - done.sum())
        stats.latencies.extend((rep.finish[done] - ready_inst[done]).tolist())
        # frame finish = max over its instances (dropped instances contribute 0)
        np.maximum.at(finish_frame, instances[done], rep.finish[done])
