"""Synthetic-but-learnable LM data pipeline (deterministic, offline).

Token streams follow a random sparse bigram process: each token's successor
distribution concentrates on a few states, so a model can reduce loss well
below uniform entropy — enough to validate end-to-end training dynamics
without external corpora.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np
import jax.numpy as jnp


class BigramStream:
    def __init__(self, vocab: int, *, branching: int = 4, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        # each state transitions to `branching` successors with random weights
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        w = rng.random((vocab, branching)) + 0.1
        self.probs = w / w.sum(1, keepdims=True)
        self.rng = rng

    def sample(self, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq + 1), np.int32)
        state = self.rng.integers(0, self.vocab, size=batch)
        out[:, 0] = state
        for t in range(1, seq + 1):
            r = self.rng.random(batch)
            cum = np.cumsum(self.probs[state], axis=1)
            choice = (r[:, None] < cum).argmax(1)
            state = self.succ[state, choice]
            out[:, t] = state
        return out


def lm_batches(
    vocab: int,
    batch: int,
    seq: int,
    *,
    seed: int = 0,
    embeds_dim: int | None = None,
) -> Iterator[dict]:
    """Yields {'tokens', 'labels'} (or {'embeds', 'labels'} for stub frontends)."""
    stream = BigramStream(vocab, seed=seed)
    emb_rng = np.random.default_rng(seed + 1)
    table = (
        emb_rng.standard_normal((vocab, embeds_dim)).astype(np.float32) * 0.05
        if embeds_dim
        else None
    )
    while True:
        chunk = stream.sample(batch, seq)
        tokens, labels = chunk[:, :-1], chunk[:, 1:]
        if table is not None:
            yield {
                "embeds": jnp.asarray(table[tokens]),
                "labels": jnp.asarray(labels),
            }
        else:
            yield {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
