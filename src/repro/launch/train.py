"""Training launcher.

CPU-scale usage (runs real steps on reduced configs):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --batch 8 --seq 64

Production usage (requires a real TPU mesh; on CPU use --dry-run, which
lowers/compiles only — see repro.launch.dryrun for the full sweep):
  python -m repro.launch.train --arch gemma-7b --shape train_4k --mesh 16x16
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_config
from ..data import lm_batches
from ..models import Model
from ..training import OptConfig, save, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if not args.smoke and jax.default_backend() == "cpu":
        raise SystemExit(
            "full configs need a TPU mesh; use --smoke on CPU or the dry-run "
            "(python -m repro.launch.dryrun) for lowering/compile validation"
        )
    model = Model(cfg)
    embeds_dim = cfg.d_model if cfg.input_mode == "embeds" else None
    batches = lm_batches(
        cfg.vocab_size, args.batch, args.seq, seed=args.seed, embeds_dim=embeds_dim
    )
    opt = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20), total_steps=args.steps)
    res = train(model, batches, args.steps, opt, seed=args.seed, log_every=args.log_every)
    if args.checkpoint:
        save(args.checkpoint, res.params)
        print(f"saved checkpoint to {args.checkpoint}")
    first, last = res.history[0]["loss"], res.history[-1]["loss"]
    print(f"final: loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
