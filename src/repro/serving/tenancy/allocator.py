"""Global allocator: one device pool, arbitrated across per-app plans.

Packing model
-------------
Every machine of every app's module-centric `Plan` (the
`machine_fractions` walk: integer covers first, fractional tail last)
becomes a :class:`DeviceSlot`.  Integer covers map one-to-one onto
dedicated devices — a full machine fills its device, nothing can join
it.  The fractional residues are where consolidation pays: they are
bin-packed **first-fit-decreasing** onto shared devices of the same
hardware class, at most ``max_coresident`` residues and total occupancy
at most ``occupancy_cap`` per device.

Feasibility guard
-----------------
A candidate co-location is admitted only if every affected app still
meets its end-to-end SLO with interference folded in.  For each slot on
the device (incumbents and newcomer alike) the profile row is inflated
by ``InterferenceModel.slowdown(coresident occupancy)`` and the slot
machine's Theorem-1 worst-case latency re-evaluated; the module's WCL
override (the max of the plan's WCL and every co-located machine's
inflated WCL) is then pushed through the app DAG's critical path, which
must stay within ``slo * slo_slack``.  Guarding at the e2e level rather
than per-module budget is deliberate: Harpagon's latency splitter drives
module budgets *fractionally tight* (budget == WCL for most modules), so
per-budget guarding would veto every co-location while the quantized
configuration cascade routinely leaves real end-to-end slack.  A residue
that would break (or be broken by) any app's SLO falls through to the
next bin and, when no bin takes it, opens its own device; residues whose
SLO cannot survive even a worst-case partner are marked ``dedicated``.

Epoch arbitration
-----------------
`GlobalAllocator.submit(app, plan)` is the control-plane entry point:
each app's `ControlRuntime` resubmits its freshly replanned module-centric
plan every epoch; the allocator repacks the whole pool against the latest
plan of every tenant and returns the new `DevicePlan` plus the
colocate/evict delta the observability layer records.  Packing is a pure
function of the submitted plans, so a repack with unchanged plans is a
no-op delta.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping

import numpy as np

from ...core.dispatch import (
    Alloc,
    ConfigArrays,
    Policy,
    config_wcl_batch,
    machine_fractions,
)
from ...core.harpagon import Plan
from ...profiling.interference import InterferenceModel
from .device import Device, DevicePlan, DevicePlanDelta, DeviceSlot, diff_device_plans

_EPS = 1e-9


def dedicated_cost(plans: "Mapping[str, Plan]") -> float:
    """The integer-device bill of per-app exclusive deployments.

    Fractional machine counts are what the planner's cost model charges
    (frame-rate proportionality), but a dedicated deployment must round
    every allocation up to whole devices — this is the baseline the
    shared pool is measured against."""
    total = 0.0
    for plan in plans.values():
        for s in plan.schedules.values():
            for a in s.allocs:
                total += math.ceil(a.machines - _EPS) * a.config.unit_price
    return total


def _tail_fill_rate(a: Alloc, allocs: "tuple[Alloc, ...]", frac: float,
                    policy: Policy) -> float:
    """The rate the machine's batch collects at — `module_wcl`'s algebra
    for the machine the slot corresponds to."""
    if policy is Policy.TC:
        w = sum(
            x.collect_rate for x in allocs
            if x.eff_ratio <= a.eff_ratio + _EPS
        )
        if a.dummy > _EPS:
            w = max(w, a.collect_rate)
        return w
    if policy in (Policy.RR, Policy.DT):
        if frac < 1.0 - 1e-12:
            return frac * a.cap + a.dummy
        if a.derate < 1.0 - 1e-12:
            return a.cap
        return a.config.throughput
    return a.config.throughput  # DT_OPT: d + b/t for every machine


def plan_slots(app: str, plan: Plan) -> "tuple[list[DeviceSlot], list[DeviceSlot]]":
    """All machines of ``plan`` as device slots: (integer covers, residues).

    Slot ``mid`` is the machine id in the module's `expand_machines`
    order — the id the pipelined stages address, so interference factors
    land on exactly the machine that is actually co-located."""
    policy = plan.options.policy
    full: list[DeviceSlot] = []
    resid: list[DeviceSlot] = []
    for m, s in plan.schedules.items():
        allocs = tuple(s.allocs)
        for mid, (a, frac) in enumerate(machine_fractions(list(allocs))):
            slot = DeviceSlot(
                app=app,
                module=m,
                config=a.config,
                fraction=frac,
                mid=mid,
                rate=frac * a.cap,
                dummy=a.dummy if frac < 1.0 - 1e-12 else 0.0,
                collect_rate=_tail_fill_rate(a, allocs, frac, policy),
                budget=s.budget,
            )
            (full if frac >= 1.0 - 1e-12 else resid).append(slot)
    return full, resid


@dataclass
class AllocatorConfig:
    """Packing knobs for the :class:`GlobalAllocator`."""

    interference: "InterferenceModel | None" = None
    max_coresident: int = 2      # MPS-style pairing; >2 needs a braver model
    occupancy_cap: float = 1.0   # total capacity fraction a device can host
    guard: bool = True           # enforce e2e SLOs under interference
    slo_slack: float = 1.0       # inflated e2e must stay <= slo * slo_slack

    def __post_init__(self):
        if self.max_coresident < 1:
            raise ValueError("max_coresident must be >= 1")
        if not 0.0 < self.occupancy_cap <= 1.0:
            raise ValueError("occupancy_cap must be in (0, 1]")
        if self.slo_slack <= 0.0:
            raise ValueError("slo_slack must be positive")


class GlobalAllocator:
    """FFD bin-packing of plan residues with an e2e-SLO feasibility guard."""

    def __init__(self, cfg: "AllocatorConfig | None" = None):
        self.cfg = cfg or AllocatorConfig()
        self.plans: dict[str, Plan] = {}
        self.version = 0
        self.device_plan: "DevicePlan | None" = None
        # per-(app, module) committed WCL override under the current packing
        self._wcl: dict[tuple[str, str], float] = {}

    # -- guard ---------------------------------------------------------------

    def _inflated_wcls(
        self, slots: "list[DeviceSlot]", occ: float
    ) -> "list[float]":
        """Theorem-1 WCLs of ``slots`` co-resident on one device at total
        occupancy ``occ``, each inflated by the interference model at its
        partners' occupancy (``occ - fraction``).  One batched
        `config_wcl_batch` call per dispatch policy present (apps can run
        different policies), instead of a scalar `config_wcl` per slot."""
        model = self.cfg.interference
        out = [0.0] * len(slots)
        by_policy: "dict[Policy, list[int]]" = {}
        for i, s in enumerate(slots):
            pol = self.plans[s.app].options.policy
            by_policy.setdefault(pol, []).append(i)
        for policy, idxs in by_policy.items():
            cfgs = tuple(
                slots[i].config
                if model is None
                else model.inflate(slots[i].config, occ - slots[i].fraction)
                for i in idxs
            )
            rates = np.array([slots[i].collect_rate for i in idxs])
            wcls = config_wcl_batch(
                ConfigArrays.build(cfgs), policy, collect_rate=rates, full=False
            )
            for j, i in enumerate(idxs):
                out[i] = float(wcls[j])
        return out

    def _inflated_wcl(self, slot: DeviceSlot, coresident: float) -> float:
        return self._inflated_wcls([slot], coresident + slot.fraction)[0]

    def _e2e_ok(self, overrides: "dict[tuple[str, str], float]") -> bool:
        """Do the affected apps hold their SLO with these WCL overrides
        (on top of the already-committed ones)?"""
        for app in {a for a, _ in overrides}:
            plan = self.plans[app]
            wl = plan.workload
            wcls = {m: s.wcl for m, s in plan.schedules.items()}
            for (a, m), w in self._wcl.items():
                if a == app:
                    wcls[m] = max(wcls[m], w)
            for (a, m), w in overrides.items():
                if a == app:
                    wcls[m] = max(wcls[m], w)
            if wl.app.latency(wcls) > wl.slo * self.cfg.slo_slack + _EPS:
                return False
        return True

    def _fits(self, members: "list[DeviceSlot]", cand: DeviceSlot) -> bool:
        """Capacity + SLO check for ``cand`` joining ``members``."""
        c = self.cfg
        if len(members) + 1 > c.max_coresident:
            return False
        occ = sum(s.fraction for s in members) + cand.fraction
        if occ > c.occupancy_cap + _EPS:
            return False
        if not c.guard or c.interference is None:
            return True
        overrides: dict[tuple[str, str], float] = {}
        group = members + [cand]
        for s, w in zip(group, self._inflated_wcls(group, occ)):
            key = (s.app, s.module)
            overrides[key] = max(overrides.get(key, 0.0), w)
        return self._e2e_ok(overrides)

    def _commit(self, members: "list[DeviceSlot]") -> None:
        """Record the device's slots' inflated WCLs as committed overrides."""
        if not self.cfg.guard or self.cfg.interference is None:
            return
        occ = sum(s.fraction for s in members)
        if len(members) < 2:
            return
        for s, w in zip(members, self._inflated_wcls(members, occ)):
            key = (s.app, s.module)
            self._wcl[key] = max(self._wcl.get(key, 0.0), w)

    # -- packing -------------------------------------------------------------

    def pack(self, plans: "Mapping[str, Plan] | None" = None) -> DevicePlan:
        """Pack the latest plan of every tenant into a fresh `DevicePlan`."""
        if plans is not None:
            self.plans.update(plans)
        self._wcl = {}
        full_all: list[DeviceSlot] = []
        residues: list[DeviceSlot] = []
        for app in sorted(self.plans):
            f, r = plan_slots(app, self.plans[app])
            full_all.extend(f)
            residues.extend(r)
        # integer covers: one dedicated, fully-occupied device each
        bins: list[list[DeviceSlot]] = [[s] for s in full_all]
        open_from = len(bins)  # bins below this index never take a partner
        # residues: first-fit-decreasing over open shared bins
        residues.sort(key=lambda s: (-s.fraction, s.key))
        for slot in residues:
            placed = False
            for i in range(open_from, len(bins)):
                members = bins[i]
                if members[0].config.hardware != slot.config.hardware:
                    continue
                if self._fits(members, slot):
                    members.append(slot)
                    self._commit(members)
                    placed = True
                    break
            if not placed:
                bins.append([slot])
        out: list[Device] = []
        for did, members in enumerate(bins):
            head = members[0]
            dedicated = False
            if (
                len(members) == 1
                and head.fraction < 1.0 - 1e-12
                and self.cfg.guard
                and self.cfg.interference is not None
            ):
                # the fallback marker: could this residue survive a
                # worst-case partner (one filling the device)?  If not,
                # the guard will keep it exclusive forever.
                worst = self.cfg.occupancy_cap - head.fraction
                w = self._inflated_wcl(head, worst)
                dedicated = not self._e2e_ok({(head.app, head.module): w})
            out.append(
                Device(
                    did=did,
                    hardware=head.config.hardware,
                    unit_price=head.config.unit_price,
                    slots=tuple(members),
                    dedicated=dedicated,
                )
            )
        self.device_plan = DevicePlan(
            devices=tuple(out),
            version=self.version,
            apps=tuple(sorted(self.plans)),
        )
        return self.device_plan

    # -- epoch arbitration ---------------------------------------------------

    def submit(
        self, app: str, plan: Plan
    ) -> "tuple[DevicePlan, DevicePlanDelta]":
        """One tenant's control loop hands in its freshly replanned plan;
        the pool repacks around it.  Returns the new device plan and the
        colocate/evict delta against the previous packing."""
        prev = self.device_plan
        if prev is None:
            prev = self.pack()
        self.plans[app] = plan
        self.version += 1
        new = self.pack()
        return new, diff_device_plans(prev, new)

    # -- failure recovery ----------------------------------------------------

    def fail_device(self, did: int) -> "tuple[DevicePlan, DevicePlanDelta]":
        """A physical device died: evict its slots and re-home them.

        Every slot the dead device hosted — the failed machine itself and
        any co-located residues that went down with it — is re-packed onto
        surviving capacity: first-fit over the surviving shared bins under
        the same capacity + e2e-SLO guard as :meth:`pack`, falling back to
        opening replacement devices (the pool pays for a new device
        exactly when no survivor can absorb the residue).  Committed WCL
        overrides are rebuilt from the surviving packing only, so a slot
        whose inflation came solely from the dead device stops being
        charged for it.  Device ids are renumbered densely (the delta
        records every move); an unknown ``did`` — a stale id from a plan
        the pool already repacked away — is a no-op returning an empty
        delta, since the device it named is already gone."""
        prev = self.device_plan
        if prev is None:
            prev = self.pack()
        dead = None
        survivors: list[Device] = []
        for d in prev.devices:
            if d.did == did:
                dead = d
            else:
                survivors.append(d)
        if dead is None:
            return prev, diff_device_plans(prev, prev)
        self.version += 1
        # rebuild the committed overrides from what actually survives
        self._wcl = {}
        bins = [list(d.slots) for d in survivors]
        # a surviving bin can take evictees only if it was openable in the
        # original packing: not an integer cover, not marked dedicated
        open_bin = [
            not d.dedicated and d.slots[0].fraction < 1.0 - 1e-12
            for d in survivors
        ]
        for members in bins:
            if len(members) >= 2:
                self._commit(members)
        evictees = sorted(dead.slots, key=lambda s: (-s.fraction, s.key))
        dedicated_flags = [d.dedicated for d in survivors]
        for slot in evictees:
            placed = False
            for i, members in enumerate(bins):
                if not (i < len(open_bin) and open_bin[i]):
                    continue
                if members[0].config.hardware != slot.config.hardware:
                    continue
                if self._fits(members, slot):
                    members.append(slot)
                    self._commit(members)
                    placed = True
                    break
            if not placed:
                bins.append([slot])
                dedicated_flags.append(False)
        out: list[Device] = []
        for new_did, members in enumerate(bins):
            head = members[0]
            out.append(
                Device(
                    did=new_did,
                    hardware=head.config.hardware,
                    unit_price=head.config.unit_price,
                    slots=tuple(members),
                    dedicated=dedicated_flags[new_did],
                )
            )
        self.device_plan = DevicePlan(
            devices=tuple(out),
            version=self.version,
            apps=tuple(sorted(self.plans)),
        )
        return self.device_plan, diff_device_plans(prev, self.device_plan)


__all__ = [
    "AllocatorConfig",
    "GlobalAllocator",
    "dedicated_cost",
    "plan_slots",
]
