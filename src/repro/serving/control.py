"""Incremental serving control plane: rate estimation, replanning, hot-swap.

Harpagon's planner derives one static plan for a fixed per-module rate, but
real arrival processes are diurnal and bursty: a single plan must be
provisioned for the peak and wastes machines the rest of the day — the
exact serving-cost inefficiency the paper targets, one level up.  This
module closes the loop (in the direction of OCTOPINF-style workload-aware
re-scheduling): a :class:`ControlRuntime` lives *inside* the pipelined
event loop, estimates the offered frame rate over a sliding window, calls
`Planner.replan` (warm-start incremental repair, versioned plans) at every
epoch boundary, and applies the resulting `PlanDelta` to the live stages
without dropping an in-flight frame:

* **drained machines finish their open batch** (closed at the swap instant)
  and their queued work, then retire from dispatch;
* **added machines join the dispatch walk immediately** — under
  ``timeout="budget"`` their flush deadlines come from the new schedule's
  per-rank remaining workloads (`dispatch.remaining_workloads`);
* **dummy streamers re-anchor** to the new provisioned collect rate;
* **admission controllers re-bind** their provisioned-rate policies to the
  new plan (`AdmissionController.rebind`), and closed-loop clients with
  ``backoff=None`` re-read the live plan's modeled latency on every retry.

Every epoch appends an :class:`EpochRecord` to :attr:`ControlRuntime.history`
(surfaced as ``ServeResult.epochs``), so a run's serving cost is auditable
as the time-integral of the active plan's cost — the quantity
``benchmarks.run --only diurnal_sweep`` compares against static peak
provisioning.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

from ..core.dispatch import Machine, expand_machines
from ..core.harpagon import Plan, Planner
from ..core.profiles import ModuleProfile
from ..profiling.measured import corrected_profiles, duration_scale, quantize_scale
from .frontend.admission import AdmissionController
from .pipeline.stages import StageUpdate


@dataclass(frozen=True)
class ControlLoopConfig:
    """Engine-facing knobs for ``ServingEngine.run(..., control=...)``.

    ``interval`` is the epoch length in simulated seconds; ``window`` the
    arrival-rate estimation window (default: one interval).  ``forecast``
    extrapolates the windowed estimate's trend one epoch ahead (two
    half-window rates -> slope), so a diurnal ramp is provisioned for where
    the rate *will be* when the next plan is live, not where it was half a
    window ago.  ``attack`` adds a fast-attack term on top of the (noise-
    damping, multi-interval) window: the estimate is floored at the trend
    estimate over just the most recent interval, so a ramp that turns
    *inside* the window (the post-trough climb a coarse epoch otherwise
    reads as a lull) is caught at attack speed while falls still release
    at the window's pace.  ``warmup`` fast-starts the epoch cadence: the
    first replans fire at ``interval / 2^warmup, ..., interval / 2``
    before the chain lands back on the regular grid, so an initial plan
    provisioned off-rate (cold start against a ramp, a miscalibrated
    profile) is repaired within a fraction of the first interval instead
    of a full one — at coarse epochs the uncorrected first interval is
    the dominant deadline-miss mode.  ``margin`` over-provisions on top (``target = est * (1 +
    margin)``) to absorb estimate noise and burn down backlog accumulated
    while under-provisioned.  ``tolerance`` / ``cost_guard`` are forwarded
    to `Planner.replan`.  ``floor`` bounds the estimate from below as a
    fraction of the initially provisioned frame rate, so a lull can never
    replan to a zero-machine cluster.

    ``correct_profiles`` folds measured batch durations (a trace/live
    `ServiceTimeSource` feeding :meth:`ControlRuntime.observe_service`)
    back into the profiles each epoch replans against: per-module
    measured/modeled duration scales, log-quantized at ``correction_tol``
    so estimator wobble cannot churn the replan cache (see
    `repro.profiling.measured`).

    The ``relax_*`` knobs govern mid-epoch transient-aware deadline
    relaxation (active only on the dummy-streaming ``timeout="budget"``
    path with burst-aware deadlines): when the observed arrival rate
    falls more than ``relax_tol`` below the rate the active plan
    provisioned, stage flush deadlines are re-resolved with the collect
    rate scaled down to the observed one (never below ``relax_floor``),
    so a stale plan stops deadline-flushing near-empty padded batches
    while it waits for the next replan epoch.  Checked every
    ``relax_every`` fraction of an epoch; ``relax=False`` disables the
    tick chain entirely.

    Promoted out of the ``experimental_`` prefix in PR 8: on steady
    arrival regimes the tick never fires and runs are bit-identical
    relax on/off (pinned by ``test_observability``), while on diurnal
    traces — including a production-shaped asymmetric day curve at
    9600-frame scale — relaxation cut total misses by up to 38% at
    coarse replan intervals (P/48: 493 vs 794 misses at seed 0,
    2162 vs 2558 at seed 1) and never measured worse.  The old
    ``experimental_relax*`` alias names were removed in PR 9 after
    their one-cycle deprecation window.
    """

    interval: float
    profiles: "Mapping[str, ModuleProfile] | None" = None
    window: "float | None" = None
    margin: float = 0.1
    forecast: bool = True
    attack: bool = True
    warmup: int = 2
    tolerance: float = 0.02
    cost_guard: float = 0.01
    floor: float = 0.3
    correct_profiles: bool = True
    correction_tol: float = 0.05
    relax: bool = True
    relax_tol: float = 0.1
    relax_floor: float = 0.3
    relax_every: float = 0.25
    # multi-tenant arbitration: called as ``on_swap(t, new_plan)`` after a
    # committed plan hot-swap, so a shared-pool allocator can repack the
    # device pool around this tenant's new module-centric plan (see
    # `serving.tenancy.SharedPool`); None = single-tenant, no arbitration
    on_swap: "Callable[[float, Plan], None] | None" = None

    def __post_init__(self):
        if self.interval <= 0.0:
            raise ValueError("control interval must be positive")
        if self.window is not None and self.window <= 0.0:
            raise ValueError("estimation window must be positive")
        if self.margin < 0.0:
            raise ValueError("margin must be >= 0")
        if self.warmup < 0 or self.warmup > 8:
            raise ValueError("warmup must be in [0, 8]")
        if not 0.0 < self.floor <= 1.0:
            raise ValueError("floor must be in (0, 1]")
        if self.correction_tol <= 0.0:
            raise ValueError("correction_tol must be positive")
        if not 0.0 < self.relax_floor <= 1.0:
            raise ValueError("relax_floor must be in (0, 1]")
        if self.relax_every <= 0.0:
            raise ValueError("relax_every must be positive")


@dataclass(frozen=True)
class EpochRecord:
    """One control-loop epoch, auditable: what was observed, what was done."""

    t: float                     # epoch boundary (simulated seconds)
    rate_est: float              # windowed offered frame-rate estimate
    target: float                # provisioned frame rate = est * (1 + margin)
    version: int                 # plan version active from t on
    cost: float                  # that plan's cost (serving-cost integrand)
    feasible: bool               # False: replan failed, previous plan kept
    swapped: bool                # True: a non-empty delta was applied
    actions: Mapping[str, str]   # per-module replan provenance
    machines_added: float = 0.0
    machines_drained: float = 0.0
    delta_summary: str = ""
    # model-vs-measured service-time audit (0.0 / empty without a measuring
    # ServiceTimeSource): mean relative |measured - modeled| over the
    # epoch's started batches, modeled = the ACTIVE plan's config duration
    duration_err: float = 0.0
    # per-module duration scales (vs the ORIGINAL profiles) the epoch's
    # replan ran under; only non-1.0 entries are recorded
    corrections: Mapping[str, float] = field(default_factory=dict)


def plan_e2e_hint(plan: Plan) -> float:
    """A finite, positive latency estimate for ``plan`` (SLO fallback).

    Used as the base for closed-loop clients' live retry backoff — shared
    by the engine (control off) and :attr:`ControlRuntime.e2e_hint` so the
    two paths can never diverge.
    """
    e = plan.e2e_latency
    if math.isfinite(e) and e > 0.0:
        return e
    return max(plan.workload.slo, 1e-3)


def serving_cost(history: Sequence[EpochRecord], horizon: float) -> float:
    """Time-averaged serving cost over ``[history[0].t, horizon]``.

    The active plan's cost integrates piecewise-constantly between epochs —
    the honest trajectory metric a periodic replanner is buying down
    against a static peak plan's flat ``cost * horizon``.
    """
    if not history:
        return math.nan
    total = 0.0
    for rec, t_next in zip(
        history, [r.t for r in history[1:]] + [max(horizon, history[-1].t)]
    ):
        total += rec.cost * max(0.0, t_next - rec.t)
    span = max(horizon, history[-1].t) - history[0].t
    return total / span if span > 0 else history[-1].cost


class ControlRuntime:
    """The live control plane driven by the pipelined event loop.

    The loop calls :meth:`observe` for every offered frame and
    :meth:`on_epoch` at each ``_K_EPOCH`` event; the runtime returns the
    per-stage :class:`StageUpdate` mapping to apply (or ``None`` when the
    replanned schedule is unchanged / infeasible).  ``timeout_of`` resolves
    a new schedule's flush deadlines exactly like the engine resolved the
    initial ones, so swapped-in machines inherit the same ``"budget"``
    semantics (per-rank remaining-workload floors included).
    """

    def __init__(
        self,
        cfg: ControlLoopConfig,
        plan: Plan,
        profiles: Mapping[str, ModuleProfile],
        frame_rate: float,
        *,
        timeout_of: Callable[[object, "list[Machine]", Plan], "float | None | dict"],
        dummies: bool = False,
        admission: "AdmissionController | None" = None,
        relax: bool = False,
    ):
        if frame_rate <= 0.0:
            raise ValueError("frame_rate must be positive")
        self.cfg = cfg
        self.planner = Planner(plan.options)
        self.plan = plan
        self.profiles = profiles
        self.frame_rate0 = frame_rate
        wl = plan.workload
        self.fanouts = {m: wl.rates[m] / frame_rate for m in wl.app.modules}
        self.timeout_of = timeout_of
        self.dummies = dummies
        self.admission = admission
        # transient-aware deadline relaxation is an engine-side gate: it
        # only makes sense on the dummy-streaming "budget"-deadline path
        # whose deadlines assume the provisioned collect rate
        self.relax_enabled = bool(relax) and cfg.relax
        self._relax_scale = 1.0
        # measured service durations (ServiceTimeSource observer feed):
        # sliding per-module (original-modeled, measured) pairs for the
        # correction estimator, plus per-epoch error accumulators against
        # the ACTIVE plan's modeled durations
        self._svc_win: dict[str, deque] = {
            m: deque(maxlen=256) for m in wl.app.modules
        }
        self._orig_dur = {
            (m, c.batch, c.hardware): c.duration
            for m, p in profiles.items()
            for c in p.configs
        }
        self._err_sum = 0.0
        self._err_n = 0
        self.scales: dict[str, float] = {}
        self._issues: deque[float] = deque()
        self._warmup_sched: "deque[float] | None" = None
        self.history: list[EpochRecord] = [
            EpochRecord(
                t=0.0,
                rate_est=frame_rate,
                target=frame_rate,
                version=plan.version,
                cost=plan.cost,
                feasible=plan.feasible,
                swapped=False,
                actions=dict(plan.provenance),
            )
        ]

    @property
    def interval(self) -> float:
        return self.cfg.interval

    def next_epoch(self, t: float) -> float:
        """Absolute time of the epoch following ``t`` (event-loop arming).

        The first call anchors the fast-start ladder at ``t`` (the first
        real arrival): with ``warmup=w`` the early epochs fire at
        ``t + interval / 2^w, ..., t + interval / 2, t + interval`` —
        geometric, so a cold-start misprovision is repaired within a
        fraction of the first interval — and every later epoch returns to
        the plain ``t + interval`` cadence.  Monotonic by construction:
        ladder entries at or before ``t`` are skipped (the wedge-lapse
        re-arm path can ask from an arbitrary later instant).
        """
        if self._warmup_sched is None:
            self._warmup_sched = deque(
                t + self.cfg.interval / (1 << (self.cfg.warmup - k))
                for k in range(self.cfg.warmup + 1)
            )
        sched = self._warmup_sched
        while sched and sched[0] <= t + 1e-12:
            sched.popleft()
        if sched:
            return sched[0]
        return t + self.cfg.interval

    @property
    def e2e_hint(self) -> float:
        """The live plan's modeled end-to-end latency (clients' backoff base)."""
        return plan_e2e_hint(self.plan)

    def observe(self, t: float) -> None:
        self._issues.append(t)

    def observe_service(
        self, module: str, machine: Machine, duration: float, t: float
    ) -> None:
        """One started batch's measured service duration (stage observer).

        Two books are kept: the correction window pairs the measurement
        with the ORIGINAL profile's duration for that (batch, hardware) —
        scales must never compound across correction epochs — while the
        epoch error accumulator pairs it with the LIVE machine's config
        duration, i.e. what the active plan currently believes.
        """
        cfg_d = machine.config.duration
        if cfg_d <= 0.0 or duration <= 0.0:
            return
        orig = self._orig_dur.get(
            (module, machine.config.batch, machine.config.hardware), cfg_d
        )
        win = self._svc_win.get(module)
        if win is not None:
            win.append((orig, duration))
        self._err_sum += abs(duration - cfg_d) / cfg_d
        self._err_n += 1

    # -- transient-aware deadline relaxation (mid-epoch ticks) ---------------
    @property
    def relax_interval(self) -> "float | None":
        """Tick period for :meth:`on_tick`; None disables the tick chain."""
        if not self.relax_enabled:
            return None
        return self.cfg.interval * self.cfg.relax_every

    def on_tick(self, t: float) -> "float | None":
        """Detect mid-epoch provisioned-rate staleness; returns the new
        collect-rate scale to retime the stages with (None: unchanged).

        The active plan provisioned ``history[-1].target`` frames/s; when
        the recently observed rate (half-interval window) falls more than
        ``relax_tol`` below it, budget deadlines derived from
        the provisioned collect rate flush near-empty padded batches every
        cycle — pure waste the next epoch would only repair after the
        fact.  The returned scale relaxes those deadlines toward the
        observed arrival quantum (`resolve_module_timeout(rate_scale=)`),
        clamped at ``relax_floor``; a recovered rate scales
        back to 1.0.
        """
        cfg = self.cfg
        window = cfg.interval * 0.5
        window = min(window, t) if t > 0.0 else window
        if window <= 0.0:
            return None
        count = 0
        for x in reversed(self._issues):
            if x < t - window:
                break
            count += 1
        observed = (count / window) * (1.0 + cfg.margin)
        provisioned = self.history[-1].target
        if provisioned <= 0.0:
            return None
        scale = 1.0
        if observed < provisioned * (1.0 - cfg.relax_tol):
            scale = max(
                cfg.relax_floor, observed / provisioned
            )
            # quantize so estimator wobble cannot churn flush re-arming
            scale = max(
                cfg.relax_floor, round(scale / 0.05) * 0.05
            )
        if abs(scale - self._relax_scale) < 1e-9:
            return None
        self._relax_scale = scale
        return scale

    def relax_timeout(
        self, module: str, machines: "list[Machine]"
    ) -> "float | None | dict":
        """The stage's deadlines under the current relax scale."""
        return self.timeout_of(
            self.plan.schedules[module], machines, self.plan, self._relax_scale
        )

    def _trend_est(
        self, t: float, window: float, *, k_down: float = 2.0, k_up: float = 0.0
    ) -> float:
        """Trend-extrapolated arrival-rate estimate over ``[t - window, t)``.

        The window's two half-rates give a slope; extrapolating from the
        recent half's center through the coming epoch provisions a ramp at
        its arrival, not at its observation.  The slope is debiased by its
        own counting noise before extrapolating — shrunk toward zero by
        ``k`` standard deviations of the half-rate difference (Poisson:
        ``sqrt(n1 + n2) / half^2``) — and the shrinkage is asymmetric.  A
        falling slope is burst noise and genuine decay mixed, and
        projecting the noise part forward under-provisions on a perfectly
        steady rate (a quiet half-window reads as a crash, the replan
        sheds machines, and the next burst lands on a shrunken cluster):
        ``k_down`` shrinks falls hard.  A rising slope at worst
        over-provisions one epoch, so ``k_up`` defaults to trusting it;
        the short fast-attack window passes ``k_up=1`` because its halves
        hold few arrivals and a raw noise spike there would churn the
        plan upward at every other epoch.
        """
        half = window / 2.0
        if half <= 0.0:
            return 0.0
        n2 = n1 = 0
        for x in reversed(self._issues):
            if x < t - window:
                break
            if x >= t - half:
                n2 += 1
            else:
                n1 += 1
        r2, r1 = n2 / half, n1 / half
        slope = (r2 - r1) / half
        k = k_up if slope >= 0.0 else k_down
        sd = math.sqrt(max(n1 + n2, 1)) / (half * half)
        mag = max(0.0, abs(slope) - k * sd)
        return r2 + math.copysign(mag, slope) * (0.5 * half + self.cfg.interval)

    def on_epoch(self, t: float) -> "dict[str, StageUpdate] | None":
        """Estimate, replan, and emit the stage updates for epoch ``t``."""
        cfg = self.cfg
        if cfg.window is not None:
            window = cfg.window
        else:
            # the trend extrapolation differentiates the window's two
            # halves, amplifying their Poisson counting noise by the
            # extrapolation distance over the half width — a multi-interval
            # window keeps that below the provisioning margin
            window = cfg.interval * (4.0 if cfg.forecast else 1.0)
        # clamp to the elapsed run: the span before t=0 holds no
        # observations, and treating it as an empty half-window would read
        # a perfectly steady start-up as a 2x ramp
        window = min(window, t) if t > 0.0 else window
        dq = self._issues
        while dq and dq[0] < t - window:
            dq.popleft()
        if cfg.forecast and window > 0.0:
            # trend-aware estimate: rate over each half-window gives the
            # slope; extrapolate from the recent half's center through the
            # coming epoch so a ramp is provisioned at its arrival, not at
            # its observation
            est = self._trend_est(t, window)
            if cfg.attack and window > cfg.interval:
                # fast-attack: a multi-interval window damps noise, but it
                # also averages away a ramp that *turns inside it* — after
                # a diurnal trough the windowed estimate is still reading
                # the lull while arrivals are already climbing, and the
                # epoch replans to a stale-low target (the dominant
                # deadline-miss mode at coarse epochs).  Re-estimate over
                # just the most recent interval and take it when it beats
                # the windowed estimate by more than the provisioning
                # margin: rises are provisioned at attack speed, falls
                # release at the window's slower pace, and the margin-wide
                # hysteresis band keeps the short window's counting noise
                # from churning the plan when the rate is steady
                recent = self._trend_est(
                    t, min(cfg.interval, window), k_up=1.0
                )
                if recent > est * (1.0 + cfg.margin):
                    est = recent
        else:
            est = len(dq) / max(window, cfg.interval)
        est = max(est, cfg.floor * self.frame_rate0)
        target = est * (1.0 + cfg.margin)
        new_rates = {m: target * f for m, f in self.fanouts.items()}
        # model-vs-measured audit for the closing epoch, then fold the
        # observed durations into the profiles the replan runs against:
        # per-module scales vs the ORIGINAL profiles, quantized so only a
        # real calibration shift forces a repair
        duration_err = self._err_sum / self._err_n if self._err_n else 0.0
        self._err_sum, self._err_n = 0.0, 0
        force: set[str] = set()
        if cfg.correct_profiles:
            for m, win in self._svc_win.items():
                if not win:
                    continue
                s = quantize_scale(duration_scale(win), cfg.correction_tol)
                if s != self.scales.get(m, 1.0):
                    self.scales[m] = s
                    force.add(m)
        profiles = corrected_profiles(self.profiles, self.scales)
        corrections = {m: s for m, s in self.scales.items() if s != 1.0}
        new_plan = self.planner.replan(
            self.plan,
            new_rates,
            profiles,
            tolerance=cfg.tolerance,
            cost_guard=cfg.cost_guard,
            force=frozenset(force),
        )
        if not new_plan.feasible:
            # keep serving on the previous plan; the failed epoch is recorded
            self.history.append(
                EpochRecord(
                    t=t, rate_est=est, target=target,
                    version=self.plan.version, cost=self.plan.cost,
                    feasible=False, swapped=False,
                    actions=dict(new_plan.provenance),
                    duration_err=duration_err,
                    corrections=corrections,
                )
            )
            return None
        delta = self.plan.diff(new_plan)
        self.plan = new_plan
        updates: dict[str, StageUpdate] = {}
        for m in delta.changed_modules:
            s = new_plan.schedules[m]
            if not s.allocs:
                continue  # never swap a stage down to zero machines
            machines = expand_machines(list(s.allocs))
            updates[m] = StageUpdate(
                machines=machines,
                timeout=self.timeout_of(s, machines, new_plan),
                phantom_target=(
                    sum(a.rate + a.dummy for a in s.allocs) if self.dummies else 0.0
                ),
            )
        if self.admission is not None:
            # admission policies bound to the provisioned rate follow the
            # epoch's plan instead of the run-constant initial rate
            self.admission.rebind(target)
        self.history.append(
            EpochRecord(
                t=t, rate_est=est, target=target,
                version=new_plan.version, cost=new_plan.cost,
                feasible=True, swapped=bool(updates),
                actions=dict(new_plan.provenance),
                machines_added=sum(
                    d.machines_added for d in delta.modules.values()
                ),
                machines_drained=sum(
                    d.machines_drained for d in delta.modules.values()
                ),
                delta_summary=delta.summary() if updates else "",
                duration_err=duration_err,
                corrections=corrections,
            )
        )
        if updates and cfg.on_swap is not None:
            # multi-tenant pools arbitrate here: the global allocator
            # repacks shared devices around this tenant's new plan
            cfg.on_swap(t, new_plan)
        return updates or None

    def on_failure(self, t: float, module: str) -> "dict[str, StageUpdate] | None":
        """Out-of-band failure replan: a machine of ``module`` was declared
        dead mid-epoch (`faults` watchdog) and its stage is now running one
        machine short of what the live plan provisioned.

        Unlike :meth:`on_epoch` this does not re-estimate the rate — the
        offered load did not change, the capacity did.  The planner is
        forced to re-derive ``module``'s schedule against the last epoch's
        target (warm-start repair leaves the healthy modules alone), and
        the failed module's stage update is emitted **unconditionally**:
        even when the replanned schedule is numerically identical to the
        live one, the stage must re-expand its machine list because the
        dead core is fenced out of `ModuleStage.apply_update`'s revival
        pool — the update is what creates the replacement (promoting the
        warm spare when one is parked).  The failure replan is appended to
        :attr:`history` as its own audit record (``actions`` marks the
        failed module), so serving-cost integration and forensics see the
        recovery epoch.
        """
        cfg = self.cfg
        last = self.history[-1]
        target = last.target
        new_rates = {m: target * f for m, f in self.fanouts.items()}
        profiles = corrected_profiles(self.profiles, self.scales)
        new_plan = self.planner.replan(
            self.plan,
            new_rates,
            profiles,
            tolerance=cfg.tolerance,
            cost_guard=cfg.cost_guard,
            force=frozenset({module}),
        )
        updates: dict[str, StageUpdate] = {}
        changed: set[str] = {module}
        swapped = False
        if new_plan.feasible:
            delta = self.plan.diff(new_plan)
            self.plan = new_plan
            changed |= set(delta.changed_modules)
            swapped = True
        for m in sorted(changed):
            s = self.plan.schedules.get(m)
            if s is None or not s.allocs:
                continue  # never swap a stage down to zero machines
            machines = expand_machines(list(s.allocs))
            updates[m] = StageUpdate(
                machines=machines,
                timeout=self.timeout_of(s, machines, self.plan),
                phantom_target=(
                    sum(a.rate + a.dummy for a in s.allocs) if self.dummies else 0.0
                ),
            )
        actions = dict(self.plan.provenance)
        actions[module] = f"failure_replan({actions.get(module, 'kept')})"
        self.history.append(
            EpochRecord(
                t=t, rate_est=last.rate_est, target=target,
                version=self.plan.version, cost=self.plan.cost,
                feasible=self.plan.feasible, swapped=swapped and bool(updates),
                actions=actions,
            )
        )
        if swapped and updates and cfg.on_swap is not None:
            cfg.on_swap(t, self.plan)
        return updates or None
