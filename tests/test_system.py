"""End-to-end behaviour tests: plan -> serve across the full stack."""
import jax
import jax.numpy as jnp
import pytest

# JAX-compile-heavy (jits real kernels/models); deselect with -m "not slow"
pytestmark = pytest.mark.slow

from repro.configs import get_config
from repro.core import Leaf, Planner, Workload, series
from repro.core import baselines as B
from repro.core.dag import AppDAG
from repro.models import Model
from repro.profiling import arch_profile
from repro.serving import ServingEngine
from repro.workloads import synth_profiles
from repro.workloads.apps import CAPTION, make_workload


def test_plan_and_serve_meets_slo():
    """Harpagon plan served by the event engine attains the SLO."""
    profiles = synth_profiles()
    wl = make_workload(CAPTION, rate=120.0, slo=2.0)
    plan = Planner(B.HARPAGON).plan(wl, profiles)
    assert plan.feasible
    engine = ServingEngine(plan)
    res = engine.run(1500, 120.0)
    assert len(res.e2e_latencies) > 500
    # worst-case-latency planning => near-perfect attainment in simulation
    assert res.attainment >= 0.97, res.attainment


def test_plan_archs_with_analytic_profiles():
    """Harpagon plans a chain of two assigned architectures end to end."""
    archs = ["gemma3-1b", "qwen1.5-4b"]
    dag = AppDAG("session", series(*[Leaf(a) for a in archs]))
    profiles = {a: arch_profile(get_config(a), seq=128) for a in archs}
    wl = Workload(dag, {a: 50.0 for a in archs}, 1.0)
    plan = Planner(B.HARPAGON).plan(wl, profiles)
    assert plan.feasible
    assert plan.e2e_latency <= 1.0 + 1e-6
    # baselines cost at least as much
    for opts in B.BASELINES:
        bl = Planner(opts).plan(wl, profiles)
        if bl.feasible:
            assert plan.cost <= bl.cost + 1e-6


def test_real_executor_serving():
    """Serve with REAL jitted model forwards as module executors."""
    profiles = synth_profiles()
    wl = make_workload(CAPTION, rate=60.0, slo=2.5)
    plan = Planner(B.HARPAGON).plan(wl, profiles)
    assert plan.feasible
    cfg = get_config("smollm-360m", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    fwd = jax.jit(lambda p, t: model.forward(p, t).logits)
    calls = []

    def executor(b):
        toks = jnp.zeros((b, 8), jnp.int32)
        fwd(params, toks).block_until_ready()
        calls.append(b)

    executors = {m: executor for m in wl.app.modules}
    engine = ServingEngine(plan, executors=executors)
    res = engine.run(200, 60.0)
    assert calls, "real executor was never invoked"
    assert len(res.e2e_latencies) > 50
