"""Failure-resilient serving (ISSUE-10): seeded fault injection, watchdog
detection, and graceful-degradation recovery.

Covers: `FaultConfig` validation and the disabled-injector contract (a
disabled config is bit-exact with ``faults=None`` on the flat, pipelined,
control-plane, and tenancy paths), frame conservation
``completed + shed + dropped == offered`` under randomized seeded fault
schedules with every miss classified into exactly one forensics cause,
the suspect→dead watchdog lifecycle (trace instants, counters, the
``failed`` forensic column), out-of-band failure replans with warm-spare
promotion, straggler transients, the bounded-retry ``retry_exhausted``
terminal (dropped, not shed), and the shared pool's ``device_loss``
repack path.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.serving import (
    FAULT_KINDS,
    ControlLoopConfig,
    FaultConfig,
    FaultRuntime,
    FrontendConfig,
    ServingEngine,
    SharedPool,
    TokenBucket,
    classify_misses,
)
from repro.serving.arrivals import trace_arrivals
from repro.serving.frontend import ClosedLoopClients
from repro.workloads import synth_profiles
from repro.workloads.apps import app_by_name, make_workload

PROFILES = synth_profiles()


def suite_plan(name, rate, slo):
    plan = Planner(B.HARPAGON).plan(
        make_workload(app_by_name(name), rate, slo), PROFILES
    )
    assert plan.feasible
    return plan


def conserves(res):
    pr = res.pipeline
    return (
        int(pr.completed.sum() + pr.shed.sum() + pr.dropped.sum())
        == res.offered
    )


# ------------------------------------------------------ config validation


class TestFaultConfig:
    def test_disabled_by_default(self):
        cfg = FaultConfig()
        assert not cfg.enabled

    def test_enabled_by_mtbf_or_schedule(self):
        assert FaultConfig(mtbf=5.0).enabled
        assert FaultConfig(schedule=((1.0, "crash"),)).enabled

    @pytest.mark.parametrize(
        "kw",
        [
            dict(mtbf=0.0),
            dict(mtbf=-1.0),
            dict(detect_k=1.0),
            dict(detect_k=0.5),
            dict(straggler_factor=1.0),
            dict(straggler_duration=0.0),
            dict(kinds=("crash", "meteor")),
            dict(schedule=((1.0, "meteor"),)),
            dict(schedule=((-0.5, "crash"),)),
        ],
    )
    def test_rejects_bad_knobs(self, kw):
        with pytest.raises(ValueError):
            FaultConfig(**kw)

    def test_engine_rejects_enabled_faults_off_pipeline(self):
        plan = suite_plan("traffic", 100.0, 2.0)
        with pytest.raises(ValueError, match="pipeline"):
            ServingEngine(plan).run(
                100, 100.0, faults=FaultConfig(schedule=((0.5, "crash"),))
            )

    def test_engine_rejects_non_config(self):
        plan = suite_plan("traffic", 100.0, 2.0)
        with pytest.raises(TypeError):
            ServingEngine(plan).run(100, 100.0, faults={"mtbf": 1.0})


# -------------------------------------------- injector/detector unit state


class TestFaultRuntime:
    def test_schedule_drains_before_mtbf_chain(self):
        rt = FaultRuntime(
            FaultConfig(mtbf=10.0, schedule=((2.0, "straggler"), (1.0, "crash")))
        )
        assert rt.next_fault(0.0) == (1.0, "crash")
        assert rt.next_fault(0.0) == (2.0, "straggler")
        t, kind = rt.next_fault(5.0)
        assert t > 5.0 and kind == "crash"

    def test_seeded_determinism(self):
        a = FaultRuntime(FaultConfig(mtbf=3.0, kinds=FAULT_KINDS, seed=7))
        b = FaultRuntime(FaultConfig(mtbf=3.0, kinds=FAULT_KINDS, seed=7))
        seq_a = [a.next_fault(0.0) for _ in range(20)]
        seq_b = [b.next_fault(0.0) for _ in range(20)]
        assert seq_a == seq_b

    def test_escalation_ladder(self):
        rt = FaultRuntime(FaultConfig(schedule=((1.0, "crash"),)))
        assert rt.escalate("M", 0) == "suspect"
        assert rt.escalate("M", 0) == "dead"
        rt.clear("M", 1)  # unrelated machine: no effect
        assert rt.escalate("M", 0) == "dead"
        rt.clear("M", 0)
        assert rt.escalate("M", 0) == "suspect"

    def test_forget_drops_all_state(self):
        rt = FaultRuntime(FaultConfig(schedule=((1.0, "straggler"),)))
        rt.escalate("M", 0)
        rt.slow[("M", 0)] = 4.0
        rt.forget("M", 0)
        assert ("M", 0) not in rt.slow
        assert rt.escalate("M", 0) == "suspect"


# ------------------------------------- disabled injector == faults absent


class TestFaultOffBitExact:
    def _runs(self, plan, n, rate, **kw):
        base = ServingEngine(plan).run(n, rate, **kw)
        off = ServingEngine(plan).run(n, rate, faults=FaultConfig(), **kw)
        return base, off

    def test_flat_path(self):
        plan = suite_plan("traffic", 100.0, 2.0)
        base, off = self._runs(plan, 300, 100.0)
        assert np.array_equal(base.e2e_latencies, off.e2e_latencies)
        assert off.faults is None

    def test_pipelined_path(self):
        plan = suite_plan("face", 150.0, 2.5)
        base, off = self._runs(plan, 400, 150.0, pipeline=True)
        assert np.array_equal(
            base.pipeline.e2e, off.pipeline.e2e, equal_nan=True
        )
        assert off.faults is None

    def test_control_path(self):
        plan = suite_plan("pose", 60.0, 3.0)
        arr = trace_arrivals(400, 60.0, seed=0, period=400 / 60.0)
        kw = dict(
            arrivals=arr, pipeline=True, timeout="budget",
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
        )
        base = ServingEngine(plan).run(
            400, 60.0,
            control=ControlLoopConfig(interval=400 / 60.0 / 8, profiles=PROFILES),
            **kw,
        )
        off = ServingEngine(plan).run(
            400, 60.0,
            control=ControlLoopConfig(interval=400 / 60.0 / 8, profiles=PROFILES),
            faults=FaultConfig(),
            **kw,
        )
        assert np.array_equal(
            base.pipeline.e2e, off.pipeline.e2e, equal_nan=True
        )

    def test_tenancy_path(self):
        plans = {
            a: suite_plan(a, r, s)
            for a, r, s in (("traffic", 100.0, 2.0), ("pose", 60.0, 3.0))
        }
        base = SharedPool(plans).run(300, pipeline=True)
        off = SharedPool(plans).run(300, pipeline=True, faults=FaultConfig())
        for a in plans:
            assert np.array_equal(
                base.results[a].pipeline.e2e,
                off.results[a].pipeline.e2e,
                equal_nan=True,
            )


# ----------------------------- conservation under randomized fault storms


class TestConservationUnderFaults:
    """The property test: ``completed + shed + dropped == offered`` exactly,
    and every miss classifies into exactly one forensics cause, under any
    fault schedule (seeded randomized storms; hypothesis-free by design —
    no new dependency)."""

    APPS = (("traffic", 100.0, 2.0), ("face", 150.0, 2.5), ("pose", 60.0, 3.0))

    def _storm(self, rng, horizon):
        n = int(rng.integers(1, 4))
        kinds = ("crash", "straggler")
        return tuple(
            sorted(
                (float(rng.uniform(0.1, horizon)), kinds[int(rng.integers(2))])
                for _ in range(n)
            )
        )

    def test_randomized_schedules_no_control(self):
        rng = np.random.default_rng(0)
        for trial in range(6):
            name, rate, slo = self.APPS[trial % len(self.APPS)]
            plan = suite_plan(name, rate, slo)
            n = 400
            sched = self._storm(rng, n / rate * 0.8)
            res = ServingEngine(plan).run(
                n, rate, pipeline=True,
                faults=FaultConfig(
                    schedule=sched, seed=int(rng.integers(1000)), detect_k=2.0
                ),
            )
            assert conserves(res), (name, sched)
            rep = classify_misses(res.pipeline, slo)
            assert rep.conserved, (name, sched)

    def test_randomized_schedules_with_control(self):
        rng = np.random.default_rng(1)
        for trial in range(3):
            name, rate, slo = self.APPS[trial % len(self.APPS)]
            plan = suite_plan(name, rate, slo / 1.25)
            n = 480
            period = n / rate
            arr = trace_arrivals(n, rate, seed=0, period=period)
            sched = self._storm(rng, period * 0.8)
            res = ServingEngine(plan).run(
                n, rate, arrivals=arr, pipeline=True, timeout="budget",
                frontend=FrontendConfig(dummies=True, burst_deadline=True),
                control=ControlLoopConfig(
                    interval=period / 8, profiles=PROFILES, margin=0.35
                ),
                faults=FaultConfig(
                    schedule=sched, seed=int(rng.integers(1000)), detect_k=2.0
                ),
            )
            assert conserves(res), (name, sched)
            rep = classify_misses(res.pipeline, slo, res.epochs)
            assert rep.conserved, (name, sched)


# ------------------------------------------- detection lifecycle + trace


class TestDetectionAndRecovery:
    def _crash_run(self, observability=False, control=None, detect_k=2.0):
        plan = suite_plan("face", 150.0, 2.5)
        return ServingEngine(plan).run(
            600, 150.0, pipeline=True, control=control,
            observability=observability,
            faults=FaultConfig(schedule=((1.0, "crash"),), detect_k=detect_k),
        )

    def test_crash_is_detected_and_requeued(self):
        res = self._crash_run()
        assert res.faults == {
            "injected": 1,
            "killed": res.faults["killed"],
            "requeued": res.faults["requeued"],
        }
        assert res.faults["killed"] == 1
        assert res.faults["requeued"] > 0
        assert conserves(res)
        failed = res.pipeline.failed
        assert failed is not None and failed.sum() > 0

    def test_failure_forensics_causes(self):
        res = self._crash_run()
        rep = classify_misses(res.pipeline, 2.5)
        assert rep.conserved
        touched = (
            rep.counts.get("machine_failure", 0)
            + rep.counts.get("recovery_transient", 0)
        )
        assert touched > 0  # failure attribution trumps epoch attribution

    def test_trace_instants(self):
        res = self._crash_run(observability=True)
        names = {ev[4] for ev in res.trace.events()}
        assert {"suspect", "fail", "requeue"} <= names

    def test_failure_replan_fires_out_of_band(self):
        plan = suite_plan("face", 150.0, 2.5 / 1.25)
        n, rate = 600, 150.0
        period = n / rate
        res = ServingEngine(plan).run(
            n, rate,
            arrivals=trace_arrivals(n, rate, seed=0, period=period),
            pipeline=True, timeout="budget",
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
            control=ControlLoopConfig(
                interval=period / 4, profiles=PROFILES, margin=0.35
            ),
            faults=FaultConfig(schedule=((period / 2.2, "crash"),), detect_k=2.0),
        )
        assert conserves(res)
        if res.faults["killed"] and any(
            "failure_replan" in a
            for e in res.epochs
            for a in e.actions.values()
        ):
            return  # the out-of-band replan landed and was recorded
        # the epoch swap may legitimately beat the watchdog verdict: then
        # the stranded members are still rescued without a replan
        assert res.faults["requeued"] > 0 or res.faults["killed"] == 0

    def test_straggler_recovers_without_kill(self):
        plan = suite_plan("traffic", 100.0, 2.0)
        res = ServingEngine(plan).run(
            500, 100.0, pipeline=True,
            faults=FaultConfig(
                schedule=((1.0, "straggler"),),
                straggler_factor=1.5,
                straggler_duration=0.2,
                detect_k=4.0,
            ),
        )
        # a mild, short slowdown must not be declared dead
        assert res.faults["injected"] == 1
        assert res.faults["killed"] == 0
        assert conserves(res)

    def test_severe_straggler_is_killed_as_dead(self):
        plan = suite_plan("traffic", 100.0, 2.0)
        res = ServingEngine(plan).run(
            500, 100.0, pipeline=True,
            faults=FaultConfig(
                schedule=((1.0, "straggler"),),
                straggler_factor=50.0,
                straggler_duration=4.0,
                detect_k=2.0,
            ),
        )
        # slow-vs-dead is indistinguishable to the watchdog: a straggler
        # that misses two windows is correctly killed, frames conserved
        # (the requeue wave may push a sibling past its own window too)
        assert res.faults["killed"] >= 1
        assert conserves(res)

    def test_mtbf_chain_is_reproducible(self):
        plan = suite_plan("pose", 60.0, 3.0)
        kw = dict(
            pipeline=True,
            faults=FaultConfig(mtbf=2.0, seed=11, detect_k=2.0),
        )
        a = ServingEngine(plan).run(400, 60.0, **kw)
        b = ServingEngine(plan).run(400, 60.0, **kw)
        assert np.array_equal(a.pipeline.e2e, b.pipeline.e2e, equal_nan=True)
        assert a.faults == b.faults


# --------------------------------------------- bounded retries (ISSUE-10.1)


class TestRetryExhausted:
    def _overloaded(self, max_retries):
        plan = suite_plan("traffic", 100.0, 2.0)
        fe = FrontendConfig(
            admission=TokenBucket(rate=40.0, burst=2.0),
            clients=ClosedLoopClients(
                n_clients=64, retry_on_shed=True,
                max_retries=max_retries, backoff=0.01,
            ),
        )
        return ServingEngine(plan).run(400, 80.0, frontend=fe, pipeline=True)

    def test_exhausted_frames_are_dropped_not_shed(self):
        res = self._overloaded(max_retries=2)
        pr = res.pipeline
        # the half-rate bucket forces terminal denials; every exhausted
        # frame is *dropped* (admitted demand the system failed after
        # re-offers), never folded into first-sight shed
        assert res.dropped > 0
        assert conserves(res)
        assert res.attempts >= 400
        # dropped-at-ingress frames never entered the pipeline
        assert not np.any(pr.dropped & pr.completed)

    def test_retry_cause_lands_in_trace(self):
        plan = suite_plan("traffic", 100.0, 2.0)
        fe = FrontendConfig(
            admission=TokenBucket(rate=40.0, burst=2.0),
            clients=ClosedLoopClients(
                n_clients=64, retry_on_shed=True, max_retries=1, backoff=0.01
            ),
        )
        res = ServingEngine(plan).run(
            400, 80.0, frontend=fe, pipeline=True, observability=True
        )
        names = [ev[4] for ev in res.trace.events()]
        assert any("retry_exhausted" in n for n in names)

    def test_zero_retries_terminal_at_first_denial(self):
        plan = suite_plan("traffic", 100.0, 2.0)
        fe = FrontendConfig(
            admission=TokenBucket(rate=40.0, burst=2.0),
            clients=ClosedLoopClients(
                n_clients=64, retry_on_shed=True, max_retries=0
            ),
        )
        res = ServingEngine(plan).run(400, 80.0, frontend=fe, pipeline=True)
        # no re-offer ever happened, so denials are first-sight sheds
        assert res.dropped == 0
        assert res.shed > 0
        assert conserves(res)


# -------------------------------------------------- shared-pool device loss


class TestDeviceLoss:
    def test_pool_crash_conserves_every_app(self):
        plans = {
            a: suite_plan(a, r, s)
            for a, r, s in (("traffic", 100.0, 2.0), ("pose", 60.0, 3.0))
        }
        pool = SharedPool(plans)
        res = pool.run(
            300, pipeline=True,
            faults=FaultConfig(
                schedule=((0.8, "device_loss"),), seed=5, detect_k=2.0
            ),
        )
        for a, r in res.results.items():
            pr = r.pipeline
            assert (
                int(pr.completed.sum() + pr.shed.sum() + pr.dropped.sum())
                == r.offered
            ), a
