"""Composable decoder model: segments of repeated layer blocks under lax.scan.

The layer-spec sequence of an architecture (configs.base.ArchConfig) is
compressed into *segments* — (pattern, repeats) with a small repeating
pattern — so heterogeneous stacks (Jamba's 1:7 attn:mamba macro-block,
gemma3's 5:1 local:global, deepseek's 3 dense + 58 MoE) all scan over
stacked parameters with a compact HLO, which keeps 512-device SPMD compiles
tractable and enables per-macro-block remat.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerSpec
from . import layers as Lyr
from . import moe as Moe
from . import ssm as Ssm
from .moe import MoEMeshInfo

Params = dict[str, Any]


# ----------------------------------------------------------------- segments
def segmentize(specs: tuple[LayerSpec, ...]) -> list[tuple[tuple[LayerSpec, ...], int]]:
    """Compress a layer-spec list into (pattern, repeats) segments."""
    out: list[tuple[tuple[LayerSpec, ...], int]] = []
    i, n = 0, len(specs)
    while i < n:
        best_p, best_r = 1, 1
        for p in range(1, min(8, n - i) + 1):
            pat = specs[i : i + p]
            r = 1
            while specs[i + r * p : i + (r + 1) * p] == pat:
                r += 1
            if r > 1 and p * r > best_p * best_r:
                best_p, best_r = p, r
        if best_r == 1:
            # literal run: absorb consecutive non-repeating layers
            j = i + 1
            out.append((specs[i:j], 1))
            i = j
        else:
            out.append((specs[i : i + best_p], best_r))
            i += best_p * best_r
    # merge adjacent literal singletons into one unrolled pattern
    merged: list[tuple[tuple[LayerSpec, ...], int]] = []
    for pat, r in out:
        if r == 1 and merged and merged[-1][1] == 1:
            merged[-1] = (merged[-1][0] + pat, 1)
        else:
            merged.append((pat, r))
    return merged


# ------------------------------------------------------------------- blocks
def _block_init(key, cfg: ArchConfig, spec: LayerSpec, dtype, ep: int) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"mix_norm": Lyr.norm_init(cfg, cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["mix"] = Lyr.attn_init(k1, cfg, dtype)
    elif spec.mixer == "mla":
        p["mix"] = Lyr.mla_init(k1, cfg, dtype)
    elif spec.mixer == "mamba":
        p["mix"] = Ssm.mamba_init(k1, cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mix"] = Ssm.mlstm_init(k1, cfg, dtype)
    elif spec.mixer == "slstm":
        p["mix"] = Ssm.slstm_init(k1, cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["ffn_norm"] = Lyr.norm_init(cfg, cfg.d_model, dtype)
        if spec.ffn == "moe":
            p["ffn"] = Moe.moe_init(k2, cfg, dtype, ep)
        else:
            p["ffn"] = Lyr.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _block_cache_init(cfg: ArchConfig, spec: LayerSpec, batch: int, max_seq: int, dtype):
    if spec.mixer == "attn":
        return Lyr.attn_cache_init(cfg, spec, batch, max_seq, dtype)
    if spec.mixer == "mla":
        return Lyr.mla_cache_init(cfg, batch, max_seq, dtype)
    if spec.mixer == "mamba":
        return Ssm.mamba_cache_init(cfg, batch, dtype)
    if spec.mixer == "mlstm":
        return Ssm.mlstm_cache_init(cfg, batch, dtype)
    if spec.mixer == "slstm":
        return Ssm.slstm_cache_init(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def _block_apply(
    p: Params,
    cfg: ArchConfig,
    spec: LayerSpec,
    x: jax.Array,
    positions: jax.Array,
    cache,
    idx,
    mesh_info: MoEMeshInfo | None,
):
    h = Lyr.apply_norm(cfg, p["mix_norm"], x)
    if spec.mixer == "attn":
        y, new_cache = Lyr.attn_forward(p["mix"], cfg, spec, h, positions, cache=cache, idx=idx)
    elif spec.mixer == "mla":
        y, new_cache = Lyr.mla_forward(p["mix"], cfg, h, positions, cache=cache, idx=idx)
    elif spec.mixer == "mamba":
        y, new_cache = Ssm.mamba_forward(p["mix"], cfg, h, cache=cache)
    elif spec.mixer == "mlstm":
        y, new_cache = Ssm.mlstm_forward(p["mix"], cfg, h, cache=cache)
    else:
        y, new_cache = Ssm.slstm_forward(p["mix"], cfg, h, cache=cache)
    from ..kernels.ops import constrain_activations

    x = constrain_activations(x + y)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h = Lyr.apply_norm(cfg, p["ffn_norm"], x)
        if spec.ffn == "moe":
            y, aux = Moe.moe_forward(p["ffn"], cfg, h, mesh_info=mesh_info)
        else:
            y = Lyr.mlp_forward(p["ffn"], h, cfg.act)
        x = constrain_activations(x + y)
    return x, new_cache, aux


@dataclass
class ModelOutput:
    logits: jax.Array | None
    cache: Any
    aux_loss: jax.Array
    hidden: jax.Array | None = None


class Model:
    """Pure-function model; parameters are plain dict pytrees."""

    def __init__(self, cfg: ArchConfig, mesh_info: MoEMeshInfo | None = None):
        self.cfg = cfg
        self.mesh_info = mesh_info
        self.specs = cfg.layer_specs()
        self.segments = segmentize(self.specs)
        self.pdtype = jnp.dtype(cfg.param_dtype)
        self.cdtype = jnp.dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        ep = self.mesh_info.ep_size if self.mesh_info else 1
        keys = jax.random.split(key, len(self.segments) + 3)
        params: Params = {"embed": Lyr.embed_init(keys[0], cfg, self.pdtype)}
        segs = []
        for si, (pattern, repeats) in enumerate(self.segments):
            kseg = keys[si + 1]

            def init_one(k):
                ks = jax.random.split(k, len(pattern))
                return tuple(
                    _block_init(ks[j], cfg, spec, self.pdtype, ep)
                    for j, spec in enumerate(pattern)
                )

            if repeats == 1:
                segs.append(init_one(kseg))
            else:
                segs.append(jax.vmap(init_one)(jax.random.split(kseg, repeats)))
        params["segments"] = segs
        params["final_norm"] = Lyr.norm_init(cfg, cfg.d_model, self.pdtype)
        if not cfg.tie_embeddings:
            params["head"] = Lyr._dense_init(keys[-1], cfg.d_model, cfg.vocab_size, self.pdtype)
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": Lyr._dense_init(keys[-2], 2 * cfg.d_model, cfg.d_model, self.pdtype),
                "block": _block_init(
                    keys[-2], cfg, LayerSpec("attn" if cfg.attn_kind != "mla" else "mla", "dense"), self.pdtype, ep
                ),
                "norm": Lyr.norm_init(cfg, cfg.d_model, self.pdtype),
            }
        return params

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_seq: int, dtype=None):
        dtype = dtype or self.cdtype
        caches = []
        for pattern, repeats in self.segments:
            one = tuple(
                _block_cache_init(self.cfg, spec, batch, max_seq, dtype)
                for spec in pattern
            )
            if repeats == 1:
                caches.append(one)
            else:
                caches.append(
                    jax.tree.map(
                        lambda x: jnp.broadcast_to(x, (repeats, *x.shape)), one
                    )
                )
        return caches

    # --------------------------------------------------------------- forward
    def forward(
        self,
        params: Params,
        tokens: jax.Array | None = None,
        *,
        embeds: jax.Array | None = None,
        positions: jax.Array | None = None,
        cache=None,
        idx=None,
        return_hidden: bool = False,
        compute_logits: bool = True,
    ) -> ModelOutput:
        cfg = self.cfg
        if embeds is None:
            x = Lyr.embed(params["embed"], cfg, tokens, self.cdtype)
        else:
            x = embeds.astype(self.cdtype)
        B, S, _ = x.shape
        if positions is None:
            base = jnp.arange(S)[None, :] + (idx if idx is not None else 0)
            positions = jnp.broadcast_to(base, (B, S))
            if cfg.mrope_sections is not None:
                positions = jnp.broadcast_to(positions, (3, B, S))

        aux_total = jnp.zeros((), jnp.float32)
        new_caches = [] if cache is not None else None
        for si, (pattern, repeats) in enumerate(self.segments):
            seg_params = params["segments"][si]
            seg_cache = cache[si] if cache is not None else None

            def apply_pattern(x, blk_params, blk_cache):
                new_bc = []
                aux = jnp.zeros((), jnp.float32)
                for j, spec in enumerate(pattern):
                    c_j = blk_cache[j] if blk_cache is not None else None
                    x, nc, a = _block_apply(
                        blk_params[j], cfg, spec, x, positions, c_j, idx, self.mesh_info
                    )
                    new_bc.append(nc)
                    aux = aux + a
                return x, tuple(new_bc), aux

            if cfg.remat:
                apply_pattern = jax.checkpoint(apply_pattern)

            if repeats == 1:
                x, nc, aux = apply_pattern(x, seg_params, seg_cache)
                aux_total = aux_total + aux
                if new_caches is not None:
                    new_caches.append(nc)
            else:

                def scan_body(carry, xs):
                    x, aux_acc = carry
                    blk_params, blk_cache = xs
                    x, nc, aux = apply_pattern(x, blk_params, blk_cache)
                    return (x, aux_acc + aux), nc

                if seg_cache is None:

                    def scan_body_nc(carry, blk_params):
                        x, aux_acc = carry
                        x, _nc, aux = apply_pattern(x, blk_params, None)
                        return (x, aux_acc + aux), None

                    (x, aux_total), _ = jax.lax.scan(
                        scan_body_nc, (x, aux_total), seg_params
                    )
                    if new_caches is not None:
                        new_caches.append(None)
                else:
                    (x, aux_total), nc = jax.lax.scan(
                        scan_body, (x, aux_total), (seg_params, seg_cache)
                    )
                    if new_caches is not None:
                        new_caches.append(nc)

        x = Lyr.apply_norm(cfg, params["final_norm"], x)
        hidden = x if return_hidden else None
        logits = None
        if compute_logits:
            logits = self.unembed(params, x)
        return ModelOutput(logits, new_caches, aux_total, hidden)

    def unembed(self, params: Params, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            return x @ params["embed"]["w"].astype(x.dtype).T
        return Lyr.dense(params["head"], x)
