"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

from .base import ArchConfig, InputShape, LayerSpec, SHAPES

from . import (
    deepseek_v3_671b,
    gemma3_1b,
    gemma_7b,
    jamba_v01_52b,
    musicgen_medium,
    qwen15_4b,
    qwen2_moe_a27b,
    qwen2_vl_2b,
    smollm_360m,
    xlstm_125m,
)

_MODULES = {
    "deepseek-v3-671b": deepseek_v3_671b,
    "smollm-360m": smollm_360m,
    "jamba-v0.1-52b": jamba_v01_52b,
    "qwen2-vl-2b": qwen2_vl_2b,
    "musicgen-medium": musicgen_medium,
    "gemma-7b": gemma_7b,
    "gemma3-1b": gemma3_1b,
    "xlstm-125m": xlstm_125m,
    "qwen2-moe-a2.7b": qwen2_moe_a27b,
    "qwen1.5-4b": qwen15_4b,
}

ARCHS: dict[str, ArchConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKE_ARCHS: dict[str, ArchConfig] = {k: m.SMOKE for k, m in _MODULES.items()}


def get_config(arch: str, *, smoke: bool = False) -> ArchConfig:
    table = SMOKE_ARCHS if smoke else ARCHS
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(table)}")
    return table[arch]


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


__all__ = [
    "ARCHS",
    "ArchConfig",
    "InputShape",
    "LayerSpec",
    "SHAPES",
    "SMOKE_ARCHS",
    "get_config",
    "get_shape",
]
