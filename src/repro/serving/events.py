"""Discrete-event core of the serving simulator (reference semantics).

One module = a set of machines fed by a dispatcher.  The dispatcher's static
request->machine assignment is computed up front (`core.dispatch`); what this
core simulates is *batch formation and service* with real deadline semantics:

* a machine's batch **opens** when a request lands in its empty formation
  buffer, **closes** when it reaches the configured batch size — or, with a
  finite ``timeout``, when the opener has waited ``timeout`` seconds (partial
  flush, exactly what a real frontend does because it cannot know whether
  more requests are coming);
* closed batches queue FIFO at the machine; service takes the profiled
  duration (or a real measured executor call) and the machine frees.

Implemented as a single priority queue over arrival / batch-flush /
machine-free events.  This is the *reference* implementation: it supports
real executors and arbitrary arrival patterns, and the vectorized hot path
(`repro.serving.replay`) is property-tested to agree with it.  End-of-stream
handling when ``timeout is None`` is governed by ``tail``:

* ``"flush"`` — execute the partial tail batch as soon as its last request
  has arrived (the seed engine's behavior);
* ``"drop"``  — discard tail requests (the seed simulator's behavior, i.e.
  steady-state-only accounting).
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Callable, Mapping, Sequence

import numpy as np

from ..core.dispatch import Machine

_ARRIVE, _FLUSH, _FREE = 0, 1, 2


def simulate_module_events(
    machines: Sequence[Machine],
    ready: np.ndarray,
    assignment: np.ndarray,
    *,
    timeout: "float | None | Mapping[int, float]" = None,
    tail: str = "flush",
    executor: Callable[[Machine, int], float] | None = None,
    phantom: np.ndarray | None = None,
) -> tuple[np.ndarray, dict[int, int]]:
    """Simulate one module; returns ``(finish, batches_per_machine)``.

    ``ready`` is the sorted per-request ready time; ``assignment[i]`` the
    machine id serving request ``i``.  ``timeout`` may be a single deadline
    or a per-machine-id mapping.  ``finish[i]`` is the absolute completion
    time (``np.nan`` for dropped tail requests).  ``executor`` (when given)
    is called at each batch start with ``(machine, group_size)`` and must
    return the measured service duration in seconds.

    ``phantom`` marks frontend dummy requests.  They occupy batch slots and
    are executed with the batch (an executor sees the full batch size), but
    a flush deadline is armed only when a *real* request lands in the
    formation buffer, and a leftover buffer holding only phantoms is
    discarded at end of stream instead of flushed.
    """
    if tail not in ("flush", "drop"):
        raise ValueError(f"unknown tail policy {tail!r}")
    if isinstance(timeout, Mapping):
        timeouts = {m.mid: timeout.get(m.mid) for m in machines}
    else:
        timeouts = {m.mid: timeout for m in machines}
    ready = np.asarray(ready, dtype=np.float64)
    n = ready.size
    real = np.ones(n, dtype=bool) if phantom is None else ~np.asarray(phantom, bool)
    finish = np.full(n, np.nan)
    by_mid = {m.mid: m for m in machines}
    batches = {m.mid: 0 for m in machines}
    openbuf: dict[int, list[int]] = {m.mid: [] for m in machines}
    token = {m.mid: 0 for m in machines}  # bumped on close, voids stale flushes
    armed = {m.mid: False for m in machines}  # deadline set for the open batch
    queue: dict[int, deque] = {m.mid: deque() for m in machines}
    free_at = {m.mid: 0.0 for m in machines}
    busy = {m.mid: False for m in machines}
    heap: list[tuple[float, int, int, int]] = []  # (time, kind, mid, payload)

    def start_next(mid: int, now: float) -> None:
        if busy[mid] or not queue[mid]:
            return
        batch_ready, rids = queue[mid].popleft()
        m = by_mid[mid]
        start = max(batch_ready, free_at[mid], now)
        dur = executor(m, len(rids)) if executor is not None else m.config.duration
        end = start + dur
        busy[mid] = True
        batches[mid] += 1
        finish[rids] = end
        heapq.heappush(heap, (end, _FREE, mid, 0))

    def close_batch(mid: int, batch_ready: float, now: float) -> None:
        rids = openbuf[mid]
        openbuf[mid] = []
        token[mid] += 1
        armed[mid] = False
        queue[mid].append((batch_ready, rids))
        start_next(mid, now)

    ai = 0  # pointer into the (sorted) arrival stream
    tails_done = False
    while True:
        # merge the sorted arrival stream with the flush/free heap; arrivals
        # win ties (a request landing exactly at a deadline joins the batch)
        if ai < n and (not heap or (ready[ai], _ARRIVE) <= heap[0][:2]):
            t, rid = float(ready[ai]), ai
            ai += 1
            mid = int(assignment[rid])
            buf = openbuf[mid]
            buf.append(rid)
            # the first REAL request arms the flush deadline (without
            # phantoms this is exactly the first member, as before)
            if real[rid] and not armed[mid] and timeouts[mid] is not None:
                armed[mid] = True
                heapq.heappush(heap, (t + timeouts[mid], _FLUSH, mid, token[mid]))
            if len(buf) >= by_mid[mid].config.batch:
                close_batch(mid, batch_ready=t, now=t)
            continue
        if heap:
            t, kind, mid, payload = heapq.heappop(heap)
            if kind == _FLUSH:
                if payload == token[mid] and openbuf[mid]:
                    close_batch(mid, batch_ready=t, now=t)
            else:  # _FREE
                busy[mid] = False
                free_at[mid] = t
                start_next(mid, now=t)
            continue
        if not tails_done:
            # stream over, queues drained: resolve leftover partial batches
            tails_done = True
            for mid, buf in openbuf.items():
                has_real = any(real[r] for r in buf)
                if buf and has_real and timeouts[mid] is None and tail == "flush":
                    # flush at the last REAL member's arrival: the frontend
                    # stops injecting phantoms once the stream ends, so
                    # trailing phantoms must not inflate real tail latency
                    t_last = float(ready[max(r for r in buf if real[r])])
                    close_batch(mid, batch_ready=t_last, now=t_last)
                elif buf:
                    openbuf[mid] = []  # drop (finish stays NaN)
            continue
        break
    return finish, batches
