"""Sharding rules: parameter/cache/batch PartitionSpec trees per architecture.

Scheme (DESIGN.md Sec. 5):
* activations/batch      -> data-parallel axes ('pod', 'data')
* attention heads / MLP hidden / vocab -> 'model' (Megatron-style via GSPMD)
* MoE experts            -> flattened EP axes (('data','model') when the
                            expert count divides, else ('model',)); shard_map
                            all_to_all routes tokens (models/moe.py)
* optional FSDP          -> the non-'model' dim of large 2-D weights is
                            additionally sharded over 'data' (ZeRO-3-style)
* KV caches              -> batch over dp; kv-heads over 'model' when they
                            divide, otherwise cache *sequence* over 'model'
                            (GSPMD partitions the cache attention into
                            flash-decode-style partial softmax + combine)
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, InputShape
from ..models.moe import MoEMeshInfo
from .mesh import dp_axes


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def choose_ep_axes(cfg: ArchConfig, mesh) -> tuple[str, ...]:
    """Flattened mesh axes experts are sharded over: wide EP (data x model)
    when the expert count divides it (deepseek: 256 over 256), else model-only
    EP with expert padding (qwen2-moe: 60 -> 64 over 16)."""
    if not cfg.is_moe_arch:
        return ()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dm = sizes.get("data", 1) * sizes.get("model", 1)
    if cfg.n_experts % dm == 0:
        return ("data", "model")
    return ("model",)


def make_moe_mesh_info(cfg: ArchConfig, mesh, shape: InputShape) -> MoEMeshInfo | None:
    if not cfg.is_moe_arch or mesh is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep_axes = choose_ep_axes(cfg, mesh)
    ep_size = 1
    for a in ep_axes:
        ep_size *= sizes[a]
    token_axes = dp_axes(mesh) + ("model",)
    token_size = 1
    for a in token_axes:
        token_size *= sizes[a]
    return MoEMeshInfo(
        ep_axes=ep_axes,
        ep_size=ep_size,
        token_axes=token_axes,
        token_size=token_size,
        mesh=mesh,
        all_axes=tuple(mesh.axis_names),
    )


# --------------------------------------------------------------------- params
_COL = ("q", "k", "v", "q_b", "k_b", "v_b", "w1", "w3", "up",
        "in_proj", "x_proj", "if_gate", "w", "proj")
# MLA low-rank down-projections: outputs are small bottlenecks (dc+dr ~ 576)
# that get sliced/normed before the head up-projection — sharding them makes
# GSPMD all-gather every layer.  Replicate them; heads shard after q_b/k_b.
_REPL = ("q_a", "kv_a")
_ROW = ("o", "w2", "down", "out_proj", "dt_proj")


def param_spec(path: str, leaf, cfg: ArchConfig, *, ep_axes, fsdp: bool, ep: int = 1) -> P:
    parts = path.split("/")
    name = parts[-1]
    ctx = parts[-2] if len(parts) > 1 else ""
    nd = leaf.ndim
    fs = "data" if fsdp else None

    # expert stacks (..., E_pad, d, f) / (..., E_pad, f, d) — the leading dim
    # may be a stacked-segment repeats dim, so index from the right and check
    # that dim -3 really is the (padded) expert count
    e_pad = -(-cfg.n_experts // max(ep, 1)) * max(ep, 1) if cfg.is_moe_arch else -1
    if (
        ctx == "ffn"
        and name in ("w1", "w2", "w3")
        and nd >= 3
        and leaf.shape[-3] == e_pad
    ):
        return P(*([None] * (nd - 3) + [ep_axes if ep_axes else None, None, None]))
    if name == "router":
        return P(*([None] * nd))
    if ctx.endswith("norm") or ctx in ("qn", "kn"):  # norm scales: replicated
        return P(*([None] * nd))
    if ctx in _REPL:
        return P(*([None] * nd))
    if ctx == "embed" and name == "w":
        return P(*([None] * (nd - 2) + ["model", fs]))
    if ctx == "head" and name == "w":
        return P(*([None] * (nd - 2) + [fs, "model"]))
    if ctx == "r":  # slstm recurrent (H, Dh, 4Dh) — heads over model
        return P(*([None] * (nd - 3) + ["model", None, None]))
    if name == "b":  # biases follow their matrix's output dim
        if ctx in _ROW:
            return P(*([None] * nd))
        return P(*([None] * (nd - 1) + ["model"]))
    if name == "conv_w":  # depthwise conv (K, di): channels over model
        return P(*([None] * (nd - 1) + ["model"]))
    if name in ("conv_b", "D"):
        return P(*([None] * (nd - 1) + ["model"]))
    if name == "A_log":  # (di, N)
        return P(*([None] * (nd - 2) + ["model", None]))
    if ctx in _COL or name in _COL:
        if nd >= 2:
            return P(*([None] * (nd - 2) + [fs, "model"]))
    if ctx in _ROW or name in _ROW:
        if nd >= 2:
            return P(*([None] * (nd - 2) + ["model", fs]))
    if name == "w" and nd >= 2:  # generic dense (treat as column)
        return P(*([None] * (nd - 2) + [fs, "model"]))
    return P(*([None] * nd))  # norms, scalars: replicated


def _mamba_gn_fix(path: str, spec: P, leaf) -> P:
    # groupnorm scales over the inner dim (model-sharded channels)
    if path.endswith("gn/w"):
        return P(*([None] * (leaf.ndim - 1) + ["model"]))
    return spec


def divisibility_fix(spec: P, leaf, sizes: dict[str, int]) -> P:
    """Drop sharding on any dim the mesh axes do not divide."""
    entries = list(spec)
    for i, ax in enumerate(entries):
        if ax is None:
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        tot = 1
        for a in axs:
            tot *= sizes.get(a, 1)
        if leaf.shape[i] % tot != 0:
            entries[i] = None
    return P(*entries)


def param_specs(
    params_shape: Any,
    cfg: ArchConfig,
    *,
    ep_axes=(),
    fsdp: bool = False,
    mesh=None,
    ep: int = 1,
):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}

    def f(path, leaf):
        s = _path_str(path)
        spec = param_spec(s, leaf, cfg, ep_axes=ep_axes, fsdp=fsdp, ep=ep)
        spec = _mamba_gn_fix(s, spec, leaf)
        # param_spec indexes dims from the right, so stacked segment params
        # (leading repeats dim) need no shifting; finally guard divisibility.
        if sizes:
            spec = divisibility_fix(spec, leaf, sizes)
        return spec

    return jax.tree_util.tree_map_with_path(f, params_shape)


# --------------------------------------------------------------------- caches
def cache_specs(cache_shape: Any, cfg: ArchConfig, mesh, shape: InputShape):
    """Batch over dp when divisible; kv-heads or sequence over 'model'."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]
    msize = sizes.get("model", 1)
    batch_ax = dp if shape.global_batch % dp_size == 0 else (
        ("data",) if shape.global_batch % sizes.get("data", 1) == 0 else None
    )

    def f(path, leaf):
        s = _path_str(path)
        nd = leaf.ndim
        name = s.split("/")[-1] if "/" in s else s
        # leading dims may include a stacked segment dim; index from the right
        if s.endswith("k") or s.endswith("v"):  # (..., B, S, Hkv, Dh)
            hkv = leaf.shape[-2]
            seq_spec = None
            head_spec = "model" if hkv % msize == 0 else None
            if head_spec is None and leaf.shape[-3] % msize == 0:
                seq_spec = "model"
            return P(*([None] * (nd - 4) + [batch_ax, seq_spec, head_spec, None]))
        if s.endswith("ckv") or s.endswith("kr"):  # (..., B, S, dc)
            seq_spec = "model" if leaf.shape[-2] % msize == 0 else None
            return P(*([None] * (nd - 3) + [batch_ax, seq_spec, None]))
        if s.endswith("conv"):  # (..., B, K-1, di)
            return P(*([None] * (nd - 3) + [batch_ax, None, "model"]))
        if s.endswith("h"):  # mamba (..., B, N, D) / slstm h (B, H, Dh)
            return P(*([None] * (nd - 3) + [batch_ax, None, "model"]))
        if s.endswith("C"):  # mlstm (..., B, H, Dk, Dv)
            return P(*([None] * (nd - 4) + [batch_ax, "model", None, None]))
        if s.endswith("n") or s.endswith("c"):  # (..., B, H, Dh)
            return P(*([None] * (nd - 3) + [batch_ax, "model", None]))
        if s.endswith("m"):  # (..., B, H)
            return P(*([None] * (nd - 2) + [batch_ax, "model"]))
        return P(*([None] * nd))

    def fix(spec: P, leaf) -> P:
        # guard: any sharded entry must divide the dim
        entries = list(spec)
        for i, ax in enumerate(entries):
            if ax is None:
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            tot = 1
            for a in axs:
                tot *= sizes.get(a, 1)
            if leaf.shape[i] % tot != 0:
                entries[i] = None
        return P(*entries)

    return jax.tree_util.tree_map_with_path(lambda p, l: fix(f(p, l), l), cache_shape)


def optimizer_specs(p_specs: Any, params_shape: Any, mesh, *, min_size: int = 1 << 20):
    """ZeRO-1 optimizer-state sharding: Adam moments of large weights get one
    extra 'data'-sharded dim (weights themselves stay replicated over data —
    sharding weight dims over the batch axis makes GSPMD reshard activations
    instead of gathering weights)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsize = sizes.get("data", 1)

    def f(spec: P, leaf) -> P:
        n = 1
        for d in leaf.shape:
            n *= d
        if n < min_size:
            return spec
        # a mesh axis may appear at most once per spec (expert weights
        # already consume 'data' via wide EP)
        used = set()
        for ax in spec:
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a is not None:
                    used.add(a)
        if "data" in used:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, ax in enumerate(entries):
            if ax is None and leaf.shape[i] % dsize == 0:
                entries[i] = "data"
                return P(*entries)
        return spec

    return jax.tree.map(f, p_specs, params_shape, is_leaf=lambda x: isinstance(x, P))


def batch_specs(shape: InputShape, cfg: ArchConfig, mesh) -> dict[str, P]:
    dp = dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]
    b_ax = dp if shape.global_batch % dp_size == 0 else (
        ("data",) if shape.global_batch % sizes.get("data", 1) == 0 else None
    )
    out = {"tokens": P(b_ax, None), "labels": P(b_ax, None)}
    if cfg.input_mode == "embeds":
        out["embeds"] = P(b_ax, None, None)
    return out


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
