"""Backward equivalence: the unified core reproduces the seed replay numbers.

The frozen legacy loops live in `repro.serving.reference`; on uniform
arrivals with default tail semantics the event-driven/vectorized subsystem
must match them within 1e-9 — `ServeResult` per-frame e2e latencies and
module stats for the engine, `SimResult` aggregates for the simulator —
across the seed apps and both dispatch policies.
"""
import numpy as np
import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.core.dispatch import Policy
from repro.serving import (
    ServingEngine,
    engine_run_reference,
    simulate,
    simulate_reference,
)
from repro.workloads import synth_profiles
from repro.workloads.apps import ACTDET, CAPTION, FACE, POSE, TRAFFIC, make_workload

PROFILES = synth_profiles()
SEED_APPS = [
    (TRAFFIC, 100.0, 2.0),
    (FACE, 150.0, 2.5),
    (POSE, 60.0, 3.0),
    (CAPTION, 90.0, 2.5),
    (ACTDET, 80.0, 3.0),
]


def _plans():
    for app, rate, slo in SEED_APPS:
        plan = Planner(B.HARPAGON).plan(make_workload(app, rate=rate, slo=slo), PROFILES)
        if plan.feasible:
            yield app, rate, plan


@pytest.mark.parametrize("policy", [Policy.TC, Policy.RR])
def test_simulator_matches_legacy(policy):
    checked = 0
    for app, rate, plan in _plans():
        for m, s in plan.schedules.items():
            allocs = list(s.allocs)
            if any(a.dummy > 0 for a in allocs):
                continue  # legacy simulator streamed real requests only
            total = sum(a.rate for a in allocs)
            ref = simulate_reference(allocs, total, policy=policy, n_requests=900)
            new = simulate(allocs, total, policy=policy, n_requests=900)
            assert new.n_requests == ref.n_requests, (app.name, m)
            assert new.max_latency == pytest.approx(ref.max_latency, abs=1e-9)
            assert new.mean_latency == pytest.approx(ref.mean_latency, abs=1e-9)
            assert set(new.per_machine_max) == set(ref.per_machine_max)
            for mid, worst in ref.per_machine_max.items():
                assert new.per_machine_max[mid] == pytest.approx(worst, abs=1e-9)
            checked += 1
    assert checked >= 5


@pytest.mark.parametrize("policy", [Policy.TC, Policy.RR])
def test_engine_matches_legacy(policy):
    """GOLDEN UPDATE (causal tail-flush fix): the engine now follows the
    pipelined event loop's CAUSAL delivery order at DAG joins — an
    end-of-stream tail flush backdates its batch into the past, but its
    downstream cascade still arrives *after* every normal completion, and
    a join frame is delivered at its last-resolving parent's processing
    instant.  The frozen seed loop replays modules flat and interleaves
    those backdated completions by value, i.e. acausally; where the two
    orders differ (a small end-of-stream cohort — at these run lengths only
    under RR, e.g. actdet diverges on 37 frames by <= 0.42 s at 400 uniform
    frames under TC) the event loop is authoritative and the engine is
    pinned to it bit-exactly instead.  Everywhere else the seed numbers are
    unchanged.
    """
    from repro.serving.pipeline import PipelineConfig

    checked = 0
    for app, rate, plan in _plans():
        ref = engine_run_reference(plan, 1000, rate, policy=policy)
        new = ServingEngine(plan, policy=policy).run(1000, rate)
        assert len(new.e2e_latencies) == len(ref.e2e_latencies), app.name
        a = np.asarray(new.e2e_latencies)
        b = np.asarray(ref.e2e_latencies)
        if policy is Policy.TC:
            # bit-kept: flat order == causal order at these seed points
            np.testing.assert_allclose(a, b, atol=1e-9)
            assert new.attainment == pytest.approx(ref.attainment, abs=1e-12)
            assert new.p99 == pytest.approx(ref.p99, abs=1e-9)
        else:
            # causal semantics: the engine must equal the event loop exactly
            pipe = ServingEngine(plan, policy=policy).run(
                1000, rate, pipeline=PipelineConfig(reference=True)
            )
            np.testing.assert_array_equal(a, np.asarray(pipe.e2e_latencies))
            # ... and the seed-loop divergence stays a bounded tail cohort
            mism = np.abs(a - b) > 1e-9
            assert mism.mean() <= 0.15, (app.name, int(mism.sum()))
            assert new.attainment == pytest.approx(ref.attainment, abs=5e-3)
        for m in plan.workload.app.modules:
            rs, ns = ref.module_stats[m], new.module_stats[m]
            assert ns.batches == rs.batches, (app.name, m)
            assert len(ns.latencies) == len(rs.latencies)
            if policy is Policy.TC:
                assert ns.max_latency == pytest.approx(rs.max_latency, abs=1e-9)
                # latency multisets agree (ordering differs: per-instance vs
                # per-machine-per-group in the seed loop)
                np.testing.assert_allclose(
                    np.sort(ns.latencies), np.sort(rs.latencies), atol=1e-9
                )
        checked += 1
    assert checked >= 3


def test_engine_event_method_matches_vectorized_on_dag():
    """The event core must agree with the kernel end-to-end through the DAG
    adapter too (multi-module, fanout, non-uniform arrivals)."""
    from repro.serving.replay import replay_module
    from repro.core.dispatch import dispatch_runs, expand_machines
    from repro.serving.arrivals import make_arrivals

    for app, rate, plan in _plans():
        for m, s in plan.schedules.items():
            machines = expand_machines(list(s.allocs))
            total = sum(a.rate for a in s.allocs)
            ready = make_arrivals("mmpp", 300, total, seed=4)
            runs = dispatch_runs(machines, 300, Policy.TC)
            vec = replay_module(machines, ready, runs, timeout=0.25)
            ev = replay_module(machines, ready, runs, timeout=0.25, method="events")
            np.testing.assert_allclose(
                vec.finish, ev.finish, atol=1e-9, equal_nan=True
            )
        break  # one app's schedules suffice here; core x-val lives elsewhere
