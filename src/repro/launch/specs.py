"""ShapeDtypeStruct input specs + jitted step functions per (arch x shape).

Nothing here allocates device memory: parameters, optimizer state and KV
caches are `jax.eval_shape` stand-ins; the dry-run lowers/compiles only.

Step kinds:
* train   — loss (CE + MoE aux + MTP) -> grads -> AdamW update
* prefill — forward S tokens, emit last-token logits + populated KV cache
* decode  — one token against a seq_len KV cache (cache donated)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, InputShape
from ..models import Model
from ..training.loop import make_loss_fn
from ..training.optimizer import OptConfig, adamw_init, adamw_update

# archs that need a sliding-window variant to run long_500k (DESIGN.md Sec. 4)
WINDOW_OVERRIDE = {
    "smollm-360m": 8192,
    "gemma-7b": 8192,
    "qwen1.5-4b": 8192,
    "qwen2-moe-a2.7b": 8192,
    "qwen2-vl-2b": 8192,
}
# (arch, shape) pairs that are skipped, with the reason recorded
SKIPS = {
    ("musicgen-medium", "long_500k"): "524k EnCodec frames ~ 3h audio; outside "
    "the model's 30s regime — windowing is musically meaningless (DESIGN.md 4)",
}


def effective_config(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Apply long-context variants; raises KeyError on skipped pairs."""
    if (cfg.name, shape.name) in SKIPS:
        raise KeyError(SKIPS[(cfg.name, shape.name)])
    if shape.name == "long_500k" and cfg.name in WINDOW_OVERRIDE:
        return cfg.with_sliding_window(WINDOW_OVERRIDE[cfg.name])
    return cfg


def input_specs(cfg: ArchConfig, shape: InputShape, model: Model | None = None) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every step input (weak-type-correct)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.input_mode == "embeds":
            return {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if shape.kind == "prefill":
        if cfg.input_mode == "embeds":
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.dtype(cfg.compute_dtype))}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token + cache of seq_len
    model = model or Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "cache": cache,
        "idx": jax.ShapeDtypeStruct((), i32),
    }


def params_shape(model: Model) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def opt_state_shape(params_sh: Any) -> Any:
    return jax.eval_shape(adamw_init, params_sh)


# ------------------------------------------------------------------- steps
def make_train_fn(model: Model, opt_cfg: OptConfig | None = None):
    opt_cfg = opt_cfg or OptConfig()
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


def make_prefill_fn(model: Model, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len

    def prefill_step(params, batch):
        cache = model.init_cache(B, S)
        out = model.forward(
            params,
            batch.get("tokens"),
            embeds=batch.get("embeds"),
            cache=cache,
            idx=0,
            compute_logits=False,
            return_hidden=True,
        )
        # serving: only the last position's logits are needed — unembedding
        # all S positions wastes V x d matmul + a huge logits materialization
        logits = model.unembed(params, out.hidden[:, -1:])
        return logits[:, 0], out.cache

    return prefill_step


def make_decode_fn(model: Model):
    def decode_step(params, batch):
        out = model.forward(
            params, batch["tokens"], cache=batch["cache"], idx=batch["idx"]
        )
        return out.logits[:, 0], out.cache

    return decode_step
