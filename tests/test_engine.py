"""Serving engine: fanout expansion, SLO attainment, baseline comparison."""
import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.core.dispatch import Policy
from repro.serving import ServingEngine
from repro.workloads import synth_profiles
from repro.workloads.apps import CAPTION, FACE, TRAFFIC, make_workload

PROFILES = synth_profiles()


def test_fanout_instances():
    """traffic: vehicle_cls fanout 2.0, pedestrian_cls 3.0 — batch counts scale."""
    wl = make_workload(TRAFFIC, rate=100.0, slo=2.0)
    plan = Planner(B.HARPAGON).plan(wl, PROFILES)
    assert plan.feasible
    res = ServingEngine(plan).run(1000, 100.0)
    st = res.module_stats
    det = sum(len(g) for g in [st["ssd_detect"].latencies])
    veh = len(st["vehicle_cls"].latencies)
    ped = len(st["pedestrian_cls"].latencies)
    # instances per frame follow the fanout ratios (tail batches may drop some)
    assert veh == pytest.approx(2 * det, rel=0.1)
    assert ped == pytest.approx(3 * det, rel=0.1)


def test_attainment_across_apps():
    for app, rate in ((FACE, 150.0), (CAPTION, 90.0)):
        wl = make_workload(app, rate=rate, slo=2.5)
        plan = Planner(B.HARPAGON).plan(wl, PROFILES)
        if not plan.feasible:
            continue
        res = ServingEngine(plan).run(1200, rate)
        assert res.attainment >= 0.95, (app.name, res.attainment)


def test_rr_engine_worse_or_equal_latency():
    """Serving a TC plan with RR dispatch must not beat TC's worst latency."""
    wl = make_workload(FACE, rate=200.0, slo=2.0)
    plan = Planner(B.HARPAGON).plan(wl, PROFILES)
    assert plan.feasible
    tc = ServingEngine(plan, policy=Policy.TC).run(1500, 200.0)
    rr = ServingEngine(plan, policy=Policy.RR).run(1500, 200.0)
    assert max(tc.e2e_latencies) <= max(rr.e2e_latencies) + 0.15
