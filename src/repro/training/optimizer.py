"""AdamW + cosine schedule + global-norm clipping, in pure JAX."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * factor.astype(x.dtype), tree), norm


def adamw_init(params: Any) -> dict[str, Any]:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def adamw_update(
    cfg: OptConfig, params: Any, grads: Any, opt_state: dict[str, Any]
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [x[0] for x in new])
    new_state = {
        "m": jax.tree.unflatten(treedef, [x[1] for x in new]),
        "v": jax.tree.unflatten(treedef, [x[2] for x in new]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
