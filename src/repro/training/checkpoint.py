"""Checkpointing: params/opt-state pytrees <-> .npz, path-keyed."""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: Any) -> None:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for keypath, leaf in flat:
        arrays[_path_str(keypath)] = np.asarray(leaf)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for keypath, leaf in flat:
            key = _path_str(keypath)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if arr.shape != leaf.shape:
                raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)
