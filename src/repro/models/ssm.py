"""State-space / recurrent mixers: Mamba (Jamba) and xLSTM (mLSTM + sLSTM).

TPU adaptation (DESIGN.md Sec. 3): the selective scan is expressed as an
associative linear recurrence (`kernels.ops.ssm_scan`) rather than a CUDA
sequential kernel; the mLSTM uses the chunkwise-parallel matrix-memory form
(MXU-friendly) instead of warp-level primitives; the sLSTM is a lax.scan —
inherently sequential, exactly as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ops
from .layers import Params, _dense_init, dense


# ------------------------------------------------------------------- Mamba
def mamba_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.d_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 7)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": _dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], di, dt_rank + 2 * N, dtype),
        "dt_proj": _dense_init(ks[3], dt_rank, di, dtype, bias=True),
        "A_log": jnp.log(A),  # fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], di, d, dtype),
    }


def mamba_cache_init(cfg: ArchConfig, batch: int, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, cfg.d_state, di), jnp.float32),  # (B, N, D) layout
    }


def _mamba_ssm_inputs(p: Params, cfg: ArchConfig, x: jax.Array):
    """x: (B, L, di) post-conv activations -> (dt, A, B, C) for the recurrence."""
    N = cfg.d_state
    dt_rank = p["dt_proj"]["w"].shape[0]
    proj = dense(p["x_proj"], x)
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt).astype(jnp.float32))  # (B, L, di)
    A = -jnp.exp(p["A_log"])  # (di, N)
    return dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def mamba_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # (B, L, d)
    *,
    cache: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    B, L, d = x.shape
    di = cfg.ssm_expand * d
    xz = dense(p["in_proj"], x)
    xm, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv1d
    K = p["conv_w"].shape[0]
    if cache is not None:
        ctx = jnp.concatenate([cache["conv"].astype(xm.dtype), xm], axis=1)
    else:
        ctx = jnp.pad(xm, ((0, 0), (K - 1, 0), (0, 0)))
    win = jnp.stack([ctx[:, i : i + L] for i in range(K)], axis=0)  # (K, B, L, di)
    xc = jnp.einsum("kbld,kd->bld", win.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32)).astype(xm.dtype)

    dt, A, Bm, Cm = _mamba_ssm_inputs(p, cfg, xc)
    h0 = cache["h"] if cache is not None else None
    if L == 1 and cache is not None:  # single-step decode: h is (B, N, D)
        a = jnp.exp(dt[:, 0][:, None, :] * A.T[None])  # (B, N, D)
        bx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[:, None, :] * Bm[:, 0][..., None]
        h = a * cache["h"] + bx
        y = jnp.einsum("bnd,bn->bd", h, Cm[:, 0])[:, None]
        h_last = h
    else:
        y, h_last = ops.selective_scan(xc, dt, A, Bm, Cm, h0)
        y = y.astype(jnp.float32)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": ctx[:, -(K - 1) :].astype(cache["conv"].dtype), "h": h_last}
    return out, new_cache


# ------------------------------------------------------------------- mLSTM
def mlstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": _dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (4, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "q": _dense_init(ks[2], di, di, dtype),
        "k": _dense_init(ks[3], di, di, dtype),
        "v": _dense_init(ks[4], di, di, dtype),
        "if_gate": _dense_init(ks[5], di, 2 * H, dtype, bias=True),
        "gn": {"w": jnp.ones((di,), dtype)},  # per-head groupnorm scale
        "down": _dense_init(ks[6], di, d, dtype),
    }


def mlstm_cache_init(cfg: ArchConfig, batch: int, dtype):
    di = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    Dh = di // H
    return {
        "conv": jnp.zeros((batch, 3, di), dtype),
        "C": jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        "n": jnp.zeros((batch, H, Dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def _headify(x: jax.Array, H: int) -> jax.Array:
    B, L, di = x.shape
    return x.reshape(B, L, H, di // H)


def _groupnorm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Per-head RMS-style normalization; x: (B, L, H, Dh)."""
    B, L, H, Dh = x.shape
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + 1e-6)
    return (y.reshape(B, L, H * Dh) * w.astype(jnp.float32)).astype(x.dtype)


def mlstm_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    cache: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    B, L, d = x.shape
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    Dh = di // H
    xz = dense(p["up"], x)
    xm, z = jnp.split(xz, 2, axis=-1)
    K = p["conv_w"].shape[0]
    if cache is not None:
        ctx = jnp.concatenate([cache["conv"].astype(xm.dtype), xm], axis=1)
    else:
        ctx = jnp.pad(xm, ((0, 0), (K - 1, 0), (0, 0)))
    win = jnp.stack([ctx[:, i : i + L] for i in range(K)], axis=0)
    xc = jnp.einsum("kbld,kd->bld", win.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32)).astype(xm.dtype)

    q = _headify(dense(p["q"], xc), H)
    k = _headify(dense(p["k"], xc), H)
    v = _headify(dense(p["v"], xm), H)
    gif = dense(p["if_gate"], xc).astype(jnp.float32)
    li = gif[..., :H]  # log input gate (pre-exp)
    lf = jax.nn.log_sigmoid(gif[..., H:])  # log forget gate

    new_cache = None
    if L == 1 and cache is not None:
        m_prev, C_prev, n_prev = cache["m"], cache["C"], cache["n"]
        li0, lf0 = li[:, 0], lf[:, 0]
        m_new = jnp.maximum(lf0 + m_prev, li0)
        i_s = jnp.exp(li0 - m_new)[..., None]
        f_s = jnp.exp(lf0 + m_prev - m_new)[..., None]
        kf = k[:, 0].astype(jnp.float32) * (Dh ** -0.5)
        vf = v[:, 0].astype(jnp.float32)
        C = f_s[..., None] * C_prev + i_s[..., None] * kf[..., :, None] * vf[..., None, :]
        n = f_s * n_prev + i_s * kf
        qf = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhd,bhdv->bhv", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new))
        y = (num / den[..., None]).astype(x.dtype)[:, None]  # (B, 1, H, Dh)
        new_cache = {"conv": ctx[:, -(K - 1) :].astype(cache["conv"].dtype), "C": C, "n": n, "m": m_new}
    else:
        y = ops.mlstm(q, k, v, li, lf)
        if cache is not None:
            # rebuild the terminal recurrent state for subsequent decode
            kf = k.astype(jnp.float32) * (Dh ** -0.5)
            vf = v.astype(jnp.float32)
            F = jnp.cumsum(lf, axis=1)
            m_new = jnp.max(F[:, -1:, :] - F + li, axis=1)  # (B, H)
            wlog = F[:, -1:, :] - F + li - m_new[:, None]
            w = jnp.exp(wlog)  # (B, L, H)
            C = jnp.einsum("blh,blhd,blhv->bhdv", w, kf, vf)
            n = jnp.einsum("blh,blhd->bhd", w, kf)
            new_cache = {
                "conv": ctx[:, -(K - 1) :].astype(cache["conv"].dtype),
                "C": C,
                "n": n,
                "m": m_new,
            }
    y = _groupnorm(y, p["gn"]["w"])
    out = dense(p["down"], y * jax.nn.silu(z))
    return out, new_cache


# ------------------------------------------------------------------- sLSTM
def slstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    Dh = d // H
    ks = jax.random.split(key, 4)
    dff = -(-(d * 4 // 3) // 8) * 8  # ~4/3 expansion, rounded up to multiple of 8
    return {
        "w": _dense_init(ks[0], d, 4 * d, dtype, bias=True),  # i f z o from input
        "r": (jax.random.normal(ks[1], (H, Dh, 4 * Dh)) * (Dh ** -0.5)).astype(dtype),
        "gn": {"w": jnp.ones((d,), dtype)},
        "up": _dense_init(ks[2], d, 2 * dff, dtype),
        "down": _dense_init(ks[3], dff, d, dtype),
    }


def slstm_cache_init(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    Dh = d // H
    z = lambda: jnp.zeros((batch, H, Dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.zeros((batch, H), jnp.float32)}


def _slstm_step(p: Params, cfg: ArchConfig, state, wx_t):
    """One sLSTM step.  wx_t: (B, 4d) precomputed input contribution."""
    H = cfg.n_heads
    d = cfg.d_model
    Dh = d // H
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    rh = jnp.einsum("bhd,hdk->bhk", h, p["r"].astype(jnp.float32))  # (B, H, 4Dh)
    g = wx_t.reshape(-1, H, 4 * Dh).astype(jnp.float32) + rh
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    li = gi.mean(-1)  # scalar gates per head
    lf = jax.nn.log_sigmoid(gf.mean(-1))
    zt = jnp.tanh(gz)
    ot = jax.nn.sigmoid(go)
    m_new = jnp.maximum(lf + m, li)
    i_s = jnp.exp(li - m_new)[..., None]
    f_s = jnp.exp(lf + m - m_new)[..., None]
    c_new = f_s * c + i_s * zt
    n_new = f_s * n + i_s
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new


def slstm_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    cache: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    B, L, d = x.shape
    H = cfg.n_heads
    wx = dense(p["w"], x)  # (B, L, 4d)
    state = cache or slstm_cache_init(cfg, B, x.dtype)
    state = {k: v for k, v in state.items()}

    def step(s, wx_t):
        return _slstm_step(p, cfg, s, wx_t)

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, L, d).astype(x.dtype)  # (B, L, H*Dh)
    y = _groupnorm(hs.reshape(B, L, H, d // H), p["gn"]["w"])
    u = dense(p["up"], y)
    a, b = jnp.split(u, 2, axis=-1)
    out = dense(p["down"], jax.nn.gelu(a) * b)
    new_cache = state if cache is not None else None
    return out, new_cache
