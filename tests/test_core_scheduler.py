"""Paper worked examples: Table II, Sec. II / III-B numbers, Theorems 1-2."""
import math

import pytest

from repro.core import (
    Alloc,
    Policy,
    generate_config,
    generate_config_ktuple,
    module_wcl,
    total_cost,
)
from repro.core.profiles import TABLE1_M1, TABLE1_M3, TABLE_M4
from repro.core.residual import apply_dummy, leftover_workloads
from repro.core.scheduler import get_wcl


def costs(allocs):
    return round(total_cost(allocs), 6)


class TestSecIIExample:
    """M1, 100 req/s, SLO 0.4 s (paper Sec. II)."""

    def test_round_robin_needs_5_machines(self):
        ok, allocs = generate_config_ktuple(100.0, 0.4, TABLE1_M1, Policy.RR, 2)
        assert ok
        assert costs(allocs) == 5.0  # batch 4, 5 machines
        assert allocs[0].config.batch == 4

    def test_tc_dispatch_needs_4_machines(self):
        ok, allocs = generate_config(100.0, 0.4, TABLE1_M1, Policy.TC)
        assert ok
        assert costs(allocs) == 4.0  # batch 8 feasible only with TC dispatch
        assert allocs[0].config.batch == 8

    def test_wcl_values_match_paper(self):
        # paper: batch-dispatch L_wc for b=2,4,8 are 0.18, 0.24, 0.40
        by_batch = {c.batch: c for c in TABLE1_M1.configs}
        for b, expect in [(2, 0.18), (4, 0.24), (8, 0.40)]:
            assert get_wcl(by_batch[b], Policy.TC, 100.0, full=True) == pytest.approx(expect)


class TestTable2:
    """M3, 198 req/s, SLO 1.0 s — scheduling methods S1-S4."""

    def test_s1_nexus_style(self):
        ok, s1 = generate_config_ktuple(198.0, 1.0, TABLE1_M3, Policy.RR, 2)
        assert ok and costs(s1) == 6.3
        assert [(a.config.batch, round(a.machines, 2)) for a in s1] == [(8, 6.0), (2, 0.3)]

    def test_s2_batch_aware_two_tuple(self):
        ok, s2 = generate_config_ktuple(198.0, 1.0, TABLE1_M3, Policy.TC, 2)
        assert ok and costs(s2) == 5.9
        assert [(a.config.batch, round(a.machines, 2)) for a in s2] == [(32, 4.0), (2, 1.9)]

    def test_s3_multi_tuple(self):
        ok, s3 = generate_config(198.0, 1.0, TABLE1_M3, Policy.TC)
        assert ok and costs(s3) == 5.3
        assert [(a.config.batch, round(a.machines, 2)) for a in s3] == [
            (32, 4.0),
            (8, 1.0),
            (2, 0.3),
        ]

    def test_s4_dummy(self):
        ok, s3 = generate_config(198.0, 1.0, TABLE1_M3, Policy.TC)
        dummy, s4 = apply_dummy(198.0, 1.0, TABLE1_M3, s3, Policy.TC)
        assert dummy == pytest.approx(2.0)
        assert costs(s4) == 5.0
        assert [(a.config.batch, round(a.machines, 2)) for a in s4] == [(32, 5.0)]

    def test_leftover_workloads(self):
        ok, s3 = generate_config(198.0, 1.0, TABLE1_M3, Policy.TC)
        u = leftover_workloads(s3)
        assert u[0] == pytest.approx(38.0)  # paper: u for b32 = 32 + 6


class TestTheorem1:
    def test_m4_worked_example(self):
        """A, B at b6 d2.0, C at b2 d1.0; T = 8 req/s (Sec. III-B)."""
        c6, c2 = TABLE_M4.configs
        allocs = [Alloc(c6, 2.0, 6.0), Alloc(c2, 1.0, 2.0)]
        # w for A/B is 8, for C is 2
        assert module_wcl(allocs, Policy.TC) == pytest.approx(2.0 + 6 / 8)
        # RR: full machines 2d = 4.0
        assert module_wcl(allocs, Policy.RR) == pytest.approx(4.0)
        # DT (Scrooge): d + b/t = 2d for every machine
        assert module_wcl(allocs, Policy.DT) == pytest.approx(4.0)

    def test_tc_never_worse_than_rr(self):
        for T in (10.0, 50.0, 198.0, 300.0):
            ok, allocs = generate_config(T, 2.0, TABLE1_M3, Policy.TC)
            if not ok:
                continue
            assert module_wcl(allocs, Policy.TC) <= module_wcl(allocs, Policy.RR) + 1e-9


class TestAlgorithm1:
    def test_covers_workload_exactly(self):
        for T in (1.0, 37.5, 100.0, 198.0, 1000.0):
            ok, allocs = generate_config(T, 1.0, TABLE1_M3, Policy.TC)
            if ok:
                assert sum(a.rate for a in allocs) == pytest.approx(T)
                assert module_wcl(allocs, Policy.TC) <= 1.0 + 1e-9

    def test_infeasible_slo(self):
        ok, allocs = generate_config(100.0, 0.05, TABLE1_M3, Policy.TC)
        assert not ok and allocs == []

    def test_zero_rate(self):
        ok, allocs = generate_config(0.0, 1.0, TABLE1_M3, Policy.TC)
        assert ok and allocs == []

    def test_ktuple_1_single_config(self):
        ok, allocs = generate_config_ktuple(100.0, 1.0, TABLE1_M3, Policy.RR, 1)
        assert ok
        assert len({a.config for a in allocs}) == 1
        assert sum(a.rate for a in allocs) == pytest.approx(100.0)
