"""Brute-force optimal latency split (the paper's "optimal solution").

The paper derives the optimum by exhaustive search (35.9 s/workload on
average).  We implement it as an exact DP over the series-parallel DAG with a
finely discretized per-module budget grid: for every module the *full*
Harpagon scheduler (Algorithm 1 + dummy generator) is evaluated at each grid
budget, and budgets are composed along the SP tree (series = convolution,
parallel = shared budget).  With a fine enough grid this dominates every
splitting heuristic; as a guard we additionally take the min with Harpagon's
own plan (Harpagon's solution is a feasible point of the search space, so a
true exhaustive search would find it).
"""
from __future__ import annotations

import math
from typing import Mapping

from .dag import Leaf, Par, Series, SP, Workload
from .dispatch import Policy
from .profiles import ModuleProfile
from .residual import schedule_module

INF = math.inf

# Cross-workload curve cache.  Workloads whose (rate, slo) land in the same
# ~0.5% log-quantized bucket share one curve: the first workload to touch a
# bucket prices it at its *exact* (T, slo) and later bucket-mates reuse that
# curve.  Identical rates/SLOs (the replayed-suite and repeated-preset case
# the ROADMAP's ~60 ms/workload figure is dominated by) therefore hit with
# zero approximation; distinct-but-close rates pay at most the bucket width
# in rate error.  Curves are keyed on the full profile (frozen/hashable), so
# a profile swap can never serve a stale curve.
_CURVE_STEP = math.log(1.005)
_CURVE_CACHE: dict[tuple, list[float]] = {}
_CURVE_CACHE_MAX = 4096
_CURVE_STATS = {"hits": 0, "misses": 0}


def _quantized(x: float) -> int:
    """Log-bucket index of a positive quantity (~0.5% relative width)."""
    if x <= 0.0:
        return -1
    return math.ceil(math.log(x) / _CURVE_STEP - 1e-9)


def curve_cache_clear() -> None:
    """Drop every cached cost curve (benchmarks' cold-cache baseline)."""
    _CURVE_CACHE.clear()
    _CURVE_STATS["hits"] = _CURVE_STATS["misses"] = 0


def curve_cache_stats() -> dict[str, int]:
    """Hit/miss/size counters since the last `curve_cache_clear`."""
    return {**_CURVE_STATS, "size": len(_CURVE_CACHE)}


def _module_cost_curve(
    m: str,
    T: float,
    slo: float,
    nq: int,
    profile: ModuleProfile,
    policy: Policy,
    use_dummy: bool,
) -> list[float]:
    """cost[k] = full scheduler cost of module m at budget k * slo / nq.

    Cached across workloads by quantized (rate, slo) bucket — see the cache
    comment above.  Returned lists are shared and must be treated read-only
    (every caller only indexes them).
    """
    key = (m, _quantized(T), _quantized(slo), nq, policy, use_dummy, profile)
    cached = _CURVE_CACHE.get(key)
    if cached is not None:
        _CURVE_STATS["hits"] += 1
        return cached
    _CURVE_STATS["misses"] += 1
    if len(_CURVE_CACHE) >= _CURVE_CACHE_MAX:
        _CURVE_CACHE.clear()
    curve = _module_cost_curve_uncached(m, T, slo, nq, profile, policy, use_dummy)
    _CURVE_CACHE[key] = curve
    return curve


def _module_cost_curve_uncached(
    m: str,
    T: float,
    slo: float,
    nq: int,
    profile: ModuleProfile,
    policy: Policy,
    use_dummy: bool,
) -> list[float]:
    """The uncached curve evaluation (see `_module_cost_curve`)."""
    q = slo / nq
    cost = [INF] * (nq + 1)
    # Budgets where the cost can change: each config's wcl is a breakpoint.
    # Evaluating every grid point is O(nq * |configs|); dedupe identical
    # feasible-sets by walking the grid and reusing the previous result when
    # no breakpoint was crossed.  The per-config WCL is L-independent, so
    # one batched call replaces the nq * |configs| scalar evaluations.
    prev_feasible_key: tuple[bool, ...] | None = None
    prev_cost = INF
    from .dispatch import config_arrays
    from .scheduler import get_wcl_batch

    arrs = config_arrays(profile.configs)
    wcl_arr = get_wcl_batch(arrs, policy, T, full=T >= arrs.throughput)

    for k in range(1, nq + 1):
        L = k * q
        key = tuple((wcl_arr <= L).tolist())
        if key == prev_feasible_key:
            cost[k] = prev_cost
            continue
        s = schedule_module(m, T, L, profile, policy, use_dummy=use_dummy)
        cost[k] = s.cost if s is not None else INF
        prev_feasible_key, prev_cost = key, cost[k]
    # enforce monotone non-increasing (more budget never costs more)
    for k in range(1, nq + 1):
        cost[k] = min(cost[k], cost[k - 1] if cost[k - 1] is not INF else cost[k])
    return cost


def _dp(sp: SP, nq: int, curves: Mapping[str, list[float]]) -> list[float]:
    if isinstance(sp, Leaf):
        return curves[sp.name]
    if isinstance(sp, Series):
        dp = _dp(sp.parts[0], nq, curves)
        for p in sp.parts[1:]:
            nxt = _dp(p, nq, curves)
            out = [INF] * (nq + 1)
            for a in range(nq + 1):
                da = dp[a]
                if da == INF:
                    continue
                for b in range(nq + 1 - a):
                    if nxt[b] == INF:
                        continue
                    v = da + nxt[b]
                    if v < out[a + b]:
                        out[a + b] = v
            for k in range(1, nq + 1):
                out[k] = min(out[k], out[k - 1])
            dp = out
        return dp
    parts = [_dp(p, nq, curves) for p in sp.parts]
    return [sum(p[k] for p in parts) for k in range(nq + 1)]


def _assign(sp: SP, k: int, nq: int, curves: Mapping[str, list[float]]) -> dict[str, int]:
    """Recover per-module grid budgets from the DP optimum at total ``k``.

    Mirrors the DP's composition: a Par node hands every branch the whole
    budget; a Series node re-runs the pairwise min-plus combination
    tracking the split point.  A leaf shrinks its budget to the *first*
    grid point achieving the (monotonized) curve value — the budget whose
    actual schedule realizes that cost, which also leaves the reassigner
    the largest end-to-end gap.
    """
    if isinstance(sp, Leaf):
        curve = curves[sp.name]
        if curve[k] == INF:
            return {sp.name: k}
        while k > 0 and curve[k - 1] == curve[k]:
            k -= 1
        return {sp.name: k}
    if isinstance(sp, Par):
        out: dict[str, int] = {}
        for p in sp.parts:
            out.update(_assign(p, k, nq, curves))
        return out
    out = {}
    rem = k
    for i, p in enumerate(sp.parts):
        if i == len(sp.parts) - 1:
            out.update(_assign(p, rem, nq, curves))
            break
        head = _dp(p, nq, curves)
        tail = _dp(Series(sp.parts[i + 1:]), nq, curves)
        best_a, best_v = 0, INF
        for a in range(rem + 1):
            v = head[a] + tail[rem - a]
            if v < best_v - 1e-15:
                best_v, best_a = v, a
        out.update(_assign(p, best_a, nq, curves))
        rem -= best_a
    return out


def _curves(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy,
    n_grid: int,
    use_dummy: bool,
) -> Mapping[str, list[float]]:
    return {
        m: _module_cost_curve(
            m, wl.rates[m], wl.slo, n_grid, profiles[m], policy, use_dummy
        )
        for m in wl.app.modules
    }


def optimal_cost(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    n_grid: int = 240,
    use_dummy: bool = True,
) -> float:
    """Exhaustive-split optimal serving cost (INF if the SLO is unsatisfiable)."""
    curves = _curves(wl, profiles, policy, n_grid, use_dummy)
    dp = _dp(wl.app.sp, n_grid, curves)
    return dp[n_grid]


def optimal_split(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    n_grid: int = 240,
    use_dummy: bool = True,
) -> dict[str, float] | None:
    """Per-module budgets realizing `optimal_cost`'s optimum (None if the
    SLO is unsatisfiable on the grid).  Backs `splitter.split_dp`: the
    planner schedules each module at the recovered budget with the same
    scheduler the curves were priced with, so the resulting plan's cost is
    the DP optimum (before the reassigner, which can only reduce it)."""
    curves = _curves(wl, profiles, policy, n_grid, use_dummy)
    dp = _dp(wl.app.sp, n_grid, curves)
    if dp[n_grid] == INF:
        return None
    q = wl.slo / n_grid
    ks = _assign(wl.app.sp, n_grid, n_grid, curves)
    return {m: ks[m] * q for m in wl.app.modules}
