"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,  # unused (every layer is MoE); shared experts = 4 x 1408
    vocab_size=151936,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    qkv_bias=True,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    d_ff_expert=1408,
    moe_every=1,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    d_ff_expert=64,
    n_experts=4,
    n_shared_experts=2,
    top_k=2,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
)
