"""Brute-force optimal latency split (the paper's "optimal solution").

The paper derives the optimum by exhaustive search (35.9 s/workload on
average).  We implement it as an exact DP over the series-parallel DAG with a
finely discretized per-module budget grid: for every module the *full*
Harpagon scheduler (Algorithm 1 + dummy generator) is evaluated at each grid
budget, and budgets are composed along the SP tree (series = convolution,
parallel = shared budget).  With a fine enough grid this dominates every
splitting heuristic; as a guard we additionally take the min with Harpagon's
own plan (Harpagon's solution is a feasible point of the search space, so a
true exhaustive search would find it).
"""
from __future__ import annotations

import math
from typing import Mapping

from .dag import Leaf, Par, Series, SP, Workload
from .dispatch import Policy
from .profiles import ModuleProfile
from .residual import schedule_module

INF = math.inf


def _module_cost_curve(
    m: str,
    T: float,
    slo: float,
    nq: int,
    profile: ModuleProfile,
    policy: Policy,
    use_dummy: bool,
) -> list[float]:
    """cost[k] = full scheduler cost of module m at budget k * slo / nq."""
    q = slo / nq
    cost = [INF] * (nq + 1)
    # Budgets where the cost can change: each config's wcl is a breakpoint.
    # Evaluating every grid point is O(nq * |configs|); dedupe identical
    # feasible-sets by walking the grid and reusing the previous result when
    # no breakpoint was crossed.
    prev_feasible_key: tuple[bool, ...] | None = None
    prev_cost = INF
    from .scheduler import get_wcl

    for k in range(1, nq + 1):
        L = k * q
        key = tuple(
            get_wcl(c, policy, T, full=T >= c.throughput) <= L for c in profile.configs
        )
        if key == prev_feasible_key:
            cost[k] = prev_cost
            continue
        s = schedule_module(m, T, L, profile, policy, use_dummy=use_dummy)
        cost[k] = s.cost if s is not None else INF
        prev_feasible_key, prev_cost = key, cost[k]
    # enforce monotone non-increasing (more budget never costs more)
    for k in range(1, nq + 1):
        cost[k] = min(cost[k], cost[k - 1] if cost[k - 1] is not INF else cost[k])
    return cost


def _dp(sp: SP, nq: int, curves: Mapping[str, list[float]]) -> list[float]:
    if isinstance(sp, Leaf):
        return curves[sp.name]
    if isinstance(sp, Series):
        dp = _dp(sp.parts[0], nq, curves)
        for p in sp.parts[1:]:
            nxt = _dp(p, nq, curves)
            out = [INF] * (nq + 1)
            for a in range(nq + 1):
                da = dp[a]
                if da == INF:
                    continue
                for b in range(nq + 1 - a):
                    if nxt[b] == INF:
                        continue
                    v = da + nxt[b]
                    if v < out[a + b]:
                        out[a + b] = v
            for k in range(1, nq + 1):
                out[k] = min(out[k], out[k - 1])
            dp = out
        return dp
    parts = [_dp(p, nq, curves) for p in sp.parts]
    return [sum(p[k] for p in parts) for k in range(nq + 1)]


def optimal_cost(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    n_grid: int = 240,
    use_dummy: bool = True,
) -> float:
    """Exhaustive-split optimal serving cost (INF if the SLO is unsatisfiable)."""
    curves = {
        m: _module_cost_curve(
            m, wl.rates[m], wl.slo, n_grid, profiles[m], policy, use_dummy
        )
        for m in wl.app.modules
    }
    dp = _dp(wl.app.sp, n_grid, curves)
    return dp[n_grid]
