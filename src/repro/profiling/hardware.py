"""TPU hardware catalog: the heterogeneous pool Harpagon schedules over.

Price ratios follow on-demand cloud pricing; the P100/V100 heterogeneity of
the paper maps onto TPU generations (DESIGN.md Sec. 3/7).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    hbm_bytes: float
    ici_bw: float  # bytes/s per link
    unit_price: float  # relative $ / chip-hour


TPU_V5E = TPUSpec("tpu-v5e", 197e12, 819e9, 16e9, 50e9, 1.0)
TPU_V4 = TPUSpec("tpu-v4", 275e12, 1228e9, 32e9, 50e9, 1.35)
TPU_V5P = TPUSpec("tpu-v5p", 459e12, 2765e9, 96e9, 100e9, 1.75)

CATALOG: dict[str, TPUSpec] = {t.name: t for t in (TPU_V5E, TPU_V4, TPU_V5P)}

# the dry-run / roofline target (single chip numbers)
TARGET = TPU_V5E
