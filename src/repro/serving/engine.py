"""Serving engine: executes a Harpagon Plan over a request stream.

Thin adapter over the unified simulation subsystem: arrival processes come
from `repro.serving.arrivals` (uniform / poisson / bursty MMPP / diurnal
trace), per-module batch replay runs on the numpy-vectorized kernel
(`repro.serving.replay`) in virtual time, and on the discrete-event core
(`repro.serving.events`) when real jitted executors are attached (wall-clock
measured, used by the end-to-end example).

Requests flow through the app DAG (Kahn toposort, `core.dag.topo_sort`) with
per-module *fanout* (a detector emits several crops per frame; a decoder
consumes every other frame): module m sees ``rates[m] / frame_rate``
instances per frame, exactly the rates the plan provisioned for.

Tail-batch semantics are real: with ``timeout`` set (seconds, or ``"budget"``
to derive a per-module collection deadline from the plan), partial batches
flush when their opener has waited that long — mid-stream under bursty
arrivals and at end of stream.  The default (``timeout=None, tail="flush"``)
reproduces the seed engine's numbers on uniform arrivals exactly (see
`repro.serving.reference`).

The optional *frontend* (`repro.serving.frontend`) sits between arrivals and
dispatch: it streams the plan's priced dummy traffic as phantom requests
(excluded from all statistics, counted in batch fill), sheds frames at
ingress under an admission policy, and can replace the open-loop arrival
process with closed-loop clients.  ``run(..., offered_rate=...)`` drives the
plan past its provisioned rate while keeping the provisioned fanout — the
honest overload experiment the frontend exists for.

``run(..., pipeline=True)`` switches from the per-module topological replay
to the multi-module pipelined co-simulation (`repro.serving.pipeline`):
frames traverse the DAG as tracked entities, downstream ingress is fed by
upstream batch completions, bounded queues exert backpressure, fanout can be
per-frame stochastic (correlated across siblings), and closed-loop clients
plus admission run *inside* the event loop.  The returned ``ServeResult``
then carries the full per-frame record in ``.pipeline`` — including the
per-module budget-overrun attribution that gives `core.splitter` its first
honest end-to-end check.  The default (``pipeline=False``) is the flat path,
bit-identical to before.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np

from ..core.dag import Workload, topo_sort
from ..core.dispatch import (
    Machine,
    Policy,
    dispatch_runs,
    expand_machines,
    remaining_workloads,
)
from ..core.harpagon import Plan
from .arrivals import make_arrivals
from .events import simulate_module_events
from .faults import FaultConfig, FaultRuntime
from .frontend import FrontendConfig, make_admission
from .frontend.clients import closed_loop_ingress
from .frontend.dummy import merge_phantoms, phantom_times
from .observability import Observability
from .replay import (
    ModuleReplay,
    causal_order,
    expand_fanout,
    lexmax_fold,
    lexmax_parents,
    propagate_depth,
    replay_module,
    runs_to_assignment,
)
from .service_time import (
    DegradedServiceTime,
    LiveServiceTime,
    ServiceTimeSource,
    resolve_service_time,
)


@dataclass
class ModuleStats:
    latencies: list[float] = field(default_factory=list)
    batches: int = 0
    dropped: int = 0
    phantom: int = 0  # frontend dummy requests streamed through this module

    @property
    def max_latency(self) -> float:
        return max(self.latencies) if self.latencies else 0.0


@dataclass
class ServeResult:
    e2e_latencies: list[float]
    module_stats: dict[str, ModuleStats]
    slo: float
    shed: int = 0      # frames rejected at ingress by the admission controller
    dropped: int = 0   # admitted frames lost mid-pipeline (tail drops etc.)
    attempts: int = 0  # closed-loop issue attempts incl. retries (0 = open loop)
    pipeline: "object | None" = None  # PipelineResult when run(pipeline=...)
    epochs: "list | None" = None      # EpochRecords when run(control=...)
    metrics: "object | None" = None   # MetricsSnapshot when run(observability=...)
    trace: "object | None" = None     # TraceRecorder when tracing was enabled
    # fault-injection tally when run(faults=...): faults injected, machines
    # declared dead, unfinished members re-queued to surviving siblings
    faults: "dict[str, int] | None" = None

    @property
    def offered(self) -> int:
        """Total frames offered to the system: completed + shed + dropped."""
        return len(self.e2e_latencies) + self.shed + self.dropped

    @property
    def attainment(self) -> float:
        """SLO attainment over *offered* frames: a shed or dropped frame is a
        miss, not a statistical no-show (an all-shed run attains 0.0)."""
        total = self.offered
        if total == 0:
            return 1.0
        ok = sum(1 for l in self.e2e_latencies if l <= self.slo + 1e-9)
        return ok / total

    @property
    def p99(self) -> float:
        if not self.e2e_latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.e2e_latencies), 0.99))

    def miss_report(self, slo: "float | None" = None):
        """SLO-miss forensics (`observability.forensics.MissReport`): every
        missed or shed frame classified into exactly one cause, conservation
        checked against ``offered - completed-in-SLO``.  Needs the per-frame
        record, so pipeline-mode runs only; the control plane's epoch audit
        trail (when one ran) refines the classification."""
        if self.pipeline is None:
            raise ValueError(
                "miss_report needs the per-frame record: run(pipeline=True)"
            )
        return self.pipeline.miss_report(
            self.slo if slo is None else slo, self.epochs
        )


def plan_burst(plan: Plan, m: str) -> float:
    """One upstream batch-arrival quantum for module ``m`` under ``plan``.

    Arrivals at a module downstream of a batched stage come quantized in
    its parents' batch completions: up to ``max(b_up) / rate_up`` seconds
    of arrivals land at once, and the *gap* between completions is as long.
    The same quantity `Planner._burst_of` uses on the WCL side
    (``PlannerOptions(burst_aware=True)``), exposed here for the deadline
    side (`resolve_module_timeout(..., burst=...)`).  Zero for sources.
    """
    wl = plan.workload
    burst = 0.0
    for p in wl.app.parents(m):
        s = plan.schedules.get(p)
        if s is None or not s.allocs:
            continue
        b_up = max(a.config.batch for a in s.allocs)
        burst = max(burst, b_up / max(s.rate, 1e-12))
    return burst


# padded-fill floor factor for burst-aware budget deadlines: the adaptive
# phantom injector's pacing law delivers ~C/1.5 in a deep lull (one 1.5-slot
# grace per injection, deficit forgiven at each anchor resync), and its
# backlog-yield suppresses it further while queued batches drain — 2x the
# nominal fill time covers both, validated against the diurnal sweep's lull
# phase (see `benchmarks.run --only diurnal_sweep`)
_PAD_FILL = 2.0


def resolve_module_timeout(
    schedule,
    machines: "list[Machine]",
    timeout: "float | str | None",
    policy: Policy,
    *,
    dummies: bool = False,
    burst: "float | None" = None,
    rate_scale: float = 1.0,
) -> "float | None | dict[int, float]":
    """Resolve the batch-collection deadline for one module schedule.

    ``"budget"`` derives a per-machine deadline from the plan: each machine
    must flush early enough that collection + its own service duration still
    fits the module's latency budget.  A module-level function so the
    control plane (`repro.serving.control`) can resolve deadlines for
    hot-swapped schedules exactly like the engine resolves the initial ones.

    ``burst`` (pass ``burst=None`` for the flag-off path) is the burst-aware
    *deadline* correction — the PR-4 finding's fix, mirroring the
    burst-aware WCL quantum (`repro.core.dispatch.config_wcl`) on the
    deadline side, opt-in via ``FrontendConfig(burst_deadline=True)``.  Two
    corrections compose on the dummy-streaming path:

    * **one upstream batch-arrival quantum** (`plan_burst`, seconds):
      downstream of a batched stage the inter-completion gap can straddle a
      zero-slack ``budget - d`` deadline, flushing a partial batch whose
      wasted service snowballs at 100% utilization (attainment below 0.5 at
      1.0x provisioning on uniform arrivals).  Adding the quantum lets the
      batch survive the gap and fill from the next completion;
    * **the padded-fill floor**: the adaptive injector is rate-limited with
      a 1.5-slot pacing law (anchor resync forgives old deficit), so its
      achievable collection in a lull is ~``2/3`` of the provisioned rate
      ``C``, and it yields entirely while real service backlog exists — a
      deadline at the nominal ``b / C`` fill time then flushes a
      nearly-empty batch on *every* cycle once traffic runs below
      provisioning (the diurnal-lull collapse).  The floor
      ``_PAD_FILL * (b + 1.5) / C`` is the fill time under that pacing law
      plus arming lag, so a flush only ever fires on a batch the injector
      could not have filled.

    Both trade modeled-WCL tightness (a deadline may exceed ``budget - d``
    by the quantum + floor slack) for flush stability — the same contract
    as ``PlannerOptions(burst_aware=True)`` on the WCL side.  Flag off
    (``burst=None``) keeps the exact PR-4 semantics, collapse included.

    ``rate_scale`` (< 1.0) is the control plane's transient-aware deadline
    relaxation (`ControlRuntime.on_tick`): when arrivals run below the
    plan's provisioned rate mid-epoch, the burst-corrected deadlines are
    re-resolved as if the collect rate were ``scale * C`` — the padded-fill
    floor and the burst quantum both stretch by ``1 / scale`` toward the
    *observed* arrival quantum, so a stale plan stops flushing near-empty
    batches.  The default 1.0 is an exact no-op, and only the
    dummy-streaming burst-aware branch consumes it.
    """
    if timeout is None or isinstance(timeout, (int, float)):
        return timeout
    if timeout == "budget":
        s = schedule
        if dummies:
            # the frontend streams the plan's dummy traffic, so batches
            # collect at the provisioned rate and the deadline can sit
            # exactly at the modeled budget (+ the opt-in burst corrections)
            if burst is None:
                return {
                    mm.mid: max(s.budget - mm.config.duration, 0.0)
                    for mm in machines
                }
            coll = sum(a.rate + a.dummy for a in s.allocs) * rate_scale
            return {
                mm.mid: max(
                    s.budget - mm.config.duration,
                    _PAD_FILL * (mm.config.batch + 1.5) / max(coll, 1e-12),
                ) + burst / rate_scale
                for mm in machines
            }
        # floor at the real-rate fill time: dummy-padded plans assume the
        # frontend injects phantom requests to speed collection, which the
        # engine does not simulate — flushing faster than real traffic can
        # fill a batch would silently overload the machine instead.  Under
        # TC machine i's batch is a consecutive slice of the stream, but
        # it fills at the *remaining* workload w_i (Theorem 1): a
        # lower-ranked machine sees only the traffic dispatched at or
        # below its rank, so its honest floor is longer than the whole-
        # module fill time.  Under RR/DT a machine fills only at its own
        # share of the traffic.
        if policy is Policy.TC:
            w_of = remaining_workloads(list(s.allocs))
            def fill(mm: Machine) -> float:
                return mm.config.batch / max(w_of.get(mm.mid, s.rate), 1e-12)
        else:
            tot = sum(mm.rate for mm in machines)
            def fill(mm: Machine) -> float:
                rate = s.rate
                if tot > 0:
                    rate *= mm.rate / tot
                return mm.config.batch / max(rate, 1e-12)
        return {
            mm.mid: max(s.budget - mm.config.duration, fill(mm))
            for mm in machines
        }
    raise ValueError(f"unknown timeout spec {timeout!r}")


class ServingEngine:
    def __init__(
        self,
        plan: Plan,
        *,
        executors: Mapping[str, Callable[[int], None]] | None = None,
        policy: Policy = Policy.TC,
    ):
        """``executors[module](batch_size)`` runs a real batched forward; when
        None the profiled config duration is used (virtual time)."""
        self.plan = plan
        self.executors = executors or {}
        self.policy = policy

    def run(
        self,
        n_frames: int,
        frame_rate: float,
        *,
        arrivals: "str | np.ndarray | Sequence[float]" = "uniform",
        seed: int = 0,
        timeout: "float | str | None" = None,
        tail: str = "flush",
        frontend: FrontendConfig | None = None,
        offered_rate: float | None = None,
        pipeline: "bool | object" = False,
        control: "object | None" = None,
        service_time: "str | ServiceTimeSource | None" = None,
        observability: "bool | object | None" = None,
        faults: "FaultConfig | None" = None,
    ) -> ServeResult:
        """Serve ``n_frames`` frames arriving at ``offered_rate`` (default:
        the provisioned ``frame_rate``) through the planned DAG.

        ``frame_rate`` stays the *provisioned* rate: it fixes the per-module
        fanout and the admission controller's default budget, so passing
        ``offered_rate > frame_rate`` drives the plan into overload without
        silently rescaling the workload shape.  ``frontend`` enables dummy
        streaming / admission control / closed-loop clients (`FrontendConfig`);
        with ``frontend.clients`` set the ``arrivals`` process is ignored —
        issue times come from the client loop.

        ``pipeline`` selects the multi-module co-simulation (``True`` or a
        `repro.serving.pipeline.PipelineConfig` for bounded queues and
        stochastic fanout); the default flat path replays modules in
        topological order with unbounded hand-off.

        ``control`` (a `repro.serving.control.ControlLoopConfig`, pipeline
        mode only) runs the incremental control plane inside the event loop:
        windowed arrival-rate estimation, warm-start ``Planner.replan`` at
        every epoch, and hot-swap of the resulting plan delta onto the live
        stages.  The returned ``ServeResult.epochs`` carries the per-epoch
        audit trail.  With ``control=None`` the path is bit-identical to
        before the control plane existed.

        ``service_time`` selects where batch service durations come from
        (`repro.serving.service_time`): ``None`` / ``"analytic"`` is the
        profiled constant (bit-exact default); a `TraceServiceTime` replays
        recorded per-(module, batch) samples deterministically; ``"live"``
        (or a `LiveServiceTime`) times the engine's real executors per
        batch.  In pipeline mode real executors auto-wrap into a live
        source, so ``run(pipeline=True)`` co-simulates against measured
        step times; combined with ``control=`` the epochs replan against
        observed durations (model-vs-measured error in each EpochRecord).

        ``observability`` (``True``, an `ObservabilityConfig`, or a prebuilt
        `Observability`) attaches the passive telemetry layer: a structured
        trace recorder (Perfetto-exportable) and a per-epoch metrics
        registry, returned as ``ServeResult.trace`` / ``.metrics``.  The
        sink is write-only — results are bit-identical with it on, off, or
        sampled.  Off (``None``, the default) costs nothing.

        ``faults`` (a `repro.serving.faults.FaultConfig`, pipeline mode
        only) arms the seeded fault injector: machine crashes, transient
        stragglers, and whole-device losses fire as events inside the
        co-simulation, a batch-duration watchdog escalates unresponsive
        machines suspect → dead, dead machines' unfinished work re-queues
        to surviving siblings, and the control plane (when one runs)
        force-replans the failed module out-of-band.  A disabled config
        (neither ``mtbf`` nor ``schedule`` set) is treated exactly like
        ``faults=None`` — bit-exact with the injector absent.
        """
        fe = frontend or FrontendConfig()
        obs = Observability.make(observability)
        wl: Workload = self.plan.workload
        ctrl = make_admission(fe.admission, wl.app.name, frame_rate)
        if offered_rate is not None and offered_rate <= 0:
            raise ValueError("offered_rate must be positive")
        if control is not None and not pipeline:
            raise ValueError(
                "control= (epoch-based plan hot-swap) requires pipeline mode: "
                "the flat path replays whole modules and cannot swap mid-run"
            )
        if faults is not None:
            if not isinstance(faults, FaultConfig):
                raise TypeError(f"faults= expects FaultConfig, got {faults!r}")
            if not faults.enabled:
                faults = None  # nothing to fire: identical to faults=None
        if faults is not None and not pipeline:
            raise ValueError(
                "faults= (seeded fault injection) requires pipeline mode: "
                "the flat path replays whole modules and has no machines to "
                "fail mid-run"
            )
        src = resolve_service_time(service_time, self.executors)
        if pipeline:
            return self._run_pipeline(
                n_frames, frame_rate, fe, ctrl,
                arrivals=arrivals, seed=seed, timeout=timeout, tail=tail,
                offered_rate=offered_rate, cfg=pipeline, control=control,
                service_time=src, obs=obs, faults=faults,
            )
        if fe.clients is not None:
            warnings.warn(
                "the fixed-point closed loop (clients= without pipeline=True) "
                "is deprecated: the event-interleaved co-simulation "
                "(pipeline=True) replaces the latency-oracle iteration",
                DeprecationWarning,
                stacklevel=2,
            )
            return self._run_closed_loop(
                n_frames, frame_rate, fe, ctrl,
                seed=seed, timeout=timeout, tail=tail,
                offered_rate=offered_rate,
            )
        arrival = make_arrivals(
            arrivals, n_frames,
            offered_rate if offered_rate is not None else frame_rate,
            seed=seed,
        )
        if ctrl is not None:
            ctrl.reset()
            ctrl.obs = obs  # flat path: ingress sheds land in the telemetry
            shed_mask = ctrl.shed_stream(arrival)
        else:
            shed_mask = np.zeros(n_frames, dtype=bool)
        result, lat = self._serve(
            arrival, shed_mask, frame_rate, fe, timeout=timeout, tail=tail,
            service_time=src, obs=obs,
        )
        if obs is not None:
            fin = arrival + lat
            t_end = (
                float(np.nanmax(fin))
                if np.isfinite(fin).any()
                else (float(arrival.max()) if arrival.size else 0.0)
            )
            machines_of = {
                m: len(expand_machines(list(s.allocs)))
                for m, s in self.plan.schedules.items()
            }
            result.metrics = obs.finalize(t_end, machines_of)
            result.trace = obs.trace
        return result

    def _run_closed_loop(
        self,
        n_frames: int,
        frame_rate: float,
        fe: FrontendConfig,
        ctrl,
        *,
        seed: int,
        timeout: "float | str | None",
        tail: str,
        offered_rate: float | None,
    ) -> ServeResult:
        """Fixed point of (client ingress -> DAG replay -> latency oracle).

        The ingress simulation needs each frame's end-to-end latency to know
        when its client slot frees; the DAG replay needs the arrival times.
        Successive substitution from the plan's modeled latency converges in
        a few iterations (under overload the closed loop self-throttles, so
        latencies barely move between rounds).
        """
        wl = self.plan.workload
        clients = fe.clients
        est0 = self.plan.e2e_latency
        if not np.isfinite(est0) or est0 <= 0.0:
            est0 = wl.slo
        est = np.full(n_frames, max(est0, 1e-6))
        pace = offered_rate if offered_rate is not None else frame_rate
        result = ServeResult([], {}, wl.slo)
        prev_arrival: np.ndarray | None = None
        for _ in range(max(1, clients.max_iters)):
            if ctrl is not None:
                ctrl.reset()
            arrival, shed_mask, attempts = closed_loop_ingress(
                clients, n_frames, pace, est, admission=ctrl, seed=seed
            )
            result, lat = self._serve(
                arrival, shed_mask, frame_rate, fe, timeout=timeout, tail=tail
            )
            result.attempts = attempts
            est = np.where(np.isfinite(lat), lat, est)
            if (
                prev_arrival is not None
                and float(np.max(np.abs(arrival - prev_arrival))) < clients.tol
            ):
                break
            prev_arrival = arrival
        return result

    def _run_pipeline(
        self,
        n_frames: int,
        frame_rate: float,
        fe: FrontendConfig,
        ctrl,
        *,
        arrivals: "str | np.ndarray | Sequence[float]",
        seed: int,
        timeout: "float | str | None",
        tail: str,
        offered_rate: float | None,
        cfg,
        control=None,
        service_time: "ServiceTimeSource | None" = None,
        obs: "Observability | None" = None,
        faults: "FaultConfig | None" = None,
    ) -> ServeResult:
        """Multi-module pipelined co-simulation (`repro.serving.pipeline`)."""
        from .control import ControlLoopConfig, ControlRuntime, plan_e2e_hint
        from .pipeline import ModuleStage, PipelineConfig, make_stage_fanouts
        from .pipeline.core import run_pipeline

        if cfg is True:
            cfg = PipelineConfig()
        if not isinstance(cfg, PipelineConfig):
            raise TypeError(f"pipeline= expects True or PipelineConfig, got {cfg!r}")
        if service_time is None and self.executors:
            # real executors in pipeline mode: co-simulate against measured
            # step times (timed per batch, steady-state cached per config)
            service_time = LiveServiceTime(self.executors)
        rt_faults = None
        if faults is not None:
            rt_faults = FaultRuntime(faults)
            # straggler faults inflate durations live through the
            # service-time hook: the wrapper holds the injector's slowdown
            # table by reference, so entering/leaving it needs no stage state
            service_time = DegradedServiceTime(rt_faults.slow, service_time)
        wl: Workload = self.plan.workload
        topo = topo_sort(wl.app.modules, wl.app.edges)
        sources = [m for m in topo if not wl.app.parents(m)]
        fanouts = {m: wl.rates[m] / frame_rate for m in topo}
        stage_fanouts = make_stage_fanouts(
            cfg.fanout, fanouts, sources, n_frames, seed=seed + 1
        )
        stages = {}
        for m in topo:
            s = self.plan.schedules[m]
            machines = expand_machines(list(s.allocs))
            w = self._module_timeout(
                m, machines, timeout,
                dummies=fe.dummies, burst_deadline=fe.burst_deadline,
            )
            # adaptive dummy streaming: pad the stage's collection up to the
            # provisioned collect rate (real + priced dummy), mirroring the
            # flat frontend's deficit injector — phantoms flow exactly when
            # real traffic lags the rate the budget deadline assumes
            target = sum(a.rate + a.dummy for a in s.allocs) if fe.dummies else 0.0
            stages[m] = ModuleStage(
                m,
                machines,
                self.policy,
                timeout=w,
                fanout=stage_fanouts[m],
                phantom_target=target,
                queue_cap=cfg.queue_cap,
                service_time=service_time,
            )
        rt = None
        if control is not None:
            if not isinstance(control, ControlLoopConfig):
                raise TypeError(
                    f"control= expects ControlLoopConfig, got {control!r}"
                )
            if control.profiles is None:
                raise ValueError(
                    "control.profiles must carry the module profiles so "
                    "Planner.replan can re-solve modules at epoch boundaries"
                )
            rt = ControlRuntime(
                control,
                self.plan,
                control.profiles,
                frame_rate,
                timeout_of=lambda s_, machines_, plan_, rate_scale=1.0: (
                    resolve_module_timeout(
                        s_, machines_, timeout, self.policy, dummies=fe.dummies,
                        burst=(
                            plan_burst(plan_, s_.module)
                            if (fe.burst_deadline and fe.dummies)
                            else None
                        ),
                        rate_scale=rate_scale,
                    )
                ),
                dummies=fe.dummies,
                admission=ctrl,
                # deadline relaxation applies to provisioned-collect-rate
                # deadlines only: the dummy-padded "budget" path with the
                # burst-aware corrections is exactly that regime
                relax=(fe.dummies and fe.burst_deadline and timeout == "budget"),
            )
            if service_time is not None:
                # feed every started batch's measured duration to the
                # control plane: epochs replan against corrected profiles
                # and record the model-vs-measured error
                for st in stages.values():
                    st.service_obs = rt.observe_service
        e2e_hint = plan_e2e_hint(self.plan)
        pace = offered_rate if offered_rate is not None else frame_rate
        if ctrl is not None:
            ctrl.reset()
            # admission emits its own decision-resolution telemetry: every
            # denial, with interim retry denials the closed loop later
            # re-admits tagged "shed_retry" (terminal ones "shed"); the
            # loop's terminal emit defers to it (see
            # `pipeline.core.issue_frame`) so sheds are never double-counted
            ctrl.obs = obs
        perf = dict(
            reference=cfg.reference,
            fast_path=cfg.fast_path,
            event_queue=cfg.event_queue,
            quantum=cfg.quantum,
        )
        if fe.clients is not None:
            res = run_pipeline(
                wl.app, stages, n_frames,
                clients=fe.clients, pace=pace, admission=ctrl,
                tail=tail, seed=seed, control=rt, e2e_hint=e2e_hint,
                obs=obs, faults=rt_faults, **perf,
            )
        else:
            issue = make_arrivals(arrivals, n_frames, pace, seed=seed)
            res = run_pipeline(
                wl.app, stages, n_frames,
                issue=issue, admission=ctrl, tail=tail, seed=seed,
                control=rt, e2e_hint=e2e_hint, obs=obs, faults=rt_faults,
                **perf,
            )
        stats = {}
        for m in topo:
            ss = res.stats[m]
            stats[m] = ModuleStats(
                latencies=ss.latencies,
                batches=ss.batches,
                dropped=ss.dropped,
                phantom=ss.phantom,
            )
        out = ServeResult(
            res.e2e[res.completed].tolist(),
            stats,
            wl.slo,
            shed=int(res.shed.sum()),
            dropped=int(res.dropped.sum()),
            attempts=res.attempts,
            pipeline=res,
            epochs=rt.history if rt is not None else None,
            faults=(
                {
                    "injected": rt_faults.n_injected,
                    "killed": rt_faults.n_killed,
                    "requeued": rt_faults.n_requeued,
                }
                if rt_faults is not None
                else None
            ),
        )
        if obs is not None:
            t_end = 0.0
            for m in topo:
                col = res.finish[m]
                v = col[~np.isnan(col)]
                if v.size:
                    t_end = max(t_end, float(v.max()))
            out.metrics = obs.finalize(
                t_end, {m: len(stages[m].machines) for m in topo}
            )
            out.trace = obs.trace
        return out

    def _serve(
        self,
        arrival: np.ndarray,
        shed_mask: np.ndarray,
        frame_rate: float,
        fe: FrontendConfig,
        *,
        timeout: "float | str | None",
        tail: str,
        service_time: "ServiceTimeSource | None" = None,
        obs: "Observability | None" = None,
    ) -> tuple[ServeResult, np.ndarray]:
        """Replay the DAG over admitted frames; returns the result plus the
        per-frame e2e latency array (NaN for shed/dropped frames)."""
        wl: Workload = self.plan.workload
        arrival = np.asarray(arrival, dtype=np.float64)
        n_frames = arrival.size
        # finish time of frame i at module m (0.0 = not processed / dropped)
        finish_at = {m: np.zeros(n_frames) for m in wl.app.modules}
        stats = {m: ModuleStats() for m in wl.app.modules}
        # a frame is *lost* when some module materialized instances for it
        # but completed none (tail drop / deadline overrun) — as opposed to a
        # frame a fanout < 1 module legitimately skipped, which the seed
        # semantics exclude from the statistics entirely
        lost = np.zeros(n_frames, dtype=bool)
        # quiescence-depth tracking (causal tail order): end-of-stream tail
        # flushes happen in the event loop's quiescence rounds, strictly
        # after all normal completions — their backdated cascades must be
        # *delivered* last at DAG joins even when their times are earlier.
        # Only the timeout=None flush path produces tails; the dummy
        # frontend's phantom merge assumes sorted streams, so the (untested)
        # dummies+no-timeout combination keeps the legacy order.
        track_depth = timeout is None and tail == "flush" and not fe.dummies
        depth = (
            {m: np.zeros(n_frames, dtype=np.int64) for m in wl.app.modules}
            if track_depth
            else {}
        )
        emit = (
            {m: np.zeros(n_frames) for m in wl.app.modules}
            if track_depth
            else {}
        )
        tail_rounds: dict[str, int] = {}
        anc = wl.app.ancestor_closure() if track_depth else {}
        for m in topo_sort(wl.app.modules, wl.app.edges):
            parents = wl.app.parents(m)
            in_depth = in_emit = None
            if parents:
                pf = np.stack([finish_at[p] for p in parents])
                ready = np.maximum(arrival, pf.max(axis=0))
                drop = (pf <= 0.0).any(axis=0)
                if track_depth:
                    in_depth, in_emit = lexmax_parents(
                        [depth[p] for p in parents],
                        [emit[p] for p in parents],
                    )
            else:
                ready = arrival
                drop = shed_mask
            fanout = wl.rates[m] / frame_rate
            anc_round = (
                max((tail_rounds.get(a, 0) for a in anc.get(m, ())), default=0)
                if track_depth
                else 0
            )
            tail_rounds[m] = self._run_module(
                m, ready, drop, fanout, finish_at[m], stats[m], lost,
                timeout=timeout, tail=tail, dummies=fe.dummies,
                burst_deadline=fe.burst_deadline,
                service_time=service_time, obs=obs,
                in_depth=in_depth,
                in_emit=in_emit,
                out_depth=depth[m] if track_depth else None,
                out_emit=emit[m] if track_depth else None,
                anc_round=anc_round,
            )
        sinks = [m for m in wl.app.modules if not wl.app.children(m)]
        sf = np.stack([finish_at[s] for s in sinks])
        ok = (sf > 0).all(axis=0)
        lat = np.where(ok, sf.max(axis=0) - arrival, np.nan)
        e2e = lat[ok]
        shed = int(shed_mask.sum())
        dropped = int((lost & ~shed_mask & ~ok).sum())
        return (
            ServeResult(e2e.tolist(), stats, wl.slo, shed=shed, dropped=dropped),
            lat,
        )

    def _module_timeout(
        self,
        m: str,
        machines: "list[Machine]",
        timeout: "float | str | None",
        *,
        dummies: bool = False,
        burst_deadline: bool = False,
    ) -> "float | None | dict[int, float]":
        burst = plan_burst(self.plan, m) if (burst_deadline and dummies) else None
        return resolve_module_timeout(
            self.plan.schedules[m], machines, timeout, self.policy,
            dummies=dummies, burst=burst,
        )

    def _run_module(
        self,
        m: str,
        ready: np.ndarray,
        drop: np.ndarray,
        fanout: float,
        finish_frame: np.ndarray,
        stats: ModuleStats,
        lost: np.ndarray,
        *,
        timeout: "float | str | None",
        tail: str,
        dummies: bool = False,
        burst_deadline: bool = False,
        service_time: "ServiceTimeSource | None" = None,
        obs: "Observability | None" = None,
        in_depth: "np.ndarray | None" = None,
        in_emit: "np.ndarray | None" = None,
        out_depth: "np.ndarray | None" = None,
        out_emit: "np.ndarray | None" = None,
        anc_round: int = 0,
    ) -> int:
        sched = self.plan.schedules[m]
        machines = expand_machines(list(sched.allocs))
        # expand frames into module-level request instances by fanout, in
        # causal order — (quiescence depth, emit, id); plain stable
        # ready-sort when no upstream tail cascades exist — skipping frames
        # dropped upstream
        order = causal_order(ready, in_depth, in_emit)
        frames = order[~drop[order]]
        instances = expand_fanout(frames, fanout)
        n = instances.size
        if n == 0:
            return 0
        ready_inst = ready[instances]
        phantom = np.zeros(n, dtype=bool)
        ready_all = ready_inst
        if dummies:
            # stream the plan's priced dummy traffic: pad the observed real
            # rate up to the provisioned collection rate with phantoms
            target = sum(a.rate + a.dummy for a in sched.allocs)
            ph = phantom_times(ready_inst, target)
            if ph.size:
                ready_all, phantom = merge_phantoms(ready_inst, ph)
        n_all = ready_all.size
        runs = dispatch_runs(machines, n_all, self.policy)
        w = self._module_timeout(
            m, machines, timeout, dummies=dummies, burst_deadline=burst_deadline
        )
        ex = self.executors.get(m)
        hook = None
        if obs is not None:
            # per-batch telemetry feed for the event-core legs: exact spans
            # (measured durations included) via `events.simulate_module_events`'s
            # passive on_batch observer; the vectorized leg below reports
            # column-level tallies from `ModuleReplay.batches` instead
            def hook(machine: Machine, start: float, end: float, rids) -> None:
                obs.batch_start(
                    m, machine.mid, start, end - start, len(rids),
                    machine.config.batch,
                    sum(1 for r in rids if phantom[r]),
                )
        if service_time is not None and service_time.kind != "analytic":
            # trace/live durations: the vectorized kernel assumes the
            # profiled constant, so route through the event core's
            # service-time hook (`MachineCore.start`'s duration callable)
            def _sourced(machine: Machine, group: int) -> float:
                return service_time.duration(m, machine, group)

            finish, batches = simulate_module_events(
                machines,
                ready_all,
                runs_to_assignment(runs, n_all),
                timeout=w,
                tail=tail,
                executor=_sourced,
                phantom=phantom,
                on_batch=hook,
            )
            rep = ModuleReplay(finish, runs_to_assignment(runs, n_all), batches, phantom)
        elif ex is None:
            rep = replay_module(
                machines, ready_all, runs, timeout=w, tail=tail, phantom=phantom
            )
            if obs is not None:
                done_all = ~np.isnan(rep.finish)
                by_mid = {mm.mid: mm.config for mm in machines}
                obs.bulk_module(
                    m,
                    batches=rep.n_batches,
                    members=int(done_all.sum()),
                    phantoms=int((phantom & done_all).sum()),
                    slots=sum(
                        k * by_mid[mid].batch for mid, k in rep.batches.items()
                    ),
                    busy=sum(
                        k * by_mid[mid].duration
                        for mid, k in rep.batches.items()
                    ),
                )
        else:
            def _measured(machine: Machine, _group: int) -> float:
                t0 = time.perf_counter()
                ex(machine.config.batch)
                return time.perf_counter() - t0

            finish, batches = simulate_module_events(
                machines,
                ready_all,
                runs_to_assignment(runs, n_all),
                timeout=w,
                tail=tail,
                executor=_measured,
                phantom=phantom,
                on_batch=hook,
            )
            rep = ModuleReplay(finish, runs_to_assignment(runs, n_all), batches, phantom)
        # phantoms fill batches but never enter the statistics; the stable
        # merge preserved real-request order, so slicing by the mask aligns
        # the finish times back with ``ready_inst`` / ``instances``
        tail_round = 0
        if out_depth is not None:
            # thread the quiescence depth through service: completions
            # inherit their machine's running-max arrival depth, this
            # module's own flushed tail (if any) fires one round past the
            # deepest ancestor flush, and each frame's resolve key is the
            # lexicographic (depth, finish) max over its instances — the
            # processing instant of its last completion event
            inst_depth = (
                in_depth[instances]
                if in_depth is not None
                else np.zeros(n, dtype=np.int64)
            )
            out_inst, tail_round = propagate_depth(
                inst_depth, rep.assignment, rep.finish, machines, w, tail,
                anc_round,
            )
            done_i = ~np.isnan(rep.finish)
            lexmax_fold(
                instances[done_i], out_inst[done_i], rep.finish[done_i],
                out_depth, out_emit,
            )
        finish_real = rep.finish[~phantom]
        done = ~np.isnan(finish_real)
        stats.batches += rep.n_batches
        stats.phantom += int(phantom.sum())
        stats.dropped += int(n - done.sum())
        stats.latencies.extend((finish_real[done] - ready_inst[done]).tolist())
        # frame finish = max over its instances (dropped instances contribute 0)
        np.maximum.at(finish_frame, instances[done], finish_real[done])
        # frames that had instances here but completed none are lost, not
        # merely skipped by fanout — they count as pipeline drops
        if not done.all():
            had = np.zeros(finish_frame.size, dtype=bool)
            had[instances] = True
            lost |= had & (finish_frame <= 0.0)
        return tail_round
