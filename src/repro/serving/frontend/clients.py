"""Closed-loop clients: arrivals driven by completions, not by a clock.

The PR-1 arrival processes are all *open-loop*: the stream keeps coming no
matter how slow the service is, which is the right model for camera feeds
but the wrong one for interactive clients.  A closed-loop client holds at
most ``max_in_flight`` frames outstanding and issues the next one only after
a completion (plus think time), so offered load self-throttles under
overload — the classic closed-vs-open distinction in serving benchmarks.

The ingress simulation here is a sequential event walk over client slots:
each slot issues a frame, the admission controller (if any) admits or sheds
it at the issue instant, an admitted frame completes after the per-frame
latency given by the ``latency`` oracle, and the slot frees ``think`` later.
A shed frame is retried with exponentially-jittered backoff (when enabled)
until ``max_retries`` is exhausted, then the frame is terminal.  The bound
exists so a dead or unrecovered stage can't spin the shed→retry loop
forever: every frame leaves the system in bounded attempts.  Terminal
classification differs by path — the pipelined co-simulation records an
exhausted frame as ``dropped`` with a ``retry_exhausted`` trace cause
(distinct from a first-sight terminal ``shed``), while this deprecated flat
path folds it into ``shed``.

The oracle makes this a *fixed-point* formulation: the engine seeds it with
the plan's modeled end-to-end latency, replays the DAG on the generated
arrivals, feeds the simulated per-frame latencies back in, and iterates
until the arrival times stop moving (`ServingEngine._run_closed_loop`).

.. deprecated::
    The fixed-point path is superseded by the event-interleaved client loop
    of the pipelined co-simulation (``ServingEngine.run(pipeline=True)``),
    where slots react to *actual* completions instead of a previous pass's
    latency oracle.  `closed_loop_ingress` and the engine shim remain for
    the flat path (`ServingEngine.run` warns ``DeprecationWarning``), and
    both formulations are pinned to agree within tolerance on uniform
    arrivals (tests/test_pipeline.py).  The `ClosedLoopClients` dataclass
    itself is *not* deprecated — the pipeline reuses it as its client spec.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from .admission import AdmissionController


@dataclass(frozen=True)
class ClosedLoopClients:
    """Closed-loop arrival mode configuration.

    ``n_clients * max_in_flight`` independent slots share one global frame
    counter; ``think_time`` is the mean pause between a completion and the
    next issue (``think_dist="exp"`` for exponential, ``"const"`` for fixed).
    """

    n_clients: int = 8
    max_in_flight: int = 1
    think_time: float = 0.0
    think_dist: str = "exp"
    retry_on_shed: bool = False
    max_retries: int = 3
    backoff: "float | None" = 0.05
    #   base retry backoff, doubled per attempt, jittered.  ``None`` = re-read
    #   the LIVE plan's modeled end-to-end latency at every retry (per-epoch
    #   state under a control loop, the fixed-point oracle otherwise): a shed
    #   client waits about one service round of the plan that is actually
    #   serving, not a run-constant guess
    max_iters: int = 5        # engine fixed-point iterations
    tol: float = 1e-3         # arrival-time convergence tolerance (seconds)

    def __post_init__(self):
        if self.n_clients < 1 or self.max_in_flight < 1:
            raise ValueError("need n_clients >= 1 and max_in_flight >= 1")
        if self.think_dist not in ("exp", "const"):
            raise ValueError(f"unknown think_dist {self.think_dist!r}")
        if self.backoff is not None and self.backoff < 0.0:
            raise ValueError("backoff must be >= 0 (or None for live latency)")


def closed_loop_ingress(
    cfg: ClosedLoopClients,
    n_frames: int,
    frame_rate: float,
    latency: np.ndarray,
    *,
    admission: AdmissionController | None = None,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Simulate the client/admission ingress; returns ``(issue, shed, attempts)``.

    ``latency[i]`` is the oracle end-to-end latency of frame ``i`` (frames
    are numbered in issue order).  ``issue[i]`` is the admitted arrival time
    of frame ``i`` (its final attempt time when permanently shed),
    ``shed[i]`` marks frames rejected at ingress for good, and ``attempts``
    counts every issue attempt including retries.  ``frame_rate`` only
    staggers the initial slot starts (one provisioned inter-frame gap apart).
    """
    if latency.shape != (n_frames,):
        raise ValueError("latency oracle must have one entry per frame")
    rng = np.random.default_rng(seed)
    slots = cfg.n_clients * cfg.max_in_flight
    issue = np.zeros(n_frames)
    shed = np.zeros(n_frames, dtype=bool)
    attempts = 0
    next_frame = 0

    def think() -> float:
        if cfg.think_time <= 0.0:
            return 0.0
        if cfg.think_dist == "const":
            return cfg.think_time
        return float(rng.exponential(cfg.think_time))

    # heap of (time, seq, frame, tries); frame == -1 means "slot wants a new
    # frame".  seq keeps heap comparisons away from ties.
    seq = 0
    heap: list[tuple[float, int, int, int]] = []
    for k in range(min(slots, n_frames)):
        heapq.heappush(heap, (k / frame_rate, seq, -1, 0))
        seq += 1

    while heap:
        t, _, frame, tries = heapq.heappop(heap)
        if frame == -1:
            if next_frame >= n_frames:
                continue  # stream exhausted: slot retires
            frame = next_frame
            next_frame += 1
            tries = 0
        attempts += 1
        will_retry = cfg.retry_on_shed and tries < cfg.max_retries
        admitted = (
            admission.admit(t, "shed_retry" if will_retry else "shed")
            if admission is not None
            else True
        )
        if admitted:
            issue[frame] = t
            done = t + max(float(latency[frame]), 0.0)
            heapq.heappush(heap, (done + think(), seq, -1, 0))
        elif cfg.retry_on_shed and tries < cfg.max_retries:
            # backoff=None: wait about one modeled service round (the oracle
            # latency is this path's "live plan state")
            base = (
                cfg.backoff
                if cfg.backoff is not None
                else max(float(latency[frame]), 1e-3)
            )
            delay = base * (2.0 ** tries) * float(rng.uniform(0.5, 1.5))
            heapq.heappush(heap, (t + delay, seq, frame, tries + 1))
        else:
            issue[frame] = t
            shed[frame] = True
            heapq.heappush(heap, (t + think(), seq, -1, 0))
        seq += 1
    return issue, shed, attempts
