"""Training loop: causal-LM loss (+ MoE load-balance aux, + deepseek MTP)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from ..models import Model
from ..models import layers as Lyr
from ..models.model import _block_apply
from ..configs.base import LayerSpec
from .optimizer import OptConfig, adamw_init, adamw_update


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _mtp_loss(model: Model, params, hidden, tokens, labels) -> jax.Array:
    """DeepSeek multi-token prediction: predict t+2 from h_t and emb(t+1)."""
    cfg = model.cfg
    emb_next = Lyr.embed(params["embed"], cfg, tokens[:, 1:], hidden.dtype)
    h = jnp.concatenate([hidden[:, :-1], emb_next], axis=-1)
    h = Lyr.dense(params["mtp"]["proj"], h)
    B, S1, _ = h.shape
    pos = jnp.broadcast_to(jnp.arange(S1)[None], (B, S1))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos, (3, B, S1))
    spec = LayerSpec("attn" if cfg.attn_kind != "mla" else "mla", "dense")
    h, _, _ = _block_apply(params["mtp"]["block"], cfg, spec, h, pos, None, None, model.mesh_info)
    h = Lyr.apply_norm(cfg, params["mtp"]["norm"], h)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["w"].astype(h.dtype).T
    else:
        logits = Lyr.dense(params["head"], h)
    return cross_entropy(logits[:, :-1], labels[:, 2:])


def make_loss_fn(model: Model, *, aux_coef: float | None = None, mtp_coef: float = 0.3):
    cfg = model.cfg
    aux_coef = cfg.router_aux_coef if aux_coef is None else aux_coef

    def loss_fn(params, batch):
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        labels = batch["labels"]
        out = model.forward(
            params,
            tokens,
            embeds=embeds,
            return_hidden=cfg.mtp_depth > 0,
        )
        loss = cross_entropy(out.logits, labels)
        metrics = {"ce": loss}
        if cfg.is_moe_arch:
            n_moe = sum(1 for s in cfg.layer_specs() if s.ffn == "moe")
            aux = out.aux_loss / jnp.maximum(n_moe, 1)
            loss = loss + aux_coef * aux
            metrics["aux"] = aux
        if cfg.mtp_depth and tokens is not None:
            mtp = _mtp_loss(model, params, out.hidden, tokens, labels)
            loss = loss + mtp_coef * mtp
            metrics["mtp"] = mtp
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, opt_cfg: OptConfig, **loss_kw) -> Callable:
    loss_fn = make_loss_fn(model, **loss_kw)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


@dataclass
class TrainResult:
    params: Any
    opt_state: Any
    history: list[dict[str, float]]


def train(
    model: Model,
    batches: Iterator[dict[str, jax.Array]],
    steps: int,
    opt_cfg: OptConfig | None = None,
    *,
    seed: int = 0,
    log_every: int = 10,
    log: Callable[[str], None] = print,
) -> TrainResult:
    opt_cfg = opt_cfg or OptConfig(total_steps=steps)
    params = model.init(jax.random.key(seed))
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    history = []
    for i in range(steps):
        batch = next(batches)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            log(f"step {i:5d} " + " ".join(f"{k}={v:.4f}" for k, v in m.items()))
    return TrainResult(params, opt_state, history)
