"""Training substrate: optimizer math, checkpointing, loss dynamics, pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# JAX-compile-heavy (jits real kernels/models); deselect with -m "not slow"
pytestmark = pytest.mark.slow

from repro.configs import SMOKE_ARCHS
from repro.data import BigramStream, lm_batches
from repro.models import Model
from repro.training import (
    OptConfig,
    adamw_init,
    adamw_update,
    cross_entropy,
    restore,
    save,
    schedule,
    train,
)


def test_adamw_first_step_matches_manual():
    cfg = OptConfig(lr=0.1, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
                    grad_clip=1e9, warmup_steps=1, total_steps=10 ** 9)
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.1, -0.2])}
    state = adamw_init(params)
    new, state, _ = adamw_update(cfg, params, grads, state)
    # with bias correction, the first Adam step is lr * sign-ish g/|g|
    expected = np.array([1.0, 2.0]) - 0.1 * np.array([0.1, -0.2]) / (
        np.abs(np.array([0.1, -0.2])) + 1e-8 / np.sqrt(1)
    )
    np.testing.assert_allclose(np.asarray(new["w"]), expected, rtol=1e-4)


def test_grad_clipping():
    cfg = OptConfig(grad_clip=1.0, warmup_steps=1)
    params = {"w": jnp.zeros(3)}
    grads = {"w": jnp.array([3.0, 4.0, 0.0])}  # norm 5
    from repro.training.optimizer import clip_by_global_norm

    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(
        np.asarray(clipped["w"]), [0.6, 0.8, 0.0], rtol=1e-5
    )


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) < 0.2
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=0.05)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=0.01)


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.array([[1, 2, -1, -1]])
    ce = cross_entropy(logits, labels)
    assert float(ce) == pytest.approx(np.log(8), rel=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    cfg = SMOKE_ARCHS["smollm-360m"]
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    path = os.path.join(tmp_path, "ckpt.npz")
    save(path, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    back = restore(path, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "c.npz")
    save(path, {"w": jnp.zeros((2, 2))})
    with pytest.raises((ValueError, KeyError)):
        restore(path, {"w": jnp.zeros((3, 3))})


def test_loss_decreases_on_learnable_stream():
    cfg = SMOKE_ARCHS["smollm-360m"]
    model = Model(cfg)
    batches = lm_batches(cfg.vocab_size, 8, 32, seed=0)
    res = train(
        model,
        batches,
        steps=25,
        opt_cfg=OptConfig(lr=3e-3, warmup_steps=2, total_steps=25),
        log_every=1000,
        log=lambda s: None,
    )
    assert res.history[-1]["loss"] < res.history[0]["loss"] - 0.1


def test_bigram_stream_deterministic():
    a = BigramStream(64, seed=3).sample(2, 16)
    b = BigramStream(64, seed=3).sample(2, 16)
    np.testing.assert_array_equal(a, b)
    c = BigramStream(64, seed=4).sample(2, 16)
    assert not np.array_equal(a, c)


def test_embeds_pipeline_for_stub_frontends():
    it = lm_batches(128, 2, 8, embeds_dim=32)
    batch = next(it)
    assert batch["embeds"].shape == (2, 8, 32)
    assert batch["labels"].shape == (2, 8)
