"""Pallas TPU fused selective-scan (Mamba core).

TPU adaptation (vs. the CUDA selective-scan): the recurrence is kept
sequential in time but fully vectorized over the channel dimension — each
grid step owns a (CL, D) chunk of the sequence, carries the (N, D) state in
VMEM scratch (D on the 128-wide lane axis), and fuses the discretization
``a = exp(dt * A)``, the recurrence and the output contraction
``y = C . h (+ D x)`` so only x/dt/B/C stream from HBM and only y streams
back — the kernel is HBM-bandwidth-bound exactly like the original.

Oracle: `repro.kernels.ref.selective_scan`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,  # (1, CL, D)
    dt_ref,  # (1, CL, D)
    at_ref,  # (N, D)  = A transposed
    b_ref,  # (1, CL, N)
    c_ref,  # (1, CL, N)
    h0_ref,  # (1, N, D)
    y_ref,  # (1, CL, D)
    hl_ref,  # (1, N, D)
    h_ref,  # VMEM scratch (N, D) f32
    *,
    cl: int,
    nc: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[:] = h0_ref[0].astype(jnp.float32)

    at = at_ref[:].astype(jnp.float32)  # (N, D)

    def step(t, h):
        dt = dt_ref[0, t].astype(jnp.float32)  # (D,)
        x = x_ref[0, t].astype(jnp.float32)  # (D,)
        bt = b_ref[0, t].astype(jnp.float32)  # (N,)
        ct = c_ref[0, t].astype(jnp.float32)  # (N,)
        a = jnp.exp(dt[None, :] * at)  # (N, D)
        h = a * h + (dt * x)[None, :] * bt[:, None]
        y = jnp.sum(h * ct[:, None], axis=0)  # (D,)
        pl.store(y_ref, (0, pl.dslice(t, 1), pl.dslice(None)), y[None].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, cl, step, h_ref[:])
    h_ref[:] = h

    @pl.when(ci == nc - 1)
    def _fin():
        hl_ref[0] = h.astype(hl_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def chunked_selective_scan(
    x: jax.Array,  # (B, L, D) post-conv activations
    dt: jax.Array,  # (B, L, D) softplus'd step sizes
    A: jax.Array,  # (D, N) negative decay rates
    Bm: jax.Array,  # (B, L, N)
    Cm: jax.Array,  # (B, L, N)
    h0: jax.Array | None = None,  # (B, N, D) NOTE: transposed state layout
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B, L, D), h_last (B, N, D))."""
    B, L, D = x.shape
    N = A.shape[1]
    cl = min(chunk, L)
    assert L % cl == 0, (L, cl)
    nc = L // cl
    if h0 is None:
        h0 = jnp.zeros((B, N, D), jnp.float32)
    at = A.T.astype(jnp.float32)  # (N, D): D on lanes

    y, h_last = pl.pallas_call(
        functools.partial(_kernel, cl=cl, nc=nc),
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, cl, D), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, cl, D), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((N, D), lambda b, ci: (0, 0)),
            pl.BlockSpec((1, cl, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, cl, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, N, D), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cl, D), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, N, D), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, D), x.dtype),
            jax.ShapeDtypeStruct((B, N, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, D), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, dt, at, Bm, Cm, h0)
    return y, h_last
