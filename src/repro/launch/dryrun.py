import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

# Multi-pod dry-run: lower + compile every (arch x input shape) on the
# production meshes and extract memory / cost / collective analyses.
# NOTE: the XLA_FLAGS override above MUST stay before any jax import (device
# count locks on first init), which is why this module has no docstring.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod
#   ... --out experiments/dryrun      # one JSON per combination

import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES
from ..kernels.ops import MeshCtx, mesh_context
from ..models import Model
from ..models.model import segmentize
from ..profiling.analytic import flops_per_token, layer_flops_per_token, param_count
from .mesh import dp_axes, make_production_mesh
from .roofline import roofline_from_compiled
from .shardings import (
    batch_specs,
    cache_specs,
    make_moe_mesh_info,
    optimizer_specs,
    param_specs,
    to_shardings,
)
from .specs import (
    SKIPS,
    effective_config,
    input_specs,
    make_decode_fn,
    make_prefill_fn,
    make_train_fn,
    opt_state_shape,
    params_shape,
)

from jax.sharding import NamedSharding, PartitionSpec as P


def scan_correction(cfg, seq: int, decode: bool) -> float:
    """cost_analysis counts each lax.scan (while) body ONCE; correct the
    aggregate FLOPs/bytes by the analytic ratio of true layer work (segment
    pattern x repeats) to once-per-segment work.  Layer work = active-param
    matmul FLOPs + attention context FLOPs (dominant at long sequence)."""
    segs = segmentize(cfg.layer_specs())
    fixed = 2.0 * cfg.vocab_size * cfg.d_model  # lm head matmul per token
    once = true = fixed
    for pat, r in segs:
        fp = float(
            sum(layer_flops_per_token(cfg, sp, seq, decode=decode) for sp in pat)
        )
        once += fp
        true += r * fp
    return true / once


# Beyond-paper per-arch tensor-parallel degree (SecPerf hillclimb: small
# models on TP=16 are collective-bound; right-sizing TP moves them to the
# memory/compute roofline).  --tp auto resolves here; --tp 16 is the paper
# baseline mesh.
TP_AUTO = {
    "deepseek-v3-671b": 16,
    "jamba-v0.1-52b": 16,
    "qwen2-moe-a2.7b": 8,
    "gemma-7b": 4,
    "qwen1.5-4b": 4,
    "musicgen-medium": 2,
    "qwen2-vl-2b": 2,
    "gemma3-1b": 2,
    "smollm-360m": 2,
    "xlstm-125m": 4,
}


def tp_auto(arch: str, shape) -> int:
    """Shape-aware TP (SecPerf):
    * train: the per-arch preference (collective-bound at TP=16 for small nets)
    * prefill: at least 256/B (dp cannot exceed the global batch)
    * decode/long: stay at TP=16 — decode streams the weights every step, so
      maximal weight sharding wins; the exception is xlstm, whose recurrent
      state resharding dominates (TP=4 measured best).
    """
    base = TP_AUTO.get(arch, 16)
    if arch == "xlstm-125m":
        return 4  # 4 heads: alignment (constraints + local recurrence) trumps
        # batch divisibility — TP=8 is 30x worse (unaligned GSPMD thrash)
    if shape.kind == "decode":
        return 16  # decode streams weights every step: maximal weight sharding
    need = max(1, 256 // max(1, shape.global_batch))
    return min(16, max(base, need))


def fsdp_auto(cfg, mesh) -> bool:
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    return param_count(cfg) * 2 / msize > 0.8e9


def run_one(arch: str, shape_name: str, *, multi_pod: bool, fsdp: str = "auto",
            tp: int = 16, verbose: bool = True) -> dict:
    t0 = time.time()
    shape = SHAPES[shape_name]
    base_cfg = ARCHS[arch]
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    if (arch, shape_name) in SKIPS:
        rec["status"] = "skipped"
        rec["reason"] = SKIPS[(arch, shape_name)]
        return rec
    cfg = effective_config(base_cfg, shape)
    if cfg is not base_cfg:
        rec["variant"] = f"sliding_window={cfg.sliding_window}"
    mesh = make_production_mesh(multi_pod=multi_pod, tp=tp)
    rec["tp"] = tp
    chips = mesh.devices.size
    mesh_info = make_moe_mesh_info(cfg, mesh, shape)
    model = Model(cfg, mesh_info=mesh_info)
    # "fsdp" here means ZeRO-1: optimizer moments sharded over 'data';
    # weights stay replicated across data (model-sharded only)
    use_fsdp = (fsdp == "on") if fsdp in ("on", "off") else (
        fsdp_auto(cfg, mesh) and shape.kind == "train"
    )
    rec["zero1"] = use_fsdp
    ep_axes = mesh_info.ep_axes if mesh_info else ()
    rec["ep_axes"] = list(ep_axes)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dpx = dp_axes(mesh)
    dp_size = 1
    for a in dpx:
        dp_size *= sizes[a]
    msize = sizes.get("model", 1)
    aligned = cfg.n_heads % msize == 0
    mctx = MeshCtx(mesh, dpx, "model", dp_size, msize, aligned=aligned)
    p_sh = params_shape(model)
    ep_size = mesh_info.ep_size if mesh_info else 1
    p_specs = param_specs(p_sh, cfg, ep_axes=ep_axes, fsdp=False, mesh=mesh, ep=ep_size)
    p_shard = to_shardings(p_specs, mesh)
    b_specs = batch_specs(shape, cfg, mesh)
    ins = input_specs(cfg, shape, model)
    repl = NamedSharding(mesh, P())

    with mesh, mesh_context(mctx):
        if shape.kind == "train":
            o_sh = opt_state_shape(p_sh)
            mv_specs = (
                optimizer_specs(p_specs, p_sh, mesh) if use_fsdp else p_specs
            )
            o_specs = {"m": mv_specs, "v": mv_specs, "step": P()}
            o_shard = to_shardings(o_specs, mesh)
            batch_shard = {
                k: NamedSharding(mesh, b_specs[k]) for k in ins
            }
            fn = jax.jit(
                make_train_fn(model),
                in_shardings=(p_shard, o_shard, batch_shard),
                out_shardings=(p_shard, o_shard, repl),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(p_sh, o_sh, ins)
        elif shape.kind == "prefill":
            batch_shard = {k: NamedSharding(mesh, b_specs[k]) for k in ins}
            cache_sh = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            c_specs = cache_specs(cache_sh, cfg, mesh, shape)
            c_shard = to_shardings(c_specs, mesh)
            logits_shard = NamedSharding(
                mesh, P(b_specs["tokens" if "tokens" in b_specs else "labels"][0], "model")
            )
            fn = jax.jit(
                make_prefill_fn(model, shape),
                in_shardings=(p_shard, batch_shard),
                out_shardings=(logits_shard, c_shard),
            )
            lowered = fn.lower(p_sh, ins)
        else:  # decode
            cache_sh = ins["cache"]
            c_specs = cache_specs(cache_sh, cfg, mesh, shape)
            c_shard = to_shardings(c_specs, mesh)
            batch_shard = {
                "tokens": NamedSharding(mesh, b_specs["tokens"]),
                "cache": c_shard,
                "idx": repl,
            }
            logits_shard = NamedSharding(mesh, P(b_specs["tokens"][0], "model"))
            fn = jax.jit(
                make_decode_fn(model),
                in_shardings=(p_shard, batch_shard),
                out_shardings=(logits_shard, c_shard),
                donate_argnames=None,
            )
            lowered = fn.lower(p_sh, ins)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    corr = scan_correction(cfg, shape.seq_len, shape.kind == "decode")
    rl, colls, mem = roofline_from_compiled(compiled, chips, scan_correction=corr)
    rec["scan_correction"] = round(corr, 3)
    # model-level "useful" FLOPs for the efficiency ratio
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n = param_count(cfg, embed=False)
    n_active = param_count(cfg, active=True, embed=False)
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = flops_per_token(cfg, shape.seq_len, decode=shape.kind == "decode") * tokens
    hlo_flops_total = rl.flops_per_device * chips
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        roofline=rl.as_dict(),
        collectives={
            "bytes_by_op": colls.bytes_by_op,
            "count_by_op": colls.count_by_op,
            "wire_bytes_per_device": colls.wire_bytes,
        },
        memory=mem,
        params=n,
        params_active=n_active,
        tokens=tokens,
        model_flops=model_flops,
        hlo_flops_total=hlo_flops_total,
        useful_ratio=(model_flops / hlo_flops_total) if hlo_flops_total else None,
    )
    if verbose:
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "status",
                                              "compile_s")}, indent=None))
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops/device=%.3e bytes/device=%.3e" % (
            rl.flops_per_device, rl.bytes_per_device))
        print("  collectives:", colls.bytes_by_op)
        print("  roofline: compute=%.4fs memory=%.4fs collective=%.4fs dominant=%s"
              % (rl.compute_s, rl.memory_s, rl.collective_s, rl.dominant))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", default="auto", choices=("auto", "on", "off"))
    ap.add_argument("--tp", default="16", help="tensor-parallel degree or 'auto'")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a in archs:
        for s in shapes:
            tag = f"{a}__{s}__{'multi' if args.multi_pod else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            try:
                tp = tp_auto(a, SHAPES[s]) if args.tp == "auto" else int(args.tp)
                rec = run_one(a, s, multi_pod=args.multi_pod, fsdp=args.fsdp, tp=tp)
            except Exception as e:
                failures += 1
                rec = {
                    "arch": a,
                    "shape": s,
                    "mesh": "2x16x16" if args.multi_pod else "16x16",
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"FAIL {tag}: {rec['error']}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
