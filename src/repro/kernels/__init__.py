"""Pallas TPU kernels (+ pure-jnp oracles in ref.py, dispatch in ops.py).

Each kernel: <name>.py holds the pl.pallas_call with explicit BlockSpec VMEM
tiling; ref.py the semantics of record; ops.py the jit'd model-facing wrapper
that picks kernel vs oracle per backend.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
