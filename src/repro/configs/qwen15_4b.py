"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    arch_type="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    source="hf:Qwen/Qwen1.5-0.5B",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
)
