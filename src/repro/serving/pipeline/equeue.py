"""Event queues for the pipelined co-simulation's global loop.

Two implementations of one tiny protocol (``push`` / ``pop`` / ``peek`` /
truthiness), both serving events in exactly the same total order — the
``(t, kind, seq)`` lexicographic order the original single ``heapq`` loop
established (``seq`` is the global FIFO push counter, so ties at one
instant resolve in push order and no comparison ever reaches the payload):

* :class:`HeapQueue` — the original global binary heap, kept as the
  reference implementation (`PipelineConfig(reference=True)` pins it);
* :class:`CalendarQueue` — a bucketed calendar queue: events land in
  buckets keyed by quantized time (``floor(t / quantum)``), bucket ids are
  tracked in a small lazy min-heap, and each bucket is its own little heap.
  Pushes into the *current* bucket (the dominant pattern: a batch closing
  at ``t`` schedules its free at ``t + d``, which usually lands a few
  buckets ahead, while flush/epoch chains land locally) pay ``log`` of the
  bucket population instead of ``log`` of the whole outstanding event set;
  the core's macro-event drains (same-instant machine-free batching) walk
  the front bucket via ``peek``/``pop`` without re-heapifying the rest.

The quantum defaults to the mean event spacing hint the caller derives from
the issue stream; correctness never depends on it (a degenerate quantum
just turns the calendar into one global heap plus a dict lookup).
"""
from __future__ import annotations

import heapq
import math


class HeapQueue:
    """The original single global binary heap (reference ordering)."""

    __slots__ = ("_h",)

    def __init__(self):
        self._h: list = []

    def push(self, entry: tuple) -> None:
        heapq.heappush(self._h, entry)

    def pop(self) -> tuple:
        return heapq.heappop(self._h)

    def peek(self) -> "tuple | None":
        return self._h[0] if self._h else None

    def __bool__(self) -> bool:
        return bool(self._h)

    def __len__(self) -> int:
        return len(self._h)


class CalendarQueue:
    """Bucketed calendar queue over ``(t, kind, seq, stage, payload)`` tuples.

    ``buckets[b]`` holds a heap of the entries with ``floor(t / quantum)
    == b``; ``_bids`` is a lazy min-heap of bucket ids (duplicates allowed,
    emptied buckets skipped at pop).  Total order served is identical to
    one global heap: bucket ids order by time prefix, and within a bucket
    the per-bucket heap orders by the same ``(t, kind, seq)`` key.
    """

    __slots__ = ("_q", "_inv_q", "_buckets", "_bids", "_n")

    def __init__(self, quantum: float = 1e-3):
        if not (quantum > 0.0) or not math.isfinite(quantum):
            raise ValueError(f"quantum must be positive and finite, got {quantum}")
        self._q = quantum
        self._inv_q = 1.0 / quantum
        self._buckets: dict[int, list] = {}
        self._bids: list[int] = []  # lazy min-heap of (possibly stale) bucket ids
        self._n = 0

    def push(self, entry: tuple) -> None:
        b = int(entry[0] * self._inv_q)
        bucket = self._buckets.get(b)
        if bucket is None:
            self._buckets[b] = [entry]
            heapq.heappush(self._bids, b)
        else:
            heapq.heappush(bucket, entry)
        self._n += 1

    def _front(self) -> "tuple[int, list]":
        """The non-empty minimum bucket (lazily discarding stale ids).

        Emptied buckets are deleted eagerly at pop, so a ``_bids`` entry
        either points at a live bucket or at nothing — a re-push into a
        drained quantum always re-registers its id.
        """
        buckets, bids = self._buckets, self._bids
        while True:
            b = bids[0]
            bucket = buckets.get(b)
            if bucket is not None:
                return b, bucket
            heapq.heappop(bids)

    def pop(self) -> tuple:
        b, bucket = self._front()
        self._n -= 1
        entry = heapq.heappop(bucket)
        if not bucket:
            del self._buckets[b]
        return entry

    def peek(self) -> "tuple | None":
        if self._n == 0:
            return None
        return self._front()[1][0]

    def __bool__(self) -> bool:
        return self._n > 0

    def __len__(self) -> int:
        return self._n


def make_queue(kind: str, quantum: "float | None" = None):
    """Build the configured event queue (``"heap"`` | ``"calendar"``)."""
    if kind == "heap":
        return HeapQueue()
    if kind == "calendar":
        return CalendarQueue(quantum if quantum else 1e-3)
    raise ValueError(f"unknown event queue {kind!r}")
