"""Assemble EXPERIMENTS.md roofline/dry-run tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b: float) -> str:
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}KB"


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def roofline_table(recs: list[dict], mesh: str) -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = [
        "| arch | shape | status | compute (s) | memory (s) | collective (s) | "
        "dominant | useful ratio | HBM/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            note = r.get("reason", r.get("error", ""))[:80]
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['status']} | — | — | — | — | — | — | {note} |"
            )
            continue
        rl = r["roofline"]
        mem = r.get("memory", {})
        hbm = fmt_bytes(mem.get("total_bytes", 0) / max(1, rl["chips"]))
        ur = r.get("useful_ratio")
        ur_s = f"{ur:.2f}" if ur else "—"
        note = r.get("variant", "")
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {rl['compute_s']:.4f} | "
            f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | **{rl['dominant']}** | "
            f"{ur_s} | {hbm} | {note} |"
        )
    return "\n".join(out)


def dryrun_summary(recs: list[dict], mesh: str) -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    ok = sum(1 for r in rows if r["status"] == "ok")
    skipped = [r for r in rows if r["status"] == "skipped"]
    err = [r for r in rows if r["status"] == "error"]
    lines = [f"- mesh `{mesh}`: **{ok} ok**, {len(skipped)} skipped, {len(err)} errors"]
    for r in skipped:
        lines.append(f"  - skipped {r['arch']} x {r['shape']}: {r['reason'][:120]}")
    for r in err:
        lines.append(f"  - ERROR {r['arch']} x {r['shape']}: {r['error'][:160]}")
    return "\n".join(lines)


def collective_detail(recs: list[dict], mesh: str) -> str:
    rows = [r for r in recs if r["mesh"] == mesh and r["status"] == "ok"]
    rows.sort(key=lambda r: -r["roofline"]["collective_s"])
    out = ["| arch x shape | collective bytes/dev | by op |", "|---|---|---|"]
    for r in rows[:10]:
        ops = ", ".join(
            f"{k}:{fmt_bytes(v)}" for k, v in sorted(
                r["collectives"]["bytes_by_op"].items(), key=lambda kv: -kv[1]
            )
        )
        out.append(
            f"| {r['arch']} x {r['shape']} | "
            f"{fmt_bytes(r['collectives']['wire_bytes_per_device'])} | {ops} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    for mesh in ("16x16", "2x16x16"):
        if not any(r["mesh"] == mesh for r in recs):
            continue
        print(f"\n### Dry-run summary — mesh {mesh}\n")
        print(dryrun_summary(recs, mesh))
        print(f"\n### Roofline — mesh {mesh}\n")
        print(roofline_table(recs, mesh))
        print(f"\n### Top collective-bound — mesh {mesh}\n")
        print(collective_detail(recs, mesh))


if __name__ == "__main__":
    main()
