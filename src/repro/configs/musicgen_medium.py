"""musicgen-medium [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

Backbone only: the EnCodec conv codec is a stub — ``input_specs`` feeds
audio-token ids (vocab 2048) or frame embeddings directly.  long_500k is
skipped for this arch (524k EnCodec frames ≈ 3 h of audio, far outside the
model's 30 s regime; see DESIGN.md §4).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    source="arXiv:2306.05284",
    norm="ln",
    act="gelu",
    rope_theta=10_000.0,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=256,
    param_dtype="float32",
    compute_dtype="float32",
)
