"""End-to-end serving driver: profile -> plan -> serve, all real.

Mirrors the paper's pipeline exactly, on CPU:
  1. OFFLINE PROFILING: measure jitted batched forwards of two reduced
     assigned architectures at each batch size (the paper's "profiling
     library", Sec. III-A).
  2. PLAN: Harpagon splits the session SLO and schedules machines over the
     measured profiles; baselines planned for comparison.
  3. SERVE: a batched request stream runs through the plan with REAL model
     executions; SLO attainment is reported.

    PYTHONPATH=src python examples/serve_multidnn.py [--requests 300]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Leaf, Planner, Workload, series
from repro.core.baselines import BASELINES
from repro.core.dag import AppDAG
from repro.core.profiles import Config, ModuleProfile
from repro.models import Model


def profile_model(name: str, batches=(1, 2, 4, 8, 16)) -> tuple[ModuleProfile, callable]:
    """Offline profiling pass: measure the real jitted forward per batch size."""
    cfg = get_config(name, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    @jax.jit
    def fwd(p, t):
        return model.forward(p, t).logits

    rows = []
    for b in batches:
        toks = jnp.zeros((b, 16), jnp.int32)
        fwd(params, toks).block_until_ready()  # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            fwd(params, toks).block_until_ready()
        d = (time.perf_counter() - t0) / reps
        rows.append(Config(b, round(d, 6), "cpu", 1.0))
    profile = ModuleProfile(name, tuple(rows))

    def executor(b):
        fwd(params, jnp.zeros((b, 16), jnp.int32)).block_until_ready()

    return profile, executor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--slo", type=float, default=2.0)
    ap.add_argument(
        "--sweep",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="after real serving, replay the plan in virtual time under "
        "uniform/poisson/bursty arrivals per planner preset",
    )
    args = ap.parse_args()

    archs = ["qwen2-vl-2b", "smollm-360m"]
    print("offline profiling (real jitted forwards)...")
    profiles, executors = {}, {}
    for a in archs:
        profiles[a], executors[a] = profile_model(a)
        rows = ", ".join(f"b{c.batch}:{c.duration*1e3:.1f}ms" for c in
                         sorted(profiles[a].configs, key=lambda c: c.batch))
        print(f"  {a}: {rows}")

    dag = AppDAG("vl-session", series(*[Leaf(a) for a in archs]))
    wl = Workload(dag, {a: args.rate for a in archs}, args.slo)
    plan = Planner().plan(wl, profiles)
    print("\n" + plan.summary())
    if not plan.feasible:
        raise SystemExit("infeasible — raise --slo or lower --rate")
    for opts in BASELINES:
        bl = Planner(opts).plan(wl, profiles)
        tag = f"{bl.cost:.2f} ({bl.cost / plan.cost:.2f}x)" if bl.feasible else "infeasible"
        print(f"  baseline {opts.name:<10} cost: {tag}")

    from repro.serving import ServingEngine

    engine = ServingEngine(plan, executors=executors)
    res = engine.run(args.requests, args.rate)
    print(
        f"\nserved {len(res.e2e_latencies)} frames with REAL executions: "
        f"SLO attainment {100 * res.attainment:.1f}%  p99 {res.p99:.3f}s (slo {args.slo}s)"
    )
    for m, st in res.module_stats.items():
        print(f"  {m}: {st.batches} batches, max module latency {st.max_latency:.3f}s")

    if args.sweep:
        # virtual-time replay of the measured profiles under arrival-process
        # diversity: the planner provisions for the uniform worst case
        # (Theorem 1); Poisson and bursty MMPP streams show how much SLO
        # attainment that steady-state assumption buys — per planner preset
        print("\narrival-process sweep (virtual time, measured profiles):")
        presets = [("harpagon", plan)] + [
            (o.name, p)
            for o in BASELINES
            if (p := Planner(o).plan(wl, profiles)).feasible
        ]
        print(f"  {'preset':<10} {'arrivals':<8} {'attain':>7} {'p99(s)':>8}")
        for name, p in presets:
            eng = ServingEngine(p, policy=p.options.policy)
            for kind in ("uniform", "poisson", "bursty"):
                r = eng.run(2000, args.rate, arrivals=kind, seed=0)
                print(
                    f"  {name:<10} {kind:<8} {100 * r.attainment:6.1f}% {r.p99:8.3f}"
                )

        # shed-rate sweep: drive the Harpagon plan past its provisioned rate
        # with bursty MMPP arrivals; without admission control the backlog
        # (and p99) grows with the run, while token-bucket / queue-depth
        # shedding at ingress bounds p99 at an explicit, reported shed rate.
        # Shed frames count as SLO misses in `attainment`.
        from repro.serving.frontend import FrontendConfig, QueueDepth, TokenBucket

        print("\nshed-rate sweep (MMPP overload, dummy streaming on):")
        fes = [
            ("none", FrontendConfig(dummies=True)),
            ("token-bucket", FrontendConfig(dummies=True, admission=TokenBucket(burst=4))),
            ("queue-depth", FrontendConfig(dummies=True, admission=QueueDepth(depth=8))),
        ]
        print(f"  {'admission':<13} {'load':<6} {'attain':>7} {'shed':>6} {'p99(s)':>8}")
        eng = ServingEngine(plan)
        for adm_name, fe in fes:
            for load in (1.0, 1.5, 3.0):
                r = eng.run(
                    2000, args.rate, arrivals="mmpp", seed=0, timeout="budget",
                    frontend=fe, offered_rate=load * args.rate,
                )
                print(
                    f"  {adm_name:<13} {load:<6g} {100 * r.attainment:6.1f}% "
                    f"{100 * r.shed / max(1, r.offered):5.1f}% {r.p99:8.3f}"
                )

        # control-plane demo: one diurnal period served by the epoch-based
        # incremental control loop (windowed rate estimation -> warm-start
        # Planner.replan -> live hot-swap) vs one static plan provisioned
        # for the diurnal peak.  Serving cost for the loop is the
        # time-integral of the active plan's cost across epochs.
        from repro.serving import ControlLoopConfig, serving_cost
        from repro.serving.arrivals import trace_arrivals

        print("\ndiurnal control plane (pipelined co-simulation):")
        n = 4000
        period = n / args.rate
        diurnal = trace_arrivals(n, args.rate, seed=0, period=period)
        fe = FrontendConfig(dummies=True)
        loop = ServingEngine(plan).run(
            n, args.rate, arrivals=diurnal, frontend=fe, pipeline=True,
            control=ControlLoopConfig(
                interval=period / 48, profiles=profiles, margin=0.25
            ),
        )
        cost_loop = serving_cost(loop.epochs, float(diurnal[-1]))
        wl_peak = Workload(dag, {a: 1.8 * args.rate for a in archs}, args.slo)
        plan_peak = Planner().plan(wl_peak, profiles)
        swaps = sum(1 for e in loop.epochs if e.swapped)
        print(
            f"  replanning : cost {cost_loop:7.2f}  attain {100 * loop.attainment:5.1f}%"
            f"  ({swaps} swaps over {len(loop.epochs)} epochs, "
            f"final plan v{loop.epochs[-1].version})"
        )
        if plan_peak.feasible:
            static = ServingEngine(plan_peak).run(
                n, 1.8 * args.rate, arrivals=diurnal, frontend=fe, pipeline=True
            )
            print(
                f"  static peak: cost {plan_peak.cost:7.2f}  attain {100 * static.attainment:5.1f}%"
                f"  -> replanning {plan_peak.cost / cost_loop:.2f}x cheaper"
            )
        else:
            print("  static peak: infeasible at 1.8x the provisioned rate "
                  "(raise --slo to compare)")


if __name__ == "__main__":
    main()
