"""Architecture configuration: one dataclass drives every assigned model family."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class LayerSpec:
    """What one decoder layer is made of."""

    mixer: str  # 'attn' | 'mla' | 'mamba' | 'mlstm' | 'slstm'
    ffn: str  # 'dense' | 'moe' | 'none'
    window: int | None = None  # sliding-window size for local attention
    rope_theta: float | None = None  # per-layer theta override (gemma3 locals)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str  # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation

    head_dim: int | None = None  # default d_model // n_heads
    # --- attention ---
    attn_kind: str = "gqa"  # 'gqa' | 'mla'
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int | None = None  # uniform window (or the local size)
    local_global: tuple[int, int] | None = None  # e.g. (5, 1) local:global
    rope_theta: float = 10_000.0
    rope_theta_local: float | None = None
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE
    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int | None = None
    # --- ffn ---
    act: str = "silu"  # 'silu' (swiglu) | 'gelu' (geglu)
    norm: str = "rms"  # 'rms' | 'ln'
    gemma_norm: bool = False  # (1+w) RMSNorm + sqrt(d) embedding scale
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1
    n_dense_layers: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- hybrid / ssm ---
    hybrid_pattern: tuple[str, ...] | None = None  # mixer per layer, cycled
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0  # xlstm: layer i is sLSTM when (i+1) % slstm_every == 0
    # --- embeddings / misc ---
    tie_embeddings: bool = False
    mtp_depth: int = 0  # deepseek multi-token-prediction aux heads
    input_mode: str = "tokens"  # 'tokens' | 'embeds' (vlm/audio frontends are stubs)
    max_seq_len: int = 131_072
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = False

    # ----------------------------------------------------------------- helpers
    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vdim(self) -> int:
        return self.v_head_dim or self.hdim

    @property
    def is_moe_arch(self) -> bool:
        return self.n_experts > 0

    def _mixer(self, i: int) -> tuple[str, int | None, float | None]:
        if self.hybrid_pattern is not None:
            m = self.hybrid_pattern[i % len(self.hybrid_pattern)]
        elif self.slstm_every:
            m = "slstm" if (i + 1) % self.slstm_every == 0 else "mlstm"
        elif self.attn_kind == "mla":
            m = "mla"
        else:
            m = "attn"
        window, theta = None, None
        if m in ("attn",):
            if self.local_global is not None:
                nl, ng = self.local_global
                if i % (nl + ng) < nl:
                    window = self.sliding_window
                    theta = self.rope_theta_local
            else:
                window = self.sliding_window
        return m, window, theta

    def _ffn(self, i: int) -> str:
        if self.d_ff == 0 and not self.is_moe_arch:
            return "none"  # xlstm blocks carry their own projections
        if not self.is_moe_arch or i < self.n_dense_layers:
            return "dense"
        j = i - self.n_dense_layers
        if self.moe_every == 1 or j % self.moe_every == self.moe_every - 1:
            return "moe"
        return "dense"

    def layer_specs(self) -> tuple[LayerSpec, ...]:
        out = []
        for i in range(self.n_layers):
            mixer, window, theta = self._mixer(i)
            out.append(LayerSpec(mixer, self._ffn(i), window, theta))
        return tuple(out)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def with_sliding_window(self, window: int) -> "ArchConfig":
        """Beyond-paper long-context variant: uniform local attention."""
        return self.replace(sliding_window=window, local_global=None)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}
