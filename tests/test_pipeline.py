"""Pipelined serving core: per-frame DAG co-simulation (ISSUE-3 acceptance).

Covers: golden equivalence of the co-simulation against the flat engine's
vectorized kernel on multi-stage DAGs (the kernel-vs-event-core
cross-validation extended through the DAG), the uniform-arrivals
mean-vs-analytic-WCL-sum acceptance bound, the splitter-budget property
(feasible `split_lc` budgets hold end-to-end; budget-overrun attribution
sums exactly to the end-to-end overrun), backpressure under bounded queues,
correlated per-frame stochastic fanout, event-interleaved closed-loop
clients agreeing with the deprecated fixed-point formulation, and the
per-rank `timeout="budget"` fill-time floor.
"""
import warnings

import numpy as np
import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.core.dag import AppDAG, Leaf, Workload, par, series, sp_critical_masks
from repro.core.dispatch import Policy, expand_machines, remaining_workloads
from repro.core.harpagon import Plan, PlannerOptions
from repro.core.profiles import Config, ModuleProfile
from repro.core.residual import schedule_module
from repro.serving import ServingEngine
from repro.serving.frontend import ClosedLoopClients, FrontendConfig, TokenBucket
from repro.serving.pipeline import (
    AccumulatorFanout,
    FanoutSpec,
    PipelineConfig,
    draw_counts,
)
from repro.serving.replay import expand_fanout
from repro.workloads import synth_profiles, synth_workloads
from repro.workloads.apps import ACTDET, CAPTION, FACE, FANOUT, TRAFFIC, make_workload

PROFILES = synth_profiles()


def chain_plan(specs, rate: float, slo: float, fanouts=None) -> Plan:
    """Build a series-chain plan from ``(name, configs, budget)`` specs."""
    leaves = [Leaf(n) for n, _, _ in specs]
    app = AppDAG("chain", series(*leaves))
    fanouts = fanouts or {}
    scheds, rates = {}, {}
    for name, cfgs, budget in specs:
        r = rate * fanouts.get(name, 1.0)
        s = schedule_module(
            name, r, budget, ModuleProfile(name, tuple(cfgs)), Policy.TC,
            use_dummy=False,
        )
        assert s is not None, name
        scheds[name] = s
        rates[name] = r
    return Plan(Workload(app, rates, slo), PlannerOptions(), scheds, True, 0.0)


def suite_plan(app, rate, slo):
    plan = Planner(B.HARPAGON).plan(make_workload(app, rate=rate, slo=slo), PROFILES)
    assert plan.feasible
    return plan


# ------------------------------------------------- golden: pipeline == kernel


class TestGoldenEquivalence:
    """With unbounded queues and deterministic fanout the co-simulation must
    reproduce the flat engine (vectorized kernel) bit-for-bit: same instance
    streams, same batch boundaries, same per-frame e2e — the kernel-vs-
    event-core cross-validation extended through multi-stage DAGs."""

    @pytest.mark.parametrize("kind", ["uniform", "poisson", "mmpp"])
    def test_two_stage_dag_matches_kernel(self, kind):
        plan = suite_plan(FACE, 150.0, 2.5)
        eng = ServingEngine(plan)
        flat = eng.run(600, 150.0, arrivals=kind, seed=5)
        pipe = eng.run(600, 150.0, arrivals=kind, seed=5, pipeline=True)
        np.testing.assert_allclose(
            np.asarray(pipe.e2e_latencies), np.asarray(flat.e2e_latencies), atol=1e-9
        )
        for m in plan.workload.app.modules:
            assert pipe.module_stats[m].batches == flat.module_stats[m].batches
            np.testing.assert_allclose(
                np.sort(pipe.module_stats[m].latencies),
                np.sort(flat.module_stats[m].latencies),
                atol=1e-9,
            )

    @pytest.mark.parametrize(
        "app,rate,slo",
        [(TRAFFIC, 100.0, 2.0), (CAPTION, 90.0, 2.5), (ACTDET, 80.0, 3.0)],
    )
    def test_wider_dags_match_kernel(self, app, rate, slo):
        """Parallel branches (traffic/actdet) and fanout < 1 (caption)."""
        plan = suite_plan(app, rate, slo)
        eng = ServingEngine(plan)
        flat = eng.run(500, rate, arrivals="mmpp", seed=2)
        pipe = eng.run(500, rate, arrivals="mmpp", seed=2, pipeline=True)
        assert len(pipe.e2e_latencies) == len(flat.e2e_latencies)
        np.testing.assert_allclose(
            np.asarray(pipe.e2e_latencies), np.asarray(flat.e2e_latencies), atol=1e-9
        )
        assert (pipe.shed, pipe.dropped) == (flat.shed, flat.dropped)

    def test_budget_timeout_matches_kernel(self):
        plan = suite_plan(FACE, 150.0, 2.5)
        eng = ServingEngine(plan)
        flat = eng.run(500, 150.0, arrivals="poisson", seed=1, timeout="budget")
        pipe = eng.run(
            500, 150.0, arrivals="poisson", seed=1, timeout="budget", pipeline=True
        )
        np.testing.assert_allclose(
            np.asarray(pipe.e2e_latencies), np.asarray(flat.e2e_latencies), atol=1e-9
        )


# ------------------------------------------------- acceptance: mean vs WCL sum


class TestAnalyticWCL:
    def test_uniform_mean_within_5pct_of_wcl_sum(self):
        """Acceptance: on uniform arrivals the pipelined mean e2e matches
        the analytic critical-path WCL sum within 5% (service-dominated
        two-stage chain: collection terms are the only modeled slack)."""
        plan = chain_plan(
            [("A", [Config(8, 1.0)], 1.1), ("B", [Config(8, 1.0)], 1.1)],
            400.0, 2.2,
        )
        res = ServingEngine(plan).run(1200, 400.0, pipeline=True)
        wcl_sum = plan.e2e_latency
        mean = float(np.mean(res.e2e_latencies))
        assert abs(mean - wcl_sum) / wcl_sum <= 0.05
        # the WCL sum is an upper envelope on uniform arrivals
        assert res.p99 <= wcl_sum + 1e-9

    def test_suite_mean_tracks_wcl_sum(self):
        """Seed apps stay within the batch-collection slack of the WCL sum
        (mean below, p99 near): the pipelined numbers are the analytic
        model's trajectory, not a new regime."""
        for app, rate, slo in ((FACE, 150.0, 2.5), (TRAFFIC, 100.0, 2.0)):
            plan = suite_plan(app, rate, slo)
            res = ServingEngine(plan).run(800, rate, pipeline=True)
            wcl_sum = plan.e2e_latency
            mean = float(np.mean(res.e2e_latencies))
            assert mean <= wcl_sum + 1e-9, app.name
            assert mean >= 0.5 * wcl_sum, app.name


# ------------------------------------------------- splitter-budget property


class TestSplitterBudgets:
    def test_feasible_lc_budgets_hold_end_to_end(self):
        """Property: when `split_lc` (via the planner) returns a feasible
        budget over integer-exact covers, every frame's pipelined e2e is
        <= SLO on uniform arrivals."""
        rng = np.random.default_rng(11)
        checked = 0
        for _ in range(6):
            b1, b2 = int(rng.choice([4, 8, 16])), int(rng.choice([2, 4, 8]))
            t1, t2 = int(rng.choice([10, 20, 40])), int(rng.choice([10, 20, 40]))
            d1, d2 = b1 / t1, b2 / t2
            # rate = integer multiple of both throughputs: no fractional tail
            rate = float(int(rng.integers(2, 5)) * np.lcm(t1, t2))
            wcl1, wcl2 = d1 + b1 / rate, d2 + b2 / rate
            slo = (wcl1 + wcl2) * 1.05
            plan = chain_plan(
                [("A", [Config(b1, d1)], wcl1 * 1.01), ("B", [Config(b2, d2)], wcl2 * 1.01)],
                rate, slo,
            )
            res = ServingEngine(plan).run(600, rate, pipeline=True)
            e2e = np.asarray(res.e2e_latencies)
            assert e2e.size and e2e.max() <= slo + 1e-9, (b1, b2, d1, d2, rate)
            checked += 1
        assert checked == 6

    def test_suite_attainment_dummy_free(self):
        """Across suite workloads whose plans carry no dummy padding, the
        default planner's pipelined attainment on uniform arrivals stays
        >= 0.99 — machines downstream of batched stages see bursty
        collection the steady-state Theorem-1 WCL does not model (the PR-3
        finding, closed by ISSUE-4) — while the burst-aware planner
        (``PlannerOptions(burst_aware=True)``, checking every machine at
        ``d + b/w + b_up/rate_up``) no longer overshoots at all."""
        import dataclasses

        opts_ba = dataclasses.replace(B.HARPAGON, name="harp-burst", burst_aware=True)
        checked = checked_ba = 0
        for wl in synth_workloads(40):
            plan = Planner(B.HARPAGON).plan(wl, PROFILES)
            if not plan.feasible:
                continue
            if any(a.dummy > 0 for s in plan.schedules.values() for a in s.allocs):
                continue
            fr = wl.rates[wl.app.modules[0]] / FANOUT[wl.app.name][wl.app.modules[0]]
            res = ServingEngine(plan).run(300, fr, pipeline=True)
            assert res.attainment >= 0.99, wl.tag
            checked += 1
            ba = Planner(opts_ba).plan(wl, PROFILES)
            if not ba.feasible or any(
                a.dummy > 0 for s in ba.schedules.values() for a in s.allocs
            ):
                continue
            res_ba = ServingEngine(ba).run(300, fr, pipeline=True)
            assert res_ba.attainment == 1.0, wl.tag
            checked_ba += 1
        assert checked >= 10 and checked_ba >= 10

    def test_burst_aware_closes_known_overshoots(self):
        """The two suite points where the default plan's realized collection
        exceeds a tight SLO by a few percent (one on a fractional tail, one
        on a full short-fill machine): the burst-aware correction makes both
        attain 1.0 at a bounded cost premium."""
        import dataclasses

        from repro.workloads.apps import app_by_name

        opts_ba = dataclasses.replace(B.HARPAGON, name="harp-burst", burst_aware=True)
        for name, rate, slo in (("traffic", 242.59, 1.5), ("face", 20.5, 1.5)):
            wl = make_workload(app_by_name(name), rate, slo)
            base = Planner(B.HARPAGON).plan(wl, PROFILES)
            assert base.feasible
            res = ServingEngine(base).run(300, rate, pipeline=True)
            assert res.attainment < 1.0  # the finding, reproduced
            ba = Planner(opts_ba).plan(wl, PROFILES)
            assert ba.feasible
            assert not any(
                a.dummy > 0 for s in ba.schedules.values() for a in s.allocs
            )
            res_ba = ServingEngine(ba).run(300, rate, pipeline=True)
            assert res_ba.attainment == 1.0, name
            assert ba.cost <= base.cost * 1.5  # bounded robustness premium

    @pytest.mark.parametrize("kind", ["uniform", "mmpp"])
    def test_attribution_sums_to_e2e_overrun(self, kind):
        """Acceptance: per-module budget-overrun attribution sums exactly to
        the frame's end-to-end overrun beyond its critical-path budget sum
        — for every completed frame, also under bursty overload."""
        plan = suite_plan(ACTDET, 80.0, 3.0)
        eng = ServingEngine(plan)
        res = eng.run(
            500, 80.0, arrivals=kind, seed=7, pipeline=True,
            offered_rate=80.0 * (1.2 if kind == "mmpp" else 1.0),
        )
        pr = res.pipeline
        budgets = {m: s.budget for m, s in plan.schedules.items()}
        attr, path_budget = pr.overrun_attribution(budgets)
        total = sum(attr[m] for m in pr.modules)
        done = pr.completed
        assert done.any()
        np.testing.assert_allclose(
            total[done], pr.e2e[done] - path_budget[done], atol=1e-9
        )
        # the decomposition rides on the realized critical path
        lat, masks = pr.critical_path()
        np.testing.assert_allclose(lat[done], pr.e2e[done], atol=1e-9)
        for f in np.flatnonzero(done)[:50]:
            on = [m for m in pr.modules if masks[m][f]]
            assert on, f

    def test_overrun_by_module_flags_the_blown_budget(self):
        """A two-stage chain whose splitter handed B an unachievable budget:
        late frames' overrun must be attributed to B, not A."""
        import dataclasses

        plan = chain_plan(
            [("A", [Config(4, 0.1)], 0.3), ("B", [Config(16, 0.4)], 0.9)],
            40.0, 0.7,
        )
        s_b = dataclasses.replace(plan.schedules["B"], budget=0.45)
        plan = dataclasses.replace(
            plan, schedules={**plan.schedules, "B": s_b}
        )
        res = ServingEngine(plan).run(400, 40.0, pipeline=True)
        pr = res.pipeline
        budgets = {m: s.budget for m, s in plan.schedules.items()}
        assert (pr.e2e > plan.workload.slo).any()
        over = pr.overrun_by_module(budgets, plan.workload.slo)
        assert over["B"] > 0.0
        assert over["B"] > over["A"]


# ------------------------------------------------- backpressure


class TestBackpressure:
    def _two_stage(self):
        # A is fast and cheap; B is slow: bounded ingress at B must stall A
        return chain_plan(
            [("A", [Config(4, 0.05)], 0.2), ("B", [Config(8, 0.8)], 1.0)],
            40.0, 1.4,
        )

    def test_bounded_queue_stalls_upstream(self):
        plan = self._two_stage()
        eng = ServingEngine(plan)
        free = eng.run(400, 40.0, arrivals="mmpp", seed=3, pipeline=True)
        tight = eng.run(
            400, 40.0, arrivals="mmpp", seed=3,
            pipeline=PipelineConfig(queue_cap=8),
        )
        # backpressure pushes waiting upstream: B's measured in-stage
        # instance latency strictly shrinks (its backlog is bounded) while
        # the frame pays the wait at the blocked hand-off instead — e2e
        # never improves and no frame is lost
        b_free = np.asarray(free.module_stats["B"].latencies)
        b_tight = np.asarray(tight.module_stats["B"].latencies)
        assert b_tight.max() < b_free.max() - 1e-9
        assert np.mean(tight.e2e_latencies) >= np.mean(free.e2e_latencies) - 1e-9
        # conservation: every offered frame accounted
        assert len(tight.e2e_latencies) + tight.shed + tight.dropped == 400

    def test_unbounded_cap_is_identity(self):
        plan = self._two_stage()
        eng = ServingEngine(plan)
        a = eng.run(300, 40.0, arrivals="poisson", seed=1, pipeline=True)
        b = eng.run(
            300, 40.0, arrivals="poisson", seed=1,
            pipeline=PipelineConfig(queue_cap=None),
        )
        np.testing.assert_array_equal(a.e2e_latencies, b.e2e_latencies)

    def test_queue_cap_floors_at_largest_batch(self):
        """A cap below the largest batch size could never form a batch; the
        stage floors it so formation always completes."""
        plan = self._two_stage()
        res = ServingEngine(plan).run(
            300, 40.0, pipeline=PipelineConfig(queue_cap=1)
        )
        assert len(res.e2e_latencies) == 300
        assert res.dropped == 0

    def test_queue_cap_validation(self):
        with pytest.raises(ValueError):
            PipelineConfig(queue_cap=0)
            ServingEngine(self._two_stage()).run(
                10, 40.0, pipeline=PipelineConfig(queue_cap=0)
            )


# ------------------------------------------------- per-frame fanout


class TestFanout:
    def test_accumulator_matches_expand_fanout(self):
        for phi in (0.5, 1.0, 1.5, 2.0, 3.0, 0.7):
            frames = np.arange(200)
            inst = expand_fanout(frames, phi)
            counts = np.bincount(inst, minlength=200)
            acc = AccumulatorFanout(phi)
            mine = np.array([acc.count(f) for f in frames])
            np.testing.assert_array_equal(mine, counts)

    def test_stochastic_is_seeded_and_mean_preserving(self):
        spec = FanoutSpec(mode="stochastic", cv=0.5, correlation=1.0)
        fanouts = {"det": 1.0, "cls_a": 2.0, "cls_b": 3.0}
        a = draw_counts(spec, 4000, fanouts, ["det"], seed=9)
        b = draw_counts(spec, 4000, fanouts, ["det"], seed=9)
        for m in fanouts:
            np.testing.assert_array_equal(a[m], b[m])
        assert a["cls_a"].mean() == pytest.approx(2.0, rel=0.1)
        assert a["cls_b"].mean() == pytest.approx(3.0, rel=0.1)
        # source clamp: a frame always physically exists
        assert a["det"].min() >= 1

    def test_sibling_correlation_tracks_rho(self):
        """correlation=1: a busy frame loads BOTH classifiers (high count
        correlation); correlation=0: independent module jitter."""
        fanouts = {"det": 1.0, "cls_a": 4.0, "cls_b": 4.0}

        def corr(rho):
            spec = FanoutSpec(mode="stochastic", cv=0.8, correlation=rho)
            c = draw_counts(spec, 6000, fanouts, ["det"], seed=3)
            return float(np.corrcoef(c["cls_a"], c["cls_b"])[0, 1])

        assert corr(1.0) > 0.6
        assert abs(corr(0.0)) < 0.15
        assert corr(1.0) > corr(0.5) > corr(0.0) - 0.05

    def test_stochastic_pipeline_run_conserves_frames(self):
        plan = suite_plan(TRAFFIC, 100.0, 2.0)
        cfg = PipelineConfig(fanout=FanoutSpec(mode="stochastic", cv=0.6))
        res = ServingEngine(plan).run(400, 100.0, pipeline=cfg)
        pr = res.pipeline
        # completed + shed + dropped + skipped == all frames
        n_acc = (
            len(res.e2e_latencies) + res.shed + res.dropped + int(pr.skipped.sum())
        )
        assert n_acc == 400
        # same seed, same draw: bit-reproducible
        res2 = ServingEngine(plan).run(400, 100.0, pipeline=cfg)
        np.testing.assert_array_equal(res.e2e_latencies, res2.e2e_latencies)

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FanoutSpec(mode="bogus")
        with pytest.raises(ValueError):
            FanoutSpec(correlation=1.5)
        with pytest.raises(ValueError):
            FanoutSpec(cv=-1.0)


# ------------------------------------------------- adaptive dummy streaming


class TestPipelineDummyStreaming:
    def test_dummy_padded_plan_hits_modeled_wcl(self):
        """The pipelined injector pads collection up to the provisioned
        collect rate (rate-limited paid-slot pacing): a dummy-padded plan
        under timeout="budget" meets its modeled 2d WCL, like the flat
        frontend's deficit injector."""
        prof = ModuleProfile("M", (Config(32, 0.3),))
        s = schedule_module("M", 10.0, 1.0, prof, Policy.TC)
        assert s is not None and any(a.dummy > 0 for a in s.allocs)
        wl = Workload(AppDAG("app", Leaf("M")), {"M": 10.0}, 1.0)
        plan = Plan(wl, PlannerOptions(), {"M": s}, True, 0.0)
        res = ServingEngine(plan).run(
            600, 10.0, arrivals="poisson", timeout="budget",
            frontend=FrontendConfig(dummies=True), pipeline=True,
        )
        assert res.module_stats["M"].phantom > 0
        assert res.attainment >= 0.99
        assert res.p99 <= plan.workload.slo + 1e-9
        # phantoms never enter the statistics
        assert len(res.e2e_latencies) + res.dropped == 600

    def test_injector_pauses_on_wedged_bounded_stage(self):
        """Regression: a full bounded stage under RR with no flush deadline
        must not be kept alive by the phantom chain — the chain goes dormant
        so the quiescence flush can run, and every frame still completes."""
        from repro.core.dispatch import Alloc
        from repro.core.residual import ModuleSchedule

        c = Config(4, 0.1)
        a = Alloc(c, 2.0, 2 * c.throughput, dummy=5.0)
        s = ModuleSchedule("M", a.rate, 0.0, 0.5, (a,), Policy.RR)
        wl = Workload(AppDAG("app", Leaf("M")), {"M": a.rate}, 1.0)
        plan = Plan(wl, PlannerOptions(policy=Policy.RR), {"M": s}, True, 0.0)
        res = ServingEngine(plan, policy=Policy.RR).run(
            2, a.rate, frontend=FrontendConfig(dummies=True),
            pipeline=PipelineConfig(queue_cap=4),
        )
        assert len(res.e2e_latencies) == 2 and res.dropped == 0

    def test_injector_idle_when_real_traffic_meets_target(self):
        """No dummy rate, real traffic at the provisioned rate on uniform
        arrivals: the adaptive injector stays (nearly) silent."""
        plan = chain_plan(
            [("A", [Config(8, 0.2)], 0.5), ("B", [Config(8, 0.2)], 0.5)],
            40.0, 1.0,
        )
        res = ServingEngine(plan).run(
            400, 40.0, timeout="budget",
            frontend=FrontendConfig(dummies=True), pipeline=True,
        )
        injected = sum(s.phantom for s in res.module_stats.values())
        assert injected <= 8  # at most start-up slack, not a stream


# ------------------------------------------------- event-interleaved clients


class TestInterleavedClients:
    def _plan(self, batched=True):
        if batched:
            return chain_plan(
                [("A", [Config(8, 0.3)], 0.5), ("B", [Config(4, 0.2)], 0.3)],
                80.0, 0.8,
            )
        return chain_plan(
            [("A", [Config(1, 0.05)], 0.2), ("B", [Config(1, 0.05)], 0.2)],
            100.0, 0.5,
        )

    def test_fixed_point_shim_deprecated(self):
        eng = ServingEngine(self._plan(batched=False))
        fe = FrontendConfig(clients=ClosedLoopClients(n_clients=4))
        with pytest.warns(DeprecationWarning):
            eng.run(50, 100.0, frontend=fe)

    @pytest.mark.parametrize("batched", [False, True])
    def test_agrees_with_fixed_point_on_uniform_pacing(self, batched):
        """Satellite acceptance: the deprecated fixed-point formulation and
        the event-interleaved loop agree within tolerance when the closed
        loop paces uniformly (constant think, deterministic service)."""
        plan = self._plan(batched)
        eng = ServingEngine(plan)
        n_clients = 80 if batched else 4
        fe = FrontendConfig(clients=ClosedLoopClients(
            n_clients=n_clients, think_time=0.2 if batched else 0.05,
            think_dist="const", max_iters=8,
        ))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            fp = eng.run(400, 80.0 if batched else 100.0, frontend=fe)
        il = eng.run(400, 80.0 if batched else 100.0, frontend=fe, pipeline=True)
        assert il.offered == fp.offered == 400
        assert np.mean(il.e2e_latencies) == pytest.approx(
            np.mean(fp.e2e_latencies), rel=0.05
        )
        assert il.attainment == pytest.approx(fp.attainment, abs=0.05)

    def test_self_throttle_under_tiny_plan(self):
        """Few clients against a slow plan: the interleaved loop serves
        everything (offered load adapts to completions, quiescent partial
        batches flush causally)."""
        plan = self._plan(batched=True)
        eng = ServingEngine(plan)
        fe = FrontendConfig(clients=ClosedLoopClients(n_clients=4))
        res = eng.run(200, 80.0, frontend=fe, pipeline=True)
        assert res.offered == 200
        assert res.shed == 0 and res.dropped == 0
        assert res.attempts == 200

    def test_retry_and_admission_conserve_frames(self):
        plan = self._plan(batched=True)
        eng = ServingEngine(plan)
        fe = FrontendConfig(
            admission=TokenBucket(rate=40.0, burst=2.0),
            clients=ClosedLoopClients(
                n_clients=64, retry_on_shed=True, max_retries=2, backoff=0.01
            ),
        )
        res = eng.run(400, 80.0, frontend=fe, pipeline=True)
        assert len(res.e2e_latencies) + res.shed + res.dropped == 400
        assert res.attempts >= 400
        # the bucket is half the offered rate, so terminal denials must
        # happen — and with retry_on_shed every terminal denial follows a
        # re-offer, so it classifies as an exhausted-retry DROP (admitted
        # demand the system failed), never a first-sight shed
        assert res.dropped > 0
        assert res.shed == 0


# ------------------------------------------------- per-rank budget floor


class TestPerRankBudgetFloor:
    def _residual_plan(self):
        """Majority machine + dummy-filled residual (Theorem-2 shape): the
        residual's real collection rate is its own small share, so its
        honest fill time is far longer than the whole-module fill time the
        PR-1 floor used."""
        from repro.core.dispatch import Alloc
        from repro.core.residual import ModuleSchedule

        c = Config(32, 0.3)
        maj = Alloc(c, 1.0, c.throughput)
        res = Alloc(c, 1.0, 23.3, dummy=c.throughput - 23.3)
        s = ModuleSchedule("M", maj.rate + 23.3, 0.0, 1.0, (maj, res), Policy.TC)
        wl = Workload(AppDAG("app", Leaf("M")), {"M": s.rate}, 1.6)
        return Plan(wl, PlannerOptions(), {"M": s}, True, 0.0)

    def test_remaining_workloads_rank_structure(self):
        plan = self._residual_plan()
        s = plan.schedules["M"]
        allocs = list(s.allocs)
        w_of = remaining_workloads(allocs)
        machines = expand_machines(allocs)
        assert set(w_of) == {mm.mid for mm in machines}
        ws = [w_of[mm.mid] for mm in machines]
        # ranks are ratio-descending: remaining workload never increases
        assert all(a >= b - 1e-9 for a, b in zip(ws, ws[1:]))
        # the top rank collects at the whole module's real rate
        assert ws[0] == pytest.approx(sum(a.rate for a in allocs))
        # the dummy-filled residual ranks last and collects at its own
        # real share only
        assert ws[-1] == pytest.approx(23.3)

    def test_budget_floor_uses_remaining_workload(self):
        """The fill-time floor of a lower-ranked TC machine is its batch
        over the REMAINING workload w_i, not over the whole module rate."""
        plan = self._residual_plan()
        s = plan.schedules["M"]
        eng = ServingEngine(plan, policy=Policy.TC)
        machines = expand_machines(list(s.allocs))
        w = eng._module_timeout("M", machines, "budget")
        w_of = remaining_workloads(list(s.allocs))
        for mm in machines:
            fill = mm.config.batch / w_of[mm.mid]
            assert w[mm.mid] == pytest.approx(max(s.budget - mm.config.duration, fill))
        # the residual's floor is strictly longer than the whole-rate floor
        low = machines[-1]
        assert w_of[low.mid] < s.rate - 1e-9
        assert w[low.mid] == pytest.approx(32 / 23.3)
        assert w[low.mid] > max(s.budget - 0.3, 32 / s.rate) + 1e-9

    def test_floor_cuts_flush_waste_on_the_residual(self, monkeypatch):
        """Satellite acceptance: collecting at the remaining workload, the
        residual machine executes markedly fewer (fuller) batches than with
        the PR-1 whole-rate floor when traffic runs below provisioning —
        the flush-waste concentration the ROADMAP flagged — while staying
        within the SLO."""
        import repro.serving.engine as engine_mod

        plan = self._residual_plan()
        eng = ServingEngine(plan)
        rate = plan.schedules["M"].rate
        kw = dict(arrivals="poisson", timeout="budget", seed=2,
                  offered_rate=0.35 * rate)
        new = eng.run(1200, rate, **kw)
        # the PR-1 behavior: every TC machine floored at the module rate
        # (remaining_workloads defaulting to s.rate via the .get fallback)
        monkeypatch.setattr(engine_mod, "remaining_workloads", lambda allocs: {})
        old = eng.run(1200, rate, **kw)
        monkeypatch.undo()
        assert new.module_stats["M"].batches < old.module_stats["M"].batches
        assert new.attainment >= 0.98
        assert new.p99 <= plan.workload.slo + 1e-9


# ------------------------------------------------- DAG helper


class TestCriticalMasks:
    def test_series_par_decomposition(self):
        sp = series(Leaf("a"), par(Leaf("b"), Leaf("c")), Leaf("d"))
        soj = {
            "a": np.array([1.0, 1.0]),
            "b": np.array([2.0, 0.5]),
            "c": np.array([1.5, 3.0]),
            "d": np.array([0.5, 0.5]),
        }
        lat, masks = sp_critical_masks(sp, soj)
        np.testing.assert_allclose(lat, [3.5, 4.5])
        np.testing.assert_array_equal(masks["b"], [True, False])
        np.testing.assert_array_equal(masks["c"], [False, True])
        np.testing.assert_array_equal(masks["a"], [True, True])

    def test_nan_branches_lose(self):
        sp = par(Leaf("x"), Leaf("y"))
        soj = {"x": np.array([np.nan, 1.0]), "y": np.array([2.0, np.nan])}
        lat, masks = sp_critical_masks(sp, soj)
        np.testing.assert_allclose(lat, [2.0, 1.0])
        np.testing.assert_array_equal(masks["x"], [False, True])
        np.testing.assert_array_equal(masks["y"], [True, False])
