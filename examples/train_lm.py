"""Train a ~100M-param language model for a few hundred steps on CPU.

Uses the smollm-360m family at reduced width (real 32-layer depth-ish config
scaled to CPU budget) on the synthetic-but-learnable bigram stream; loss drops
well below the uniform baseline, exercising the full training substrate
(AdamW + schedule + clipping + checkpointing).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import math
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data import lm_batches
from repro.models import Model
from repro.profiling import param_count
from repro.training import OptConfig, restore, save, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint", default="/tmp/repro_lm.npz")
    args = ap.parse_args()

    # smollm family, sized for CPU: ~8 layers of the same architecture
    cfg = get_config("smollm-360m").replace(
        n_layers=8,
        d_model=384,
        n_heads=6,
        n_kv_heads=2,
        head_dim=64,
        d_ff=1024,
        vocab_size=2048,
        param_dtype="float32",
        compute_dtype="float32",
    )
    model = Model(cfg)
    n = param_count(cfg)
    print(f"arch family: smollm-360m (reduced) — {n/1e6:.1f}M params, "
          f"uniform CE = {math.log(cfg.vocab_size):.3f}")

    batches = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0)
    res = train(
        model,
        batches,
        steps=args.steps,
        opt_cfg=OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        log_every=max(1, args.steps // 20),
    )
    first, last = res.history[0]["loss"], res.history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"(uniform {math.log(cfg.vocab_size):.3f})")
    if args.checkpoint:
        save(args.checkpoint, res.params)
        restored = restore(args.checkpoint, res.params)
        print(f"checkpoint round-trip OK: {args.checkpoint}")


if __name__ == "__main__":
    main()
