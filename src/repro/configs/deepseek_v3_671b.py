"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP [arXiv:2412.19437]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,  # nominal; MLA caches the 576-wide latent instead
    d_ff=18432,  # dense layers (first 3); routed experts use d_ff_expert
    vocab_size=129280,
    source="arXiv:2412.19437",
    attn_kind="mla",
    head_dim=128,  # qk nope dim
    v_head_dim=128,
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    d_ff_expert=2048,
    n_dense_layers=3,
    moe_every=1,
    mtp_depth=1,
    rope_theta=10_000.0,
    max_seq_len=131_072,
    remat=True,
)

# reduced same-family variant for CPU smoke tests
SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    v_head_dim=32,
    q_lora_rank=64,
    kv_lora_rank=64,
    rope_head_dim=16,
    d_ff=512,
    d_ff_expert=128,
    n_experts=4,
    top_k=2,
    n_dense_layers=1,
    vocab_size=512,
    mtp_depth=1,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
