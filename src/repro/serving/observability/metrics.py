"""Low-overhead metrics registry: per-module counters, gauges, histograms.

The registry accumulates cheap scalar state per module while the serving
loop runs — integer counters (batches, close causes, backpressure parks),
running sums for means (batch occupancy, dummy fill), a busy-time
integrator for utilization, and small fixed-bucket histograms (queue depth
at batch close).  At every control-plane epoch boundary (and once at end of
run) the accumulators flush into one row per module per epoch; the rows
travel on ``ServeResult.metrics`` as a :class:`MetricsSnapshot`.

Everything here is plain Python arithmetic on a handful of attributes — no
numpy allocation per event — so the registry stays inside the tracing
overhead budget (the ``pipeline_speed`` smoke gate's <= 10%).
"""
from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass, field

# fixed queue-depth histogram buckets (instances waiting at batch close)
_DEPTH_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128, 256)


class _ModuleAcc:
    """One module's accumulators between two epoch flushes."""

    __slots__ = (
        "batches", "members", "phantoms", "slots", "parks", "busy",
        "closes", "depth_hist", "depth_n",
    )

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.batches = 0       # batches started
        self.members = 0       # members (real + phantom) across started batches
        self.phantoms = 0      # phantom members across started batches
        self.slots = 0         # capacity slots across started batches
        self.parks = 0         # deliveries parked by backpressure
        self.busy = 0.0        # seconds of machine service time
        self.closes = {}       # close cause -> count
        self.depth_hist = [0] * (len(_DEPTH_BUCKETS) + 1)
        self.depth_n = 0

    @property
    def empty(self) -> bool:
        return self.batches == 0 and self.parks == 0 and not self.closes


@dataclass
class MetricsSnapshot:
    """Flushed per-module-per-epoch metric rows (``ServeResult.metrics``)."""

    rows: list[dict] = field(default_factory=list)
    depth_buckets: tuple = _DEPTH_BUCKETS

    def for_module(self, module: str) -> list[dict]:
        return [r for r in self.rows if r["module"] == module]

    def table(self) -> str:
        """Aligned text table of the per-epoch rows (``serve.py --trace``)."""
        cols = (
            "epoch", "module", "t0", "t1", "batches", "occupancy",
            "dummy_fill", "stalls", "utilization", "duration_err",
        )
        lines = ["  ".join(f"{c:>12}" for c in cols)]
        for r in self.rows:
            cells = []
            for c in cols:
                v = r.get(c, 0.0)
                cells.append(
                    f"{v:>12.4f}" if isinstance(v, float) else f"{v:>12}"
                )
            lines.append("  ".join(cells))
        return "\n".join(lines)


class MetricsRegistry:
    """Accumulate per-module counters; flush one row per module per epoch."""

    __slots__ = ("_acc", "rows", "_t0", "_epoch")

    def __init__(self):
        self._acc: dict[str, _ModuleAcc] = {}
        self.rows: list[dict] = []
        self._t0 = 0.0
        self._epoch = 0

    def _mod(self, module: str) -> _ModuleAcc:
        acc = self._acc.get(module)
        if acc is None:
            acc = self._acc[module] = _ModuleAcc()
        return acc

    # -- hot-path accumulation ----------------------------------------------
    def batch(self, module: str, size: int, cap: int, n_phantom: int,
              dur: float) -> None:
        acc = self._mod(module)
        acc.batches += 1
        acc.members += size
        acc.phantoms += n_phantom
        acc.slots += cap
        acc.busy += dur

    def close(self, module: str, cause: str, depth: int) -> None:
        acc = self._mod(module)
        acc.closes[cause] = acc.closes.get(cause, 0) + 1
        acc.depth_hist[bisect_right(_DEPTH_BUCKETS, depth)] += 1
        acc.depth_n += 1

    def park(self, module: str) -> None:
        self._mod(module).parks += 1

    def add_busy(self, module: str, seconds: float) -> None:
        self._mod(module).busy += seconds

    # -- column-level accumulation (segment fast path / flat engine) --------
    def bulk(self, module: str, *, batches: int, members: int,
             phantoms: int, slots: int, busy: float) -> None:
        """Fold one vectorized module replay's aggregate into the epoch."""
        acc = self._mod(module)
        acc.batches += batches
        acc.members += members
        acc.phantoms += phantoms
        acc.slots += slots
        acc.busy += busy
        if batches:
            acc.closes["full"] = acc.closes.get("full", 0) + batches

    # -- epoch flush --------------------------------------------------------
    def flush(self, t1: float, machines_of: "dict[str, int]",
              duration_err: float = 0.0) -> None:
        """Close the accumulation window ``[t0, t1)`` into one row per
        module; ``machines_of`` maps module -> active machine count (the
        utilization denominator)."""
        span = max(t1 - self._t0, 0.0)
        for module, acc in sorted(self._acc.items()):
            if acc.empty:
                continue
            n_m = max(machines_of.get(module, 1), 1)
            members = max(acc.members, 1)
            row = {
                "epoch": self._epoch,
                "module": module,
                "t0": self._t0,
                "t1": t1,
                "batches": acc.batches,
                "occupancy": acc.members / max(acc.slots, 1),
                "dummy_fill": acc.phantoms / members,
                "stalls": acc.parks,
                "utilization": (
                    acc.busy / (n_m * span) if span > 0.0 else 0.0
                ),
                "duration_err": duration_err,
                "closes": dict(acc.closes),
                "queue_depth_hist": list(acc.depth_hist),
            }
            self.rows.append(row)
            acc.reset()
        self._t0 = t1
        self._epoch += 1

    def snapshot(self) -> MetricsSnapshot:
        return MetricsSnapshot(rows=self.rows)
