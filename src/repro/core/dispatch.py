"""Request dispatching: worst-case-latency (L_wc) models and the TC dispatcher.

Paper Sec. III-B.  Three dispatch policies:

* ``TC``  (Harpagon, Theorem 1): batched requests are handed to machines in
  descending throughput-cost-ratio order, so machine *i* collects its batch at
  its *remaining workload* rate ``w_i = sum_{r_j <= r_i} f_j``:
  ``L_wc(i) = d_i + b_i / w_i``.
* ``RR``  (Nexus/InferLine/Clipper): individual requests round-robin'ed; a
  full-capacity machine collects at its own throughput (``b/t = d``), giving
  ``L_wc = 2 d``; a partially-loaded machine (rate ``f < t``) collects at
  ``f``: ``L_wc = d + b / f``.
* ``DT``  (Scrooge): frontend forms batches and paces each machine at its
  configuration throughput, ``L_wc = d + b / t = 2 d`` for every machine
  (optimistic for partial machines; Table III row "Scrooge").

``dispatch_trace`` realizes TC/RR dispatching request-by-request; the
event-driven simulator (`repro.serving.simulator`) uses it to validate
Theorem 1 empirically.
"""
from __future__ import annotations

import enum
import math
from contextlib import contextmanager
from dataclasses import dataclass

import numpy as np

from .profiles import Config

_EPS = 1e-9

# per-planning-call memo for `config_wcl` (None = memoization off).  The
# planner's splitter cascade re-evaluates the same (config, policy, rate,
# full, burst) tuples many times — every cascade tier re-runs Algorithm 1,
# the dummy generator re-runs it once per allocation, and the reassigner
# loops over modules — so `Planner.plan`/`replan` wrap their bodies in
# `wcl_memo()` and the pure function amortizes to a dict hit.  Scoped to
# the call (not a global LRU) so the cache can never outlive the inputs
# that shaped it and costs nothing outside planning.
_WCL_MEMO: "dict | None" = None


@contextmanager
def wcl_memo():
    """Enable `config_wcl` memoization for the enclosed planning call.

    Re-entrant: a nested scope (e.g. ``replan`` falling back to ``plan``)
    keeps sharing the outermost cache.
    """
    global _WCL_MEMO
    outer = _WCL_MEMO
    if outer is None:
        _WCL_MEMO = {}
    try:
        yield
    finally:
        _WCL_MEMO = outer


class Policy(enum.Enum):
    TC = "tc"  # throughput-cost batched dispatch (Harpagon)
    RR = "rr"  # round-robin individual dispatch (Nexus/InferLine/Clipper)
    DT = "dt"  # machine-throughput-paced dispatch (Scrooge), sound on partials
    DT_OPT = "dt_opt"  # Table III "d + b/t" taken literally (Harp-dt ablation)


@dataclass(frozen=True)
class Alloc:
    """``machines`` (possibly fractional tail) running ``config``, serving ``rate`` req/s.

    ``dummy`` is phantom request rate injected by the frontend (dummy
    generator / dummy-filled residual): it raises the batch-collection rate
    (and the machine count paid for) without carrying real traffic.

    ``derate`` is the utilization-headroom factor the scheduler provisioned
    under: each machine is assigned only ``derate * throughput`` traffic, so
    its run period ``b / (derate * t) = d / derate`` leaves slack for
    timeout-flushed partial batches (``derate == 1`` = paper semantics, zero
    slack).  The invariant ``rate + dummy == machines * derate * throughput``
    holds for scheduler-produced allocations.
    """

    config: Config
    machines: float
    rate: float  # real request rate (machines * derate * throughput - dummy)
    dummy: float = 0.0
    derate: float = 1.0

    @property
    def cost(self) -> float:
        """Frame-rate-proportional cost: p * (f + dummy) / t == p * machines."""
        return self.config.unit_price * self.machines

    @property
    def full(self) -> bool:
        return self.machines >= 1.0 - 1e-12

    @property
    def cap(self) -> float:
        """Per-machine assigned capacity under headroom derating."""
        return self.config.throughput * self.derate

    @property
    def collect_rate(self) -> float:
        return self.rate + self.dummy

    @property
    def eff_ratio(self) -> float:
        """Dispatch rank: dummy-filled machines are always dispatched last
        (their padded stream feeds the collection of everything above)."""
        return -math.inf if self.dummy > _EPS else self.config.ratio

    def __repr__(self) -> str:
        dm = f"+{self.dummy:.3g}dum" if self.dummy else ""
        hr = f" util<={self.derate:.2g}" if self.derate < 1.0 - 1e-12 else ""
        return f"{self.rate:.6g}{dm} ({self.machines:.3g} x b{self.config.batch}@{self.config.hardware}{hr})"


@dataclass(frozen=True, eq=False)
class ConfigArrays:
    """Columnar (numpy) view of a configuration table.

    The batched WCL kernel (`config_wcl_batch`) evaluates Theorem 1 over a
    whole profile at once instead of one scalar `config_wcl` call per
    config.  ``throughput``/``ratio`` are materialized from the scalar
    `Config` properties so the array entries are the *same doubles* the
    scalar path computes — elementwise IEEE-754 arithmetic on them is then
    bit-identical to the scalar cascade.
    """

    configs: tuple[Config, ...]
    duration: np.ndarray
    batch: np.ndarray
    throughput: np.ndarray
    unit_price: np.ndarray
    ratio: np.ndarray

    @classmethod
    def build(cls, configs) -> "ConfigArrays":
        configs = tuple(configs)
        return cls(
            configs=configs,
            duration=np.array([c.duration for c in configs], dtype=np.float64),
            batch=np.array([float(c.batch) for c in configs], dtype=np.float64),
            throughput=np.array([c.throughput for c in configs], dtype=np.float64),
            unit_price=np.array([c.unit_price for c in configs], dtype=np.float64),
            ratio=np.array([c.ratio for c in configs], dtype=np.float64),
        )

    def __len__(self) -> int:
        return len(self.configs)

    def tail(self, k: int) -> "ConfigArrays":
        """View of configs[k:] (numpy slices are views — no copy)."""
        return ConfigArrays(
            self.configs[k:],
            self.duration[k:],
            self.batch[k:],
            self.throughput[k:],
            self.unit_price[k:],
            self.ratio[k:],
        )


# id-keyed ConfigArrays cache.  Keying by ``id(configs)`` skips re-hashing
# the config tuple on every lookup (a tuple hash walks every frozen
# dataclass); storing the tuple in the value keeps it alive, so its id can
# never be reused while the entry exists.
_ARRAYS_CACHE: "dict[int, tuple[tuple, ConfigArrays]]" = {}


def config_arrays(configs: "tuple[Config, ...]") -> ConfigArrays:
    """Cached columnar view of a profile's config tuple."""
    key = id(configs)
    hit = _ARRAYS_CACHE.get(key)
    if hit is not None and hit[0] is configs:
        return hit[1]
    arrs = ConfigArrays.build(configs)
    if len(_ARRAYS_CACHE) > 4096:
        _ARRAYS_CACHE.clear()
    _ARRAYS_CACHE[key] = (configs, arrs)
    return arrs


def config_wcl_batch(
    arrs: ConfigArrays,
    policy: Policy,
    *,
    collect_rate,
    full=True,
    burst: float = 0.0,
) -> np.ndarray:
    """Elementwise `config_wcl` over a whole config table in one call.

    ``collect_rate`` may be a scalar (one rate for every config) or an
    array (one rate per config); ``full`` likewise a bool or bool array.
    Branches mirror the scalar kernel exactly — same operations in the
    same order on the same doubles — so the result is bit-identical to
    calling `config_wcl` per row (the scalar path stays as the
    bit-exactness oracle behind ``PlannerOptions.vectorized=False``).
    """
    d, b = arrs.duration, arrs.batch
    if policy is Policy.DT_OPT:
        return d + b / arrs.throughput  # == 2d, optimistic on partials
    cr = collect_rate
    if isinstance(cr, np.ndarray):
        starved = cr <= _EPS
        gen = d + b / np.where(starved, 1.0, cr) + burst
        gen = np.where(starved, math.inf, gen)
    elif cr <= _EPS:
        gen = np.full_like(d, math.inf)
    else:
        gen = d + b / cr + burst
    if policy in (Policy.RR, Policy.DT):
        if full is True:
            return 2.0 * d  # RR: local collection at own throughput; DT: d + b/t
        if full is False:
            return gen
        return np.where(full, 2.0 * d, gen)
    return gen  # TC: Theorem 1 at the remaining workload


def total_cost(allocs: list[Alloc]) -> float:
    return sum(a.cost for a in allocs)


def total_rate(allocs: list[Alloc]) -> float:
    return sum(a.rate for a in allocs)


def collect_capacity(allocs: list[Alloc]) -> float:
    """Provisioned batch-collection capacity ``sum(machines * derate * t)``.

    For scheduler-produced allocations this equals ``sum(rate + dummy)`` —
    the total traffic (real + streamed phantom) the machines are paid to
    collect.  The control plane's replan reuses a module whose new rate
    still fits under this capacity: the dummy share absorbs the drift.
    """
    return sum(a.machines * a.cap for a in allocs)


def config_wcl(
    config: Config, policy: Policy, *, collect_rate: float, full: bool = True,
    burst: float = 0.0,
) -> float:
    """Worst-case latency of ONE machine at ``config``.

    ``collect_rate`` is the rate at which this machine's batch fills up:
    * TC: the remaining workload ``w`` (Theorem 1),
    * RR full machine: its own throughput; RR partial: its assigned rate,
    * DT: its own throughput always.

    ``burst`` is a burst-aware collection correction (seconds): downstream
    of a batched stage, arrivals come quantized in upstream batch
    completions, so any machine whose batch waits on arrivals can straddle
    an inter-completion gap of up to one upstream batch's arrival quantum
    ``b_up / rate_up`` beyond the steady-state ``b / w`` fill time —
    `scheduler.get_wcl` applies it to full and tail machines alike (a full
    machine with a short fill time straddles the gap just the same); the
    RR/DT ``2d`` short-circuit below skips it, so that caller adds it
    explicitly.
    """
    memo = _WCL_MEMO
    if memo is not None:
        key = (config, policy, collect_rate, full, burst)
        hit = memo.get(key)
        if hit is not None:
            return hit
    d, b = config.duration, config.batch
    if policy is Policy.DT_OPT:
        out = d + b / config.throughput  # == 2d, optimistic on partials
    elif policy in (Policy.RR, Policy.DT) and full:
        out = 2.0 * d  # RR: local collection at own throughput; DT: d + b/t
    elif collect_rate <= _EPS:
        out = math.inf
    else:
        out = d + b / collect_rate + burst
    if memo is not None:
        memo[key] = out
    return out


def module_wcl(allocs: list[Alloc], policy: Policy) -> float:
    """Worst-case latency of a module = max over its machines (Theorem 1)."""
    if not allocs:
        return 0.0
    worst = 0.0
    for a in allocs:
        if a.rate <= _EPS:
            continue
        if policy is Policy.TC:
            # remaining workload: every alloc ranked at-or-below this one
            # (dummy traffic counts towards batch collection; dummy-filled
            # machines rank last)
            w = sum(
                x.collect_rate
                for x in allocs
                if x.eff_ratio <= a.eff_ratio + _EPS
            )
            if a.dummy > _EPS:
                w = max(w, a.collect_rate)
            lat = config_wcl(a.config, policy, collect_rate=w)
        elif policy in (Policy.RR, Policy.DT):
            # the tail machine of a fractional alloc collects at its own rate
            frac = a.machines - math.floor(a.machines)
            if a.derate < 1.0 - 1e-12:
                # headroom-derated machine: collects at its assigned capacity
                lat = config_wcl(a.config, policy, collect_rate=a.cap, full=False)
            else:
                lat = config_wcl(a.config, policy, collect_rate=a.config.throughput)
            if frac > 1e-12:
                tail_rate = frac * a.cap + a.dummy
                lat = max(
                    lat,
                    config_wcl(
                        a.config, policy, collect_rate=tail_rate, full=False
                    ),
                )
        else:  # DT_OPT: d + b/t for every machine
            lat = config_wcl(a.config, policy, collect_rate=a.config.throughput)
        worst = max(worst, lat)
    return worst


# ---------------------------------------------------------------------------
# Request-level dispatch traces (ground truth for the event simulator).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Machine:
    """A concrete machine instance in a dispatch plan."""

    mid: int
    config: Config
    rate: float  # assigned request rate (== throughput if at full capacity)


def machine_fractions(allocs: list[Alloc]) -> list[tuple[Alloc, float]]:
    """The single machine enumerator: ``(owning alloc, capacity fraction)``
    per machine id, ratio-descending, full machines first, fractional tail
    last.  Everything that needs a per-machine-id view of an allocation set
    (`expand_machines`, `remaining_workloads`, the tenancy layer's
    device-centric plan view) derives from this walk so the id
    correspondence is structural, not re-implemented."""
    out: list[tuple[Alloc, float]] = []
    for a in sorted(allocs, key=lambda x: -x.eff_ratio):
        n_full = math.floor(a.machines + 1e-12)
        out.extend((a, 1.0) for _ in range(n_full))
        frac = a.machines - n_full
        if frac > 1e-9:
            out.append((a, frac))
    return out


def expand_machines(allocs: list[Alloc]) -> list[Machine]:
    """Expand allocations to individual machines, ratio-descending order.

    Each machine's assigned rate is the alloc's per-machine capacity
    ``derate * throughput`` (== throughput without headroom); the fractional
    tail machine carries the fractional share of that capacity.
    """
    return [
        Machine(mid, a.config, frac * a.cap)
        for mid, (a, frac) in enumerate(machine_fractions(allocs))
    ]


def remaining_workloads(allocs: list[Alloc]) -> dict[int, float]:
    """Per-machine-id remaining REAL workload ``w_i`` under TC ranking.

    Theorem 1: the machines of allocation *a* collect their batches at the
    total rate of traffic dispatched at-or-below *a*'s rank — not at the
    whole module rate.  Machine ids match `expand_machines` (both derive
    from `machine_fractions`).  Only real rates count: the caller is the
    ``timeout="budget"`` fill-time floor for plans whose dummy traffic is
    *not* streamed, where phantoms cannot help fill a batch.
    """
    return {
        mid: sum(x.rate for x in allocs if x.eff_ratio <= a.eff_ratio + _EPS)
        for mid, (a, _frac) in enumerate(machine_fractions(allocs))
    }


def dispatch_runs(
    machines: list[Machine], n_requests: int, policy: Policy
) -> list[tuple[int, int]]:
    """Assign requests to machines as run-length pairs ``[(machine_id, count)]``.

    Runs cover request ids 0..n-1 consecutively; this is the compact form of
    ``dispatch_trace`` (one entry per batch under TC instead of one per
    request), which the vectorized replay kernel expands with ``np.repeat``.

    TC: consecutive runs of ``batch`` requests per machine, walking machines in
    throughput-cost order (machines of equal ratio take turns batch-by-batch).
    RR: individual requests round-robin, weighted by assigned rate (each
    machine receives requests at a rate equal to its share of the workload).
    """
    runs: list[tuple[int, int]] = []
    if n_requests <= 0 or not machines:
        return runs
    if policy is Policy.TC:
        # Weighted fair batch scheduling: machine i receives one batch every
        # b_i / f_i time units; ties are broken by throughput-cost ratio
        # (matching Fig. 4: req1-6 -> A, req7-12 -> B, req13-16 -> C).
        # The greedy min-walk over (next_t, -ratio, index) is equivalent to
        # merge-sorting every machine's periodic run slots k * b_i / f_i by
        # that same key, which vectorizes: O(batches log batches) in numpy
        # instead of O(batches * machines) in Python — this is on the
        # simulator hot path for 10^6-request replays.
        periods = np.array([m.config.batch / m.rate for m in machines])
        batches = np.array([m.config.batch for m in machines], dtype=np.int64)
        ratios = np.array([m.config.ratio for m in machines])
        mids = np.array([m.mid for m in machines], dtype=np.int64)
        # horizon: coverage(v) = sum_i b_i * (floor(v / p_i) + 1) >= v * T,
        # so slots up to v_n = n / sum(rates) always cover n requests
        v_n = n_requests / sum(m.rate for m in machines)
        counts = (np.floor(v_n / periods).astype(np.int64) + 1)
        midx = np.repeat(np.arange(len(machines)), counts)
        k = np.arange(midx.size) - np.repeat(
            np.concatenate([[0], np.cumsum(counts)[:-1]]), counts
        )
        slot_t = k * periods[midx]
        order = np.lexsort((midx, -ratios[midx], slot_t))
        sizes = batches[midx[order]]
        cum = np.cumsum(sizes)
        n_runs = int(np.searchsorted(cum, n_requests, side="left")) + 1
        run_mids = mids[midx[order[:n_runs]]]
        run_sizes = sizes[:n_runs].copy()
        run_sizes[-1] -= int(cum[n_runs - 1]) - n_requests
        return [(int(a), int(b)) for a, b in zip(run_mids, run_sizes)]
    # RR / DT: weighted round-robin of individual requests (deficit counter).
    credit = [0.0] * len(machines)
    tot = sum(m.rate for m in machines)
    prev_mid, count = -1, 0
    for _ in range(n_requests):
        for i, m in enumerate(machines):
            credit[i] += m.rate / tot
        # give the request to the machine with the largest credit
        j = max(range(len(machines)), key=lambda i: credit[i])
        credit[j] -= 1.0
        mid = machines[j].mid
        if mid == prev_mid:
            count += 1
        else:
            if count:
                runs.append((prev_mid, count))
            prev_mid, count = mid, 1
    if count:
        runs.append((prev_mid, count))
    return runs


def dispatch_trace(
    machines: list[Machine], n_requests: int, policy: Policy
) -> list[tuple[int, int]]:
    """Assign request ids 0..n-1 to machines: returns [(req_id, machine_id)].

    Per-request expansion of ``dispatch_runs`` (see there for the policy
    semantics); kept for compatibility and the trace-shape property tests.
    """
    out: list[tuple[int, int]] = []
    rid = 0
    for mid, count in dispatch_runs(machines, n_requests, policy):
        for _ in range(count):
            out.append((rid, mid))
            rid += 1
    return out
