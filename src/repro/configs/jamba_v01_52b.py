"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887]."""
from .base import ArchConfig

# 8-layer macro-block: attention at position 4, Mamba elsewhere (1:7);
# MoE replaces the MLP on every other layer (odd indices).
_PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    source="arXiv:2403.19887",
    hybrid_pattern=_PATTERN,
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    moe_every=2,
    d_state=16,
    d_conv=4,
    ssm_expand=2,
    max_seq_len=262_144,
    remat=True,
)

SMOKE = CONFIG.replace(
    n_layers=8,  # one full macro-block
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    d_ff_expert=256,
    n_experts=4,
    top_k=2,
    vocab_size=512,
    d_state=8,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
