"""Vectorized per-machine batch-replay kernel — the simulator hot path.

Replays the same batch-formation/service semantics as the event-driven core
(`repro.serving.events`) in O(batches) numpy work instead of a per-event
Python loop, so replaying 10^6 requests across the 1131-workload suite takes
seconds.  The two key identities:

* batch boundaries under a deadline are *usually* the plain ``batch``-sized
  reshape — one vectorized check confirms no deadline fires mid-stream and
  falls back to a per-batch greedy scan (still O(batches)) when traffic is
  bursty enough that it does;
* the FIFO service chain ``end_g = max(ready_g, end_{g-1}) + d`` runs as one
  short loop per *batch* in exactly the event core's operation order, so the
  kernel's finish times are BIT-identical to the event-driven cores (the
  prefix-max closed form is the same value only to float association) —
  which is what lets the pipelined co-simulation's segment fast-path
  (`repro.serving.pipeline.fastpath`) delegate to this kernel without
  perturbing a single bit.

Property tests (tests/test_event_core.py) pin this kernel to the event core,
and golden tests pin both to the frozen seed loops in
`repro.serving.reference` on uniform arrivals.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from ..core.dispatch import Machine
from .events import simulate_module_events


@dataclass
class ModuleReplay:
    """Result of replaying one module over a request stream."""

    finish: np.ndarray  # absolute completion time per request (NaN = dropped)
    assignment: np.ndarray  # serving machine id per request
    batches: dict[int, int]  # executed batches per machine
    phantom: np.ndarray | None = None  # frontend dummy-request mask (None = none)

    @property
    def done(self) -> np.ndarray:
        return ~np.isnan(self.finish)

    @property
    def real(self) -> np.ndarray:
        """Mask of real (non-phantom) requests — the only ones stats count."""
        if self.phantom is None:
            return np.ones(self.finish.size, dtype=bool)
        return ~self.phantom

    @property
    def n_batches(self) -> int:
        return sum(self.batches.values())


def runs_to_assignment(runs: Sequence[tuple[int, int]], n: int) -> np.ndarray:
    """Expand ``dispatch_runs`` run-length pairs to a per-request mid array."""
    if not runs:
        return np.zeros(0, dtype=np.int64)
    mids = np.fromiter((mid for mid, _ in runs), np.int64, len(runs))
    counts = np.fromiter((c for _, c in runs), np.int64, len(runs))
    out = np.repeat(mids, counts)
    if out.size != n:
        raise ValueError(f"runs cover {out.size} requests, expected {n}")
    return out


def _batch_bounds(
    ready: np.ndarray,
    batch: int,
    timeout: float | None,
    tail: str,
    phantom: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Group a machine's sorted ready times into batches.

    Returns ``(sizes, g_ready)``: per-batch request counts (consecutive,
    starting at request 0; a dropped tail is simply not covered) and the time
    each batch is handed to the machine.

    ``phantom`` marks frontend dummy requests.  They fill batch slots like
    real traffic, but a flush deadline is armed only by the batch's first
    *real* request (the deadline exists to bound real latency), and a
    leftover batch containing only phantoms is discarded at end of stream
    instead of executed (the frontend stops injecting when the stream ends).
    """
    n = ready.size
    has_phantom = phantom is not None and bool(phantom.any())
    if timeout is None:
        n_full, tail_sz = divmod(n, batch)
        flush_tail = bool(tail_sz) and tail == "flush"
        if flush_tail and has_phantom and bool(phantom[n_full * batch:].all()):
            flush_tail = False  # phantom-only tail: nothing real to flush for
        ng = n_full + (1 if flush_tail else 0)
        if ng == 0:
            return np.zeros(0, np.int64), np.zeros(0)
        last = np.minimum(np.arange(1, ng + 1) * batch, n) - 1
        sizes = np.diff(np.concatenate([[0], last + 1]))
        g_ready = ready[last]
        if flush_tail and has_phantom:
            # the end-of-stream flush happens at the tail's last REAL arrival
            # (the frontend stops injecting once the stream ends) — trailing
            # phantoms must not inflate real tail latency
            tail_real = np.flatnonzero(~phantom[n_full * batch:])
            g_ready = g_ready.astype(np.float64, copy=True)
            g_ready[-1] = ready[n_full * batch + tail_real[-1]]
        return sizes, g_ready
    if has_phantom:
        # greedy scan with real-opener deadlines (phantom streams are rare
        # and short — engine runs — so the O(batches) loop is fine)
        real_idx = np.flatnonzero(~phantom)
        sizes_l: list[int] = []
        gr_l: list[float] = []
        i = 0
        ri = 0
        while i < n:
            while ri < real_idx.size and real_idx[ri] < i:
                ri += 1
            if ri >= real_idx.size:
                # only phantoms remain: full batches still close by fill
                # (the machine cannot know), the partial remainder is never
                # time-flushed and drops at end of stream
                while i + batch <= n:
                    sizes_l.append(batch)
                    gr_l.append(float(ready[i + batch - 1]))
                    i += batch
                break
            deadline = float(ready[real_idx[ri]]) + timeout
            j = i + batch
            j_dl = int(np.searchsorted(ready, deadline, side="right"))
            if j <= j_dl:  # fills before the first real request's deadline
                r = float(ready[j - 1])
            else:
                j = j_dl
                r = deadline
            sizes_l.append(j - i)
            gr_l.append(r)
            i = j
        return np.asarray(sizes_l, np.int64), np.asarray(gr_l)
    # deadline semantics: tentative reshape boundaries are valid iff every
    # group's opener deadline covers the group's last member (and the tail's
    # covers the end of stream)
    nb = math.ceil(n / batch)
    starts = np.arange(nb) * batch
    ends = np.minimum(starts + batch, n)
    if np.all(ready[ends - 1] <= ready[starts] + timeout):
        g_ready = ready[ends - 1].astype(np.float64, copy=True)
        if ends[-1] - starts[-1] < batch:  # partial tail flushes at deadline
            g_ready[-1] = ready[starts[-1]] + timeout
        return ends - starts, g_ready
    # bursty fallback: greedy scan, one iteration per *batch* (not request)
    sizes_l = []
    gr_l = []
    i = 0
    while i < n:
        deadline = ready[i] + timeout
        j = i + batch
        j_dl = int(np.searchsorted(ready, deadline, side="right"))
        if j <= j_dl:  # fills before the deadline
            r = float(ready[j - 1])
        else:  # deadline flush: everything arrived by then (>= the opener)
            j = j_dl
            r = deadline
        sizes_l.append(j - i)
        gr_l.append(r)
        i = j
    return np.asarray(sizes_l, np.int64), np.asarray(gr_l)


def replay_machine(
    ready: np.ndarray,
    batch: int,
    duration: float,
    *,
    timeout: float | None = None,
    tail: str = "flush",
    phantom: np.ndarray | None = None,
) -> tuple[np.ndarray, int]:
    """Replay one machine; returns ``(finish, n_batches)``.

    ``ready`` must be sorted.  ``finish[i]`` is the absolute completion time
    of request ``i`` (NaN when the tail is dropped).  ``phantom`` marks
    frontend dummy requests (see `_batch_bounds` for their semantics).
    """
    if tail not in ("flush", "drop"):
        raise ValueError(f"unknown tail policy {tail!r}")
    ready = np.asarray(ready, dtype=np.float64)
    n = ready.size
    finish = np.full(n, np.nan)
    if n == 0:
        return finish, 0
    sizes, g_ready = _batch_bounds(ready, batch, timeout, tail, phantom)
    ng = sizes.size
    if ng == 0:
        return finish, 0
    # FIFO service chain: end_g = max(ready_g, end_{g-1}) + d, evaluated
    # with exactly the event core's operation order so the kernel is
    # BIT-identical to `simulate_module_events` (and to the pipelined
    # co-simulation's MachineCore chain), not merely equal to ~1e-15 — the
    # prefix-max closed form `d*(g+1) + cummax(ready_g - d*g)` is the same
    # number algebraically but associates the additions differently.  One
    # Python iteration per *batch* keeps this O(n / batch), a rounding
    # error on the kernel's total runtime.
    end_l: list[float] = []
    append = end_l.append
    prev = -math.inf
    for r in g_ready.tolist():
        if prev > r:
            r = prev
        prev = r + duration
        append(prev)
    end = np.asarray(end_l)
    covered = int(sizes.sum())
    finish[:covered] = np.repeat(end, sizes)
    return finish, ng


def replay_module(
    machines: Sequence[Machine],
    ready: np.ndarray,
    runs: Sequence[tuple[int, int]],
    *,
    timeout: "float | None | Mapping[int, float]" = None,
    tail: str = "flush",
    method: str = "vectorized",
    phantom: np.ndarray | None = None,
) -> ModuleReplay:
    """Replay one module's machines over a sorted request-ready stream.

    ``runs`` is the dispatcher's run-length assignment (`dispatch_runs`).
    ``timeout`` may be one deadline for all machines or a per-machine-id
    mapping (machines with longer service need shorter collection windows to
    meet the same budget).  ``method="events"`` routes through the reference
    event core instead of the vectorized kernel (identical results; used for
    cross-validation and whenever real executors are involved).  ``phantom``
    marks frontend dummy requests: they fill batch slots but never arm flush
    deadlines or force end-of-stream flushes, and callers exclude them from
    latency statistics via ``ModuleReplay.real``.
    """
    ready = np.asarray(ready, dtype=np.float64)
    n = ready.size
    assignment = runs_to_assignment(runs, n)
    if phantom is not None:
        phantom = np.asarray(phantom, dtype=bool)
        if phantom.shape != ready.shape:
            raise ValueError("phantom mask must match the request stream")
    if method == "events":
        finish, batches = simulate_module_events(
            machines, ready, assignment, timeout=timeout, tail=tail, phantom=phantom
        )
        return ModuleReplay(finish, assignment, batches, phantom)
    if method != "vectorized":
        raise ValueError(f"unknown method {method!r}")
    finish = np.full(n, np.nan)
    batches: dict[int, int] = {}
    # one stable argsort groups requests by machine while preserving arrival
    # order within each group (much cheaper than a per-machine == scan)
    order = np.argsort(assignment, kind="stable")
    sorted_mid = assignment[order]
    for m in machines:
        lo = int(np.searchsorted(sorted_mid, m.mid, side="left"))
        hi = int(np.searchsorted(sorted_mid, m.mid, side="right"))
        if lo == hi:
            batches[m.mid] = 0
            continue
        idx = order[lo:hi]
        w = timeout.get(m.mid) if isinstance(timeout, Mapping) else timeout
        f, nb = replay_machine(
            ready[idx], m.config.batch, m.config.duration, timeout=w, tail=tail,
            phantom=None if phantom is None else phantom[idx],
        )
        finish[idx] = f
        batches[m.mid] = nb
    return ModuleReplay(finish, assignment, batches, phantom)


def fanout_counts(n: int, fanout: float) -> np.ndarray:
    """Per-position instance counts of the seed fractional accumulator.

    Position ``i`` (0-based, in stream order) contributes
    ``floor(S_i) - floor(S_{i-1})`` instances where ``S_i = fanout *
    (i+1)``.  Fanouts that are multiples of 0.5 (every seed app) are exact
    in binary floating point, so the vectorized floor-difference is
    bit-identical to the accumulator loop; other fanouts take the loop to
    preserve its exact rounding drift (`pipeline.fanout.AccumulatorFanout`
    realizes the same semantics one frame at a time).
    """
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    if float(2.0 * fanout).is_integer():
        cum = np.floor(fanout * np.arange(1, n + 1))
        return np.diff(np.concatenate([[0.0], cum])).astype(np.int64)
    counts_l = []
    acc = 0.0
    for _ in range(n):
        acc += fanout
        k = int(acc)
        acc -= k
        counts_l.append(k)
    return np.asarray(counts_l, np.int64)


def expand_fanout(frames: np.ndarray, fanout: float) -> np.ndarray:
    """Expand ready-ordered frame ids into module-level request instances
    (see `fanout_counts` for the accumulator semantics)."""
    if frames.size == 0:
        return frames[:0]
    return np.repeat(frames, fanout_counts(frames.size, fanout))
