"""Composable DAG stages: one module's machines behind a bounded ingress.

A :class:`ModuleStage` wraps the single-machine cores of
`repro.serving.events.MachineCore` into one DAG stage: an *incremental*
dispatcher assigns instances to machines in arrival order (the streaming
form of `core.dispatch.dispatch_runs` — the static run-length walk cannot be
precomputed because the pipelined arrival stream only exists as the
co-simulation unfolds), formation buffers fill/flush exactly like the
single-module reference core, and a bounded ingress backlog exerts
**backpressure**: when ``queue_cap`` instances are already waiting to start
service, further deliveries park FIFO and the *upstream machine that
produced them stays busy* until the stage drains — the cross-stage
interference Harpagon's per-module WCL sums cannot see.

The stage owns no event loop; `repro.serving.pipeline.core` drives every
stage of the app DAG from one global heap.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Callable, Mapping, Sequence

from ...core.dispatch import Machine, Policy
from ..events import MachineCore


class Instance:
    """One module-level request of one frame (``frame == -1``: phantom)."""

    __slots__ = ("frame", "ready")

    def __init__(self, frame: int, ready: float = 0.0):
        self.frame = frame
        self.ready = ready

    @property
    def real(self) -> bool:
        return self.frame >= 0


class TCDispatcher:
    """Incremental weighted-fair batch walk (Harpagon TC dispatch).

    Machine *i* owns periodic run slots at ``k * b_i / f_i`` merged by
    ``(slot time, -ratio, index)``; consecutive arrivals fill the current
    run (one batch) before the walk advances — request-for-request identical
    to `core.dispatch.dispatch_runs(policy=TC)` on the same stream.

    :meth:`update` swaps the machine set *without restarting the walk*
    (control-plane hot swap): kept machines keep their virtual-time slot
    positions and the open run keeps filling, so a partially-formed batch
    is never stranded; added machines join at the walk's current frontier
    (`dispatch.remaining_workloads` semantics — a new machine starts
    collecting its slice of the stream immediately).
    """

    def __init__(self, machines: Sequence[Machine]):
        self.machines = list(machines)
        self._next_t = {m.mid: 0.0 for m in machines}
        self._cur: "int | None" = None  # mid of the machine with an open run
        self._left = 0

    def assign(self) -> int:
        if self._left == 0:
            i = min(
                range(len(self.machines)),
                key=lambda j: (
                    self._next_t[self.machines[j].mid],
                    -self.machines[j].config.ratio,
                    j,
                ),
            )
            m = self.machines[i]
            self._cur = m.mid
            self._left = m.config.batch
            self._next_t[m.mid] += m.config.batch / m.rate
        self._left -= 1
        return self._cur

    def assign_run(self, count: int) -> "list[tuple[int, int]]":
        """Assign ``count`` consecutive arrivals in one walk advance.

        Returns run-length pairs ``[(mid, k)]`` — exactly the machines the
        scalar :meth:`assign` would have produced for ``count`` successive
        calls, but advancing the virtual-time walk run-by-run instead of
        request-by-request (the macro-event form of the TC walk: one
        ``min()`` per *batch*, not per instance)."""
        runs: list[tuple[int, int]] = []
        while count > 0:
            if self._left == 0:
                i = min(
                    range(len(self.machines)),
                    key=lambda j: (
                        self._next_t[self.machines[j].mid],
                        -self.machines[j].config.ratio,
                        j,
                    ),
                )
                m = self.machines[i]
                self._cur = m.mid
                self._left = m.config.batch
                self._next_t[m.mid] += m.config.batch / m.rate
            k = self._left if self._left < count else count
            self._left -= k
            count -= k
            if runs and runs[-1][0] == self._cur:
                runs[-1] = (self._cur, runs[-1][1] + k)
            else:
                runs.append((self._cur, k))
        return runs

    def update(self, machines: Sequence[Machine]) -> None:
        old = self._next_t
        self.machines = list(machines)
        frontier = min(
            (old[m.mid] for m in machines if m.mid in old), default=0.0
        )
        self._next_t = {m.mid: old.get(m.mid, frontier) for m in machines}
        if self._cur is not None and self._cur not in self._next_t:
            self._left = 0  # the open run's machine drained: abandon the run


class RRDispatcher:
    """Deficit-counter weighted round-robin of individual requests (RR/DT),
    request-for-request identical to `dispatch_runs` under those policies.
    :meth:`update` preserves kept machines' deficit credits across a swap."""

    def __init__(self, machines: Sequence[Machine]):
        self.machines = list(machines)
        self._credit = {m.mid: 0.0 for m in machines}
        self._tot = sum(m.rate for m in self.machines)

    def assign(self) -> int:
        for m in self.machines:
            self._credit[m.mid] += m.rate / self._tot
        j = max(range(len(self.machines)), key=lambda i: self._credit[self.machines[i].mid])
        mid = self.machines[j].mid
        self._credit[mid] -= 1.0
        return mid

    def assign_run(self, count: int) -> "list[tuple[int, int]]":
        """Deficit walk for ``count`` arrivals, merged into run-length pairs
        (scalar-identical; RR interleaves, so runs are usually length 1)."""
        runs: list[tuple[int, int]] = []
        for _ in range(count):
            mid = self.assign()
            if runs and runs[-1][0] == mid:
                runs[-1] = (mid, runs[-1][1] + 1)
            else:
                runs.append((mid, 1))
        return runs

    def update(self, machines: Sequence[Machine]) -> None:
        old = self._credit
        self.machines = list(machines)
        self._credit = {m.mid: old.get(m.mid, 0.0) for m in machines}
        self._tot = sum(m.rate for m in self.machines)


def make_dispatcher(machines: Sequence[Machine], policy: Policy):
    if policy is Policy.TC:
        return TCDispatcher(machines)
    return RRDispatcher(machines)


@dataclass
class StageStats:
    """Per-stage accounting, mirror of the engine's ``ModuleStats`` fields."""

    latencies: list[float] = field(default_factory=list)
    batches: int = 0
    dropped: int = 0
    phantom: int = 0


@dataclass
class StageUpdate:
    """One stage's share of a plan hot-swap (control-plane epoch).

    ``machines`` is the *target* machine set of the new schedule (mids as
    produced by ``expand_machines`` — the stage remaps them onto its own
    stable core ids); ``timeout`` is keyed by those same mids.
    ``phantom_target`` is the new provisioned collect rate for the adaptive
    dummy streamer (0 = stop streaming).
    """

    machines: Sequence[Machine]
    timeout: "float | None | Mapping[int, float]" = None
    phantom_target: float = 0.0


class ModuleStage:
    """One DAG module as a pipeline stage: dispatcher + cores + backlog.

    ``timeout`` is a single flush deadline or a per-machine-id mapping (the
    engine's ``"budget"`` resolution).  ``phantom_target`` > 0 streams the
    plan's priced phantom traffic *adaptively*: the stage pads batch
    formation up to that total collect rate (``sum(rate + dummy)``), so a
    phantom is injected only when real traffic has left a gap — the
    event-interleaved analogue of the flat frontend's pad-to-provisioned
    injector (`frontend.dummy.phantom_times`).  ``queue_cap`` bounds the
    number of instances waiting to start service; ``None`` means unbounded
    (no backpressure — the flat-engine regime).
    """

    def __init__(
        self,
        name: str,
        machines: Sequence[Machine],
        policy: Policy,
        *,
        timeout: "float | None | Mapping[int, float]" = None,
        fanout=None,
        phantom_target: float = 0.0,
        queue_cap: "int | None" = None,
        service_time=None,
        service_obs: "Callable | None" = None,
    ):
        if queue_cap is not None and queue_cap < 1:
            raise ValueError("queue_cap must be >= 1 (or None for unbounded)")
        self._req_queue_cap = queue_cap  # as requested, pre-floor (re-floored on swap)
        if queue_cap is not None:
            # formation buffers count toward the backlog, so a cap below the
            # largest batch size could never form a full batch: floor it
            queue_cap = max(queue_cap, max(m.config.batch for m in machines))
        if isinstance(timeout, Mapping):
            t_of = {m.mid: timeout.get(m.mid) for m in machines}
        else:
            t_of = {m.mid: timeout for m in machines}
        self.name = name
        self.machines = list(machines)
        self.policy = policy  # the segment fast-path re-derives dispatch_runs
        self.cores = {m.mid: MachineCore(m, t_of[m.mid]) for m in machines}
        self._next_mid = max((m.mid for m in machines), default=-1) + 1
        self.dispatcher = make_dispatcher(machines, policy)
        self.fanout = fanout
        self.phantom_target = float(phantom_target)
        # phantom pacing state: a phantom is due when `delivered` (real +
        # phantom arrivals since `anchor`) falls behind target * elapsed —
        # total collection is padded up to, and rate-limited at, the target
        self.anchor = 0.0
        self.delivered = 0
        # True while the injection chain is dormant (stage was full): a
        # dormant chain schedules no events, so a wedged pipeline can reach
        # quiescence and flush; the next successful delivery revives it
        self.phantom_paused = False
        # bumped when a hot-swap re-anchors the streamer: pending chain
        # events carry the token they were pushed under and die if stale,
        # so a swap can restart the chain without double-injecting
        self.phantom_token = 0
        self.queue_cap = queue_cap
        # batch service durations: None takes the profiled constant (the
        # bit-exact default); a `serving.service_time.ServiceTimeSource`
        # supplies trace/live wall-clock durations at every batch start.
        # ``service_obs(module, machine, duration, now)`` — when set — sees
        # each started batch's actual duration (the control plane's
        # model-vs-measured estimator feed).
        self.service_time = service_time
        self.service_obs = service_obs
        # observability (`repro.serving.observability`): ``obs`` is the
        # optional hook sink (None = hook-free hot path), ``flushed_col``
        # the FrameTable's always-on partial-flush forensic column — both
        # wired by `pipeline.core.run_pipeline`
        self.obs = None
        self.flushed_col = None
        # fault wiring (`repro.serving.faults`, all None/False without an
        # injector — the hooks are never consulted on the fault-free path):
        # ``watchdog(name, mid, core, now)`` arms a detection heartbeat at
        # every batch close; ``keep_spare`` holds the most-recently-drained
        # machine idle-warm one epoch as failover insurance
        self.watchdog = None
        self.keep_spare = False
        self._spare: "int | None" = None
        self.backlog = 0  # instances delivered but not yet started service
        # deliveries parked by backpressure: (instance, blocker) where
        # blocker is the (stage, mid) whose outputs they are, or None for
        # ingress arrivals (open-loop frames waiting at the source)
        self.parked: deque = deque()
        self.in_service: dict[int, list[Instance]] = {}
        self.stats = StageStats()

    # -- capacity ------------------------------------------------------------
    @property
    def has_space(self) -> bool:
        return self.queue_cap is None or self.backlog < self.queue_cap

    @property
    def service_backlog(self) -> bool:
        """True when closed batches are queued behind a busy machine.

        The phantom injector checks this: a real frontend fills *otherwise
        idle* batch slots, so while real work is already waiting for service
        the stage must spend its capacity burning that backlog down, not
        serving phantoms — otherwise provisioning slack (a control loop's
        ``margin``) could never drain a transient queue.
        """
        return any(c.queue for c in self.cores.values())

    # -- control-plane hot swap ----------------------------------------------
    def apply_update(self, upd: StageUpdate, now: float, push: Callable) -> None:
        """Apply one epoch's plan delta to the live stage.

        Per configuration, existing cores are kept up to the new machine
        count (work-holding cores first — a draining core of the right
        configuration is revived rather than duplicated); surplus cores are
        marked draining: their open batch closes *now* (flushes with its
        real members; a phantom-only buffer is discarded), already-queued
        batches run to completion, and no new members are dispatched to
        them.  Added machines get fresh stage-local ids and join the
        dispatch walk immediately.  The dispatcher is rebuilt over the new
        active set (the TC walk restarts ratio-aligned), and the dummy
        streamer re-anchors to the new provisioned collect rate.
        """
        if isinstance(upd.timeout, Mapping):
            t_of = {m.mid: upd.timeout.get(m.mid) for m in upd.machines}
        else:
            t_of = {m.mid: upd.timeout for m in upd.machines}

        by_cfg: dict = {}
        for mid, core in self.cores.items():
            by_cfg.setdefault(core.machine.config, []).append(core)
        new_by_cfg: dict = {}
        for m in upd.machines:
            new_by_cfg.setdefault(m.config, []).append(m)

        active: list[Machine] = []
        claimed: set[int] = set()
        for cfg, new_ms in new_by_cfg.items():
            pool = by_cfg.get(cfg, [])
            # keep work-holding cores first; revive draining cores before
            # creating duplicates (their queued work rejoins the same rank)
            # a fenced dead core is never revived — a replacement gets a
            # fresh id (or promotes the warm spare)
            pool = sorted(
                (c for c in pool if not c.failed),
                key=lambda c: (c.draining, c.drained),
            )
            for nm in new_ms:
                if pool:
                    core = pool.pop(0)
                    mid = core.machine.mid
                    if mid == self._spare:
                        # warm-spare promotion: the idle-warm machine
                        # rejoins dispatch instead of a cold add
                        self._spare = None
                        if self.obs is not None:
                            self.obs.promote_spare(now, self.name, mid)
                else:
                    mid = self._next_mid
                    self._next_mid += 1
                    core = MachineCore(_dc_replace(nm, mid=mid), None)
                    self.cores[mid] = core
                machine = _dc_replace(nm, mid=mid)
                core.machine = machine
                core.timeout = t_of.get(nm.mid)
                core.draining = False
                claimed.add(mid)
                active.append(machine)
        for mid, core in self.cores.items():
            if mid in claimed or core.draining:
                continue
            core.draining = True
            if self.obs is not None:
                self.obs.drain(now, self.name, mid)
            if core.buf:
                # drained machines finish their open batch: it closes now
                # (partial) and their queued work runs to completion; a
                # phantom-only buffer is discarded — nothing real is lost
                if any(i.real for i in core.buf):
                    self.close(mid, batch_ready=now, now=now, push=push, cause="drain")
                else:
                    self.discard_leftover(mid)
        # retire cores that finished draining: they hold no work and no
        # live event references them (a busy core cannot be drained; stale
        # flush events tolerate a missing mid), so keeping them would grow
        # the stage without bound across epochs and slow every hot-path
        # scan (service_backlog, quiescence) proportionally to run length
        retire = [
            mid for mid, c in self.cores.items()
            if mid not in claimed and c.draining and c.drained
        ]
        if self.keep_spare:
            # keep the most-recently-drained healthy retiree idle-warm for
            # one epoch (failover insurance — ROADMAP's lazily-drained warm
            # machine); last epoch's spare, if still unclaimed, retires now
            prev = self._spare
            self._spare = None
            cand = [m for m in retire if not self.cores[m].failed and m != prev]
            if cand:
                self._spare = max(cand)
                retire.remove(self._spare)
        for mid in retire:
            del self.cores[mid]
            self.in_service.pop(mid, None)

        self.machines = active
        # the walk continues across the swap: kept machines keep their slot
        # positions (their open formation buffers keep filling — no batch is
        # stranded), added machines join at the frontier
        self.dispatcher.update(active)
        if self._req_queue_cap is not None:
            self.queue_cap = max(
                self._req_queue_cap,
                max((m.config.batch for m in active), default=1),
            )

        target = float(upd.phantom_target)
        retarget = abs(target - self.phantom_target) > 1e-12
        self.phantom_target = target
        if retarget:
            # re-anchor the dummy streamer to the new provisioned rate:
            # paid-up through now, old chain events die on the stale token
            self.phantom_token += 1
            self.phantom_paused = False
            if target > 0.0:
                period = 1.0 / target
                self.anchor = now - self.delivered * period
                push(
                    now + period, _K_ARRIVE, None,
                    ("phantom", self.name, self.phantom_token),
                )

    def retime(
        self,
        timeout: "float | None | Mapping[int, float]",
        now: float,
        push: Callable,
    ) -> None:
        """Swap every active core's flush deadline in place (the control
        plane's mid-epoch deadline relaxation).

        Unlike :meth:`apply_update` this touches no machines and closes no
        batches: each core's open formation buffer keeps its members and its
        arming instant, only the deadline is re-anchored — a pending flush
        dies on the bumped token and the replacement fires at
        ``max(armed_at + new_timeout, now)`` (an already-overdue deadline
        under the *longer* new timeout flushes immediately, never in the
        past).  Draining cores are left alone: their open batch was already
        closed at the drain instant.
        """
        if isinstance(timeout, Mapping):
            t_of = {m.mid: timeout.get(m.mid) for m in self.machines}
        else:
            t_of = {m.mid: timeout for m in self.machines}
        for machine in self.machines:
            mid = machine.mid
            core = self.cores[mid]
            if core.draining:
                continue
            deadline = core.retime(t_of.get(mid))
            if deadline is not None:
                push(max(deadline, now), _K_FLUSH, self.name, (mid, core.token))

    # -- formation / service -------------------------------------------------
    def deliver(self, inst: Instance, now: float, push: Callable) -> None:
        """Hand one instance to the dispatcher at time ``now``.

        ``push(t, kind, stage_name, payload)`` schedules flush/free events on
        the owner's heap.  Caller must have checked :attr:`has_space`.
        """
        inst.ready = now
        self.delivered += 1
        self.backlog += 1
        mid = self.dispatcher.assign()
        core = self.cores[mid]
        deadline = core.add(inst, now, inst.real)
        if deadline is not None:
            push(deadline, _K_FLUSH, self.name, (mid, core.token))
        if core.full:
            self.close(mid, batch_ready=now, now=now, push=push)

    def deliver_run(self, frame: int, count: int, now: float, push: Callable) -> None:
        """Hand ``count`` same-instant REAL instances of ``frame`` to the
        dispatcher in one macro-event.

        Scalar-identical to ``count`` successive :meth:`deliver` calls when
        the stage is unbounded (``queue_cap is None``), has nothing parked,
        and streams no phantoms — the caller gates on exactly those
        conditions.  The dispatcher advances run-by-run (one walk step per
        batch) and each run's members join the formation buffer as a block:
        the buffer fills/closes at the same member boundaries, the flush
        deadline arms on the same (first real) member at the same instant,
        and frees are pushed in the same order, so every downstream event
        carries the same ``(t, kind, seq)`` key as the scalar path."""
        self.delivered += count
        self.backlog += count
        for mid, k in self.dispatcher.assign_run(count):
            core = self.cores[mid]
            buf = core.buf
            batch = core.machine.config.batch
            while k > 0:
                take = batch - len(buf)
                if take > k:
                    take = k
                if not core.armed and core.timeout is not None:
                    core.armed = True
                    core.armed_at = now
                    push(now + core.timeout, _K_FLUSH, self.name, (mid, core.token))
                buf.extend(Instance(frame, now) for _ in range(take))
                k -= take
                if len(buf) >= batch:
                    self.close(mid, batch_ready=now, now=now, push=push)
                    buf = core.buf  # close swapped in a fresh buffer

    def close(
        self, mid: int, batch_ready: float, now: float, push: Callable,
        cause: str = "full",
    ) -> None:
        """Close ``mid``'s formation buffer (``cause``: why — ``"full"`` for
        a filled batch, ``"deadline"`` / ``"eos"`` / ``"drain"`` for partial
        flushes).  A partial flush marks its real members in the forensic
        ``flushed`` column: their service burned unfilled slots."""
        core = self.cores[mid]
        if cause != "full":
            col = self.flushed_col
            if col is not None:
                for i in core.buf:
                    if i.frame >= 0:
                        col[i.frame] = True
        if self.obs is not None:
            self.obs.batch_close(
                now, self.name, mid, len(core.buf), cause, self.backlog
            )
        core.close(batch_ready)
        if self.watchdog is not None:
            # detection heartbeat: the batch must complete within k x its
            # modeled service or the machine escalates suspect -> dead.
            # Armed even for a silently-crashed core — that is exactly the
            # batch whose missed heartbeat reveals the crash.
            self.watchdog(self.name, mid, core, now)
        self.start_next(mid, now, push)

    def start_next(self, mid: int, now: float, push: Callable) -> bool:
        """Start the next queued batch on ``mid`` (unless backpressured)."""
        core = self.cores[mid]
        src, obs = self.service_time, self.service_obs
        if src is None and obs is None:
            started = core.start(now, lambda members: core.machine.config.duration)
        else:
            drawn: list[float] = []

            def _dur(members) -> float:
                d = (
                    core.machine.config.duration
                    if src is None
                    else src.duration(self.name, core.machine, len(members))
                )
                drawn.append(d)
                return d

            started = core.start(now, _dur)
        if started is None:
            return False
        end, members = started
        if obs is not None and drawn:
            obs(self.name, core.machine, drawn[0], now)
        self.stats.batches += 1
        self.backlog -= len(members)
        self.in_service[mid] = members
        tel = self.obs
        if tel is not None:
            d = (
                drawn[0]
                if (src is not None or obs is not None) and drawn
                else core.machine.config.duration
            )
            tel.batch_start(
                self.name, mid, end - d, d, len(members),
                core.machine.config.batch,
                sum(1 for i in members if i.frame < 0),
            )
            tel.queue_depth(now, self.name, self.backlog)
        push(end, _K_FREE, self.name, (mid,))
        return True

    def fail_machine(self, mid: int, now: float) -> "list[Instance]":
        """Declare machine ``mid`` dead and reclaim its unfinished work.

        Fences the core (`MachineCore.fail`), removes the machine from the
        dispatch walk, and returns the REAL instances the owner must
        re-queue to surviving siblings: the batch in service (reclaimed
        from ``in_service`` — its pending free event is fenced off by the
        ``failed`` flag), the closed batches queued behind it, and the
        open formation buffer.  Phantom members are simply dropped (dummy
        traffic is priced, not conserved).  The fenced core stays in
        ``cores`` so stale flush/free events die cleanly; the next plan
        hot-swap retires it.

        Bookkeeping: queued/buffered members leave the backlog here and
        re-enter it on re-delivery; ``delivered`` rolls back for every
        surrendered member so the phantom pacing anchor does not count
        the same instance twice.
        """
        core = self.cores.get(mid)
        if core is None:
            return []  # fully retired: nothing left to reclaim
        # The machine may already be out of the dispatch walk (an epoch swap
        # retired the silently-crashed core before the watchdog's verdict) —
        # its stranded members are reclaimed all the same.  Idempotence is
        # the caller's job (`FaultRuntime.dead`): a second call would find
        # the buffers already emptied and reclaim nothing, but must not
        # re-roll the bookkeeping.
        in_flight = list(self.in_service.pop(mid, ()))
        members = in_flight + core.fail()
        self.backlog -= len(members) - len(in_flight)
        self.delivered -= len(members)
        self.machines = [m for m in self.machines if m.mid != mid]
        self.dispatcher.update(self.machines)
        return [i for i in members if i.real]

    def discard_leftover(self, mid: int) -> list[Instance]:
        """End-of-stream drop of the open buffer; returns real instances."""
        all_members = self.cores[mid].discard()
        self.backlog -= len(all_members)
        dropped = [i for i in all_members if i.real]
        self.stats.dropped += len(dropped)
        return dropped


# event kinds of the pipeline's global heap (core.py re-exports): arrivals
# first (a request landing exactly at a deadline joins the batch), then
# machine-frees (upstream completions must deliver before a downstream flush
# at the same instant fires), then flushes, then control-plane epochs (a
# swap observes everything that happened up to and including its instant).
# FREE-before-FLUSH within one stage is outcome-equivalent to the
# single-module core's FLUSH-before-FREE (both orders start the same FIFO
# batch at the same time).  Faults sort last: a batch completing exactly at
# a crash instant completes, and a detection verdict at an epoch boundary
# sees the post-swap stage.
_K_ARRIVE, _K_FREE, _K_FLUSH, _K_EPOCH, _K_FAULT = 0, 1, 2, 3, 4
