from .apps import APPS, app_by_name
from .synth import synth_profiles, synth_workloads

__all__ = ["APPS", "app_by_name", "synth_profiles", "synth_workloads"]
