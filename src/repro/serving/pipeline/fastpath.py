"""Segment fast-path: delegate a quiescent co-simulation to the flat kernel.

PR 3 established (property tests over all apps × arrival processes,
including ``timeout="budget"``) that the pipelined event loop and the flat
engine's vectorized per-module replay agree whenever queues are unbounded
and fanout is deterministic.  This module is that theorem turned into a
cache: when a segment of the run is *quiescent of everything only the
event loop can express* —

* open-loop issue times (no closed-loop clients),
* no admission shedding against live state,
* no control epochs (no machine-set hot-swaps mid-segment),
* every stage unbounded (``queue_cap is None``, no backpressure),
* deterministic accumulator fanout (`fanout.AccumulatorFanout`),
* no adaptive phantom streaming (``phantom_target == 0``)

— the whole segment replays in O(batches) numpy work per machine on the
vectorized kernel (`repro.serving.replay`), filling the same
`result.FrameTable` columns the event loop would have produced, with
finish times BIT-identical to the event cores (the kernel's FIFO chain
evaluates in their operation order).  Every eligibility condition above is
run-constant, so the quiescent segment is always the *entire* run and the
event-loop re-entry point is the end of stream.

**The causal boundary.**  One construct is acausal in the flat replay:
the end-of-stream tail flush with ``timeout=None`` closes a partial batch
at its last member's ready time — *backdating* service into the past,
because the flat engine knows module-by-module that the stream has ended.
The event loop only learns that once everything else has drained, so its
tail flushes (and their downstream cascades) happen strictly after all
normal events.  The two orders coincide exactly when every
quiescence-derived arrival sorts after the normal arrivals it joins — true
for almost every stream length, but a backdated tail on one branch of a
join CAN slot earlier than a sibling's normal completions.  The fast path
tracks a conservative *quiescence depth* per frame (0 = normal, k = fed by
a k-deep tail-flush cascade) and demands the depth sequence be
non-decreasing along every module's flat-order arrival stream — the exact
condition under which the event loop's ``[normal, then tail-cascade]``
delivery order equals the flat stable ready-sort.  On violation it
returns ``None`` untouched (per-stage stats are committed only on
success) and `core.run_pipeline` falls through to the macro-event general
loop, whose causal semantics are the ground truth.

Speed: ~20-40x over the event-by-event loop at 10^4-10^6 frames on the
suite apps (see ``benchmarks.run --only pipeline_speed``), which is what
makes control-plane and SLO sweeps at the ROADMAP's million-frame scale
tractable.
"""
from __future__ import annotations

from typing import Mapping

import numpy as np

from ...core.dag import AppDAG
from ...core.dispatch import dispatch_runs
from ..replay import fanout_counts, replay_module, runs_to_assignment
from .fanout import AccumulatorFanout
from .result import FrameTable, PipelineResult
from .stages import ModuleStage


def eligible(dag: AppDAG, stages: Mapping[str, ModuleStage]) -> bool:
    """Stage-side fast-path eligibility (caller already checked that the
    run is open-loop with no admission and no control plane)."""
    return all(
        st.queue_cap is None
        and st.phantom_target <= 0.0
        and isinstance(st.fanout, AccumulatorFanout)
        for st in stages.values()
    )


def run_flat_segment(
    dag: AppDAG,
    stages: Mapping[str, ModuleStage],
    n_frames: int,
    issue: np.ndarray,
    tail: str,
) -> "PipelineResult | None":
    """Replay one quiescent segment (the whole eligible run) vectorized.

    Module-by-module in topological order — the flat engine's schedule,
    which the PR-3 ordering argument showed delivers every frame to every
    stage at the same instant and in the same arrival order as the global
    event loop.  Per-frame records land in the same `FrameTable` columns
    the event loop fills, so the returned `PipelineResult` is
    indistinguishable from the general path's.

    Returns ``None`` — with no observable side effects — when the
    quiescence-depth monotonicity check detects a backdated tail flush
    interleaving a join's arrival stream (see module docstring): the
    caller then runs the event loop, whose causal order is authoritative.
    """
    topo = dag.topo_order()
    torder = {m: i for i, m in enumerate(topo)}
    parents = {m: sorted(dag.parents(m), key=torder.__getitem__) for m in topo}
    children = {m: sorted(dag.children(m), key=torder.__getitem__) for m in topo}
    sinks = [m for m in topo if not children[m]]
    ancestors = dag.ancestor_closure()

    ft = FrameTable(n_frames, topo, parents, len(sinks))
    ft.issue[:] = issue
    # ``bad[m][f]``: frame f produced no completion at m — voided by a bad
    # parent, skipped by a zero instance count, or every instance dropped
    # (the event loop's stage_resolved(done=False) propagation, columnar)
    bad = {m: np.zeros(n_frames, dtype=bool) for m in topo}
    # quiescence depth of f's completion at m: 0 = produced by the normal
    # event phase, r >= 1 = produced in (the cascade of) the r-th
    # quiescence flush round — the event loop flushes every
    # ancestors-drained stage per round, so round r's completions (and
    # their fill-cascades) all causally precede round r+1's
    depth = {m: np.zeros(n_frames, dtype=np.int64) for m in topo}
    # the round in which m's own acausal tail (timeout None, flushed
    # partial) fires: one past the last round an ancestor still held work
    tail_round: dict[str, int] = {}
    stats_buf: list = []  # committed only on success: bail must be effect-free

    for m in topo:
        st = stages[m]
        if parents[m]:
            pf = np.stack([ft.finish[p] for p in parents[m]])
            voided = np.isnan(pf).any(axis=0)
            ready = pf.max(axis=0)  # NaN only where voided (excluded below)
            in_depth = np.max(
                np.stack([depth[p] for p in parents[m]]), axis=0
            )
        else:
            voided = np.zeros(n_frames, dtype=bool)
            ready = ft.issue
            in_depth = np.zeros(n_frames, dtype=np.int64)
        bad[m] |= voided
        # stage arrival order: time-ordered, frame id breaking ties — the
        # order the event loop's (t, seq) heap + (topo, frame) same-instant
        # delivery sort realizes
        order = np.argsort(ready, kind="stable")
        alive = order[~voided[order]]
        # causal-boundary check: the event loop delivers normal arrivals in
        # ready order and tail-cascade arrivals strictly after, by depth —
        # equal to this flat stream iff depth is monotone along it
        d_seq = in_depth[alive]
        if d_seq.size and np.any(np.diff(d_seq) < 0):
            return None
        counts = fanout_counts(alive.size, st.fanout.phi)
        taken = counts > 0
        entered = alive[taken]
        ft.avail[m][entered] = ready[entered]
        bad[m][alive[~taken]] = True  # zero-fanout skip: vacuously resolved

        instances = np.repeat(alive, counts)
        if instances.size == 0:
            tail_round[m] = 0
            continue
        ready_inst = ready[instances]
        machines = st.machines
        timeout = {mm.mid: st.cores[mm.mid].timeout for mm in machines}
        runs = dispatch_runs(machines, instances.size, st.policy)
        rep = replay_module(machines, ready_inst, runs, timeout=timeout, tail=tail)
        done = rep.done
        # per-frame finish = max over the frame's completed instances
        # (partial completion proceeds with the instances that did finish)
        fmax = np.full(n_frames, -np.inf)
        np.maximum.at(fmax, instances[done], rep.finish[done])
        has_done = fmax > -np.inf
        ft.finish[m][has_done] = fmax[has_done]
        had = np.zeros(n_frames, dtype=bool)
        had[entered] = True
        lost_here = had & ~has_done
        ft.lost |= lost_here
        bad[m] |= lost_here

        # propagate quiescence depth: FIFO service serializes a machine's
        # stream, so a completion inherits the running max of its machine's
        # arrival rounds; an end-of-stream flushed partial tail (timeout
        # None) fires in this stage's own quiescence round — one past the
        # last round any ancestor still held work
        inst_depth = in_depth[instances]
        assignment = runs_to_assignment(runs, instances.size)
        sizes_by_mid = np.bincount(
            assignment, minlength=max(mm.mid for mm in machines) + 1
        )
        has_tail = tail == "flush" and any(
            timeout[mm.mid] is None
            and int(sizes_by_mid[mm.mid]) % mm.config.batch
            for mm in machines
        )
        tail_round[m] = (
            1 + max(
                (tail_round[a] for a in ancestors[m] if tail_round.get(a)),
                default=0,
            )
            if has_tail
            else 0
        )
        sorder = np.argsort(assignment, kind="stable")
        sorted_mid = assignment[sorder]
        out_inst = np.zeros(instances.size, dtype=np.int64)
        for mm in machines:
            lo = int(np.searchsorted(sorted_mid, mm.mid, side="left"))
            hi = int(np.searchsorted(sorted_mid, mm.mid, side="right"))
            if lo == hi:
                continue
            idx = sorder[lo:hi]
            serial = np.maximum.accumulate(inst_depth[idx])
            n_m = idx.size
            rem = n_m % mm.config.batch
            if rem and timeout[mm.mid] is None and tail == "flush":
                serial[n_m - rem:] = np.maximum(serial[n_m - rem:], tail_round[m])
            out_inst[idx] = serial
        dep_m = depth[m]
        np.maximum.at(dep_m, instances, out_inst)

        ss = st.stats
        n_done = int(done.sum())
        stats_buf.append((
            ss, rep.n_batches, instances.size - n_done,
            (rep.finish[done] - ready_inst[done]).tolist(),
        ))

    for ss, n_batches, n_dropped, lats in stats_buf:
        ss.batches += n_batches
        ss.dropped += n_dropped
        ss.latencies.extend(lats)

    sink_finish = np.stack([ft.finish[s] for s in sinks])
    ok = ~np.isnan(sink_finish).any(axis=0)
    ft.e2e[ok] = sink_finish.max(axis=0)[ok] - ft.issue[ok]
    ft.resolved[:] = True  # every frame is accounted: done, skipped, or lost
    return ft.finalize(dag, {m: stages[m].stats for m in topo}, attempts=0)
