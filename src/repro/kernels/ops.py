"""Dispatching wrappers around the Pallas kernels.

Models call these; on TPU (or with ``REPRO_FORCE_PALLAS=interpret``) they run
the Pallas kernels, otherwise the pure-jnp oracles in `ref`.  This keeps the
model code identical across CPU validation and TPU deployment.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import os
from dataclasses import dataclass

import jax

from . import ref


@functools.cache
def _mode() -> str:
    forced = os.environ.get("REPRO_FORCE_PALLAS", "")
    if forced in ("interpret", "tpu"):
        return forced
    return "tpu" if jax.default_backend() == "tpu" else "ref"


@dataclass(frozen=True)
class MeshCtx:
    """Trace-time mesh context: lets ops shard_map themselves explicitly
    (attention is embarrassingly parallel over batch x heads, so wrapping it
    in shard_map guarantees ZERO collectives, where GSPMD propagation around
    a chunked scan can otherwise reshard the KV stream)."""

    mesh: object
    dp_axes: tuple[str, ...]
    model_axis: str
    dp_size: int
    model_size: int
    # True when the arch's attention heads divide the TP degree: the Megatron
    # constraint/row-parallel pattern only helps aligned models — forcing it
    # on unaligned ones (12 heads over TP=16) makes GSPMD reshard constantly.
    aligned: bool = True


_MESH_CTX: contextvars.ContextVar[MeshCtx | None] = contextvars.ContextVar(
    "repro_mesh_ctx", default=None
)


@contextlib.contextmanager
def mesh_context(ctx: MeshCtx | None):
    token = _MESH_CTX.set(ctx)
    try:
        yield
    finally:
        _MESH_CTX.reset(token)


def constrain_activations(x):
    """Pin the canonical residual-stream sharding P(dp, None, ..., None).

    Without this, GSPMD propagates downstream layouts (e.g. the MoE's
    256-way flat-token sharding) BACKWARD through residual adds into wide
    attention intermediates and materializes full-replica gathers.
    """
    ctx = _MESH_CTX.get()
    if ctx is None or not ctx.aligned:
        return x
    from jax.sharding import PartitionSpec as P

    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    spec = P(*([dp] + [None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_hidden(x):
    """Pin Megatron-style hidden sharding P(dp, ..., 'model') on the last dim
    (FFN hidden, attention head outputs).  Forces GSPMD into the row-parallel
    partial-sum + all-reduce pattern instead of gathering the full hidden."""
    ctx = _MESH_CTX.get()
    if ctx is None or not ctx.aligned:
        return x
    from jax.sharding import PartitionSpec as P

    if x.shape[-1] % ctx.model_size != 0 or x.shape[0] % ctx.dp_size != 0:
        return x
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    spec = P(*([dp] + [None] * (x.ndim - 2) + [ctx.model_axis]))
    return jax.lax.with_sharding_constraint(x, spec)


def row_parallel_dense(x, w):
    """Megatron row-parallel projection: x (..., f_sharded) @ w (f_sharded, d)
    -> psum over 'model'.  Explicit shard_map because the GSPMD cost model
    otherwise all-gathers the (much larger) hidden activation instead of
    all-reducing the small output."""
    ctx = _MESH_CTX.get()
    f = w.shape[-2]
    if (
        ctx is None
        or not ctx.aligned
        or f % ctx.model_size != 0
        or x.shape[0] % ctx.dp_size != 0
        or x.shape[-1] != f
    ):
        return x @ w.astype(x.dtype)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    x_spec = P(*([dp] + [None] * (x.ndim - 2) + [ctx.model_axis]))
    w_spec = P(*([None] * (w.ndim - 2) + [ctx.model_axis, None]))
    out_spec = P(*([dp] + [None] * (x.ndim - 1)))

    def body(xx, ww):
        return jax.lax.psum(xx @ ww.astype(xx.dtype), ctx.model_axis)

    return shard_map(
        body, mesh=ctx.mesh, in_specs=(x_spec, w_spec), out_specs=out_spec,
        check_rep=False,
    )(x, w)


def _shardable_attn(ctx: MeshCtx | None, q, k) -> bool:
    if ctx is None:
        return False
    B, _, Hq, _ = q.shape
    Hkv = k.shape[2]
    # MQA/low-kv archs replicate KV across model ranks inside the shard_map;
    # each rank's local query heads must still form whole KV groups
    kv_ok = Hkv % ctx.model_size == 0 or (
        Hq % ctx.model_size == 0 and (Hq // ctx.model_size) % Hkv == 0
    )
    return B % ctx.dp_size == 0 and Hq % ctx.model_size == 0 and kv_ok


def _sharded_attention(ctx: MeshCtx, q, k, v, *, causal, window, scale):
    """shard_map over (batch -> dp, heads -> model): fully local attention.

    When KV heads do not divide the model axis (MQA), KV is replicated across
    model ranks and each rank serves its local query-head group.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    q_spec = P(dp, None, ctx.model_axis, None)
    kv_sharded = k.shape[2] % ctx.model_size == 0
    kv_spec = q_spec if kv_sharded else P(dp, None, None, None)

    def body(qq, kk, vv):
        # with replicated KV the local query-head group size is Hq_loc / Hkv
        if kk.shape[1] >= 8192 and kk.shape[1] % 1024 == 0:
            return ref.attention_chunked(
                qq, kk, vv, causal=causal, window=window, scale=scale
            )
        return ref.attention(qq, kk, vv, causal=causal, window=window, scale=scale)

    fn = shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(q_spec, kv_spec, kv_spec),
        out_specs=q_spec,
        check_rep=False,
    )
    return fn(q, k, v)


def attention(q, k, v, *, causal=True, window=None, scale=None, q_offset=0, kv_len=None):
    mode = _mode()
    if mode != "ref" and kv_len is None and q.shape[1] % 128 == 0:
        from .flash_attention import flash_attention

        return flash_attention(
            q,
            k,
            v,
            causal=causal,
            window=window,
            scale=scale,
            interpret=mode == "interpret",
        )
    ctx = _MESH_CTX.get()
    if kv_len is None and q_offset == 0 and _shardable_attn(ctx, q, k):
        return _sharded_attention(ctx, q, k, v, causal=causal, window=window, scale=scale)
    # Long sequences WITHOUT a mesh: chunked online-softmax (never materialize
    # S^2 logits).  Under GSPMD (ctx set but heads not shardable) the chunked
    # scan makes the partitioner replicate the KV stream per step — the plain
    # einsum form partitions far better there (see EXPERIMENTS.md SecPerf A.1).
    if (
        ctx is None
        and kv_len is None
        and q_offset == 0
        and k.shape[1] >= 8192
        and k.shape[1] % 1024 == 0
    ):
        return ref.attention_chunked(
            q, k, v, causal=causal, window=window, scale=scale
        )
    return ref.attention(
        q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset, kv_len=kv_len
    )


def mla_prefill_attention(q_nope, q_rope, k_nope, kr, v, *, scale):
    """MLA naive-form prefill attention with the head-concat INSIDE the
    shard_map boundary: q = [q_nope ; q_rope], k = [k_nope ; broadcast(kr)].

    Keeping the concatenation of the per-head (sharded) and shared-rope
    (replicated) halves inside per-device code stops GSPMD from gathering
    full-head tensors every layer.
    """
    import jax.numpy as jnp

    B, S, H, dn = q_nope.shape
    dr = q_rope.shape[-1]

    def body(qn, qr, kn, krr, vv):
        k = jnp.concatenate(
            [kn, jnp.broadcast_to(krr[:, :, None], (*kn.shape[:3], dr))], -1
        )
        q = jnp.concatenate([qn, qr], -1)
        if k.shape[1] >= 8192 and k.shape[1] % 1024 == 0:
            return ref.attention_chunked(q, k, vv, causal=True, scale=scale)
        return ref.attention(q, k, vv, causal=True, scale=scale)

    ctx = _MESH_CTX.get()
    if ctx is not None and B % ctx.dp_size == 0 and H % ctx.model_size == 0:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
        hspec = P(dp, None, ctx.model_axis, None)
        fn = shard_map(
            body,
            mesh=ctx.mesh,
            in_specs=(hspec, hspec, hspec, P(dp, None, None), hspec),
            out_specs=hspec,
            check_rep=False,
        )
        return fn(q_nope, q_rope, k_nope, kr, v)
    return body(q_nope, q_rope, k_nope, kr, v)


def decode_attention(q, k_cache, v_cache, lengths, *, window=None, scale=None):
    mode = _mode()
    if mode != "ref" and k_cache.shape[1] % 128 == 0:
        from .decode_attention import flash_decode

        return flash_decode(
            q,
            k_cache,
            v_cache,
            lengths,
            window=window,
            scale=scale,
            interpret=mode == "interpret",
        )
    return ref.decode_attention(q, k_cache, v_cache, lengths, window=window, scale=scale)


def rmsnorm(x, w, *, eps=1e-6, gemma=False):
    mode = _mode()
    if mode != "ref" and x.shape[-1] % 128 == 0:
        from .rmsnorm import fused_rmsnorm

        return fused_rmsnorm(x, w, eps=eps, gemma=gemma, interpret=mode == "interpret")
    return ref.rmsnorm(x, w, eps=eps, gemma=gemma)


def selective_scan(x, dt, A, Bm, Cm, h0=None):
    mode = _mode()
    if mode != "ref" and x.shape[1] % 128 == 0:
        from .ssm_scan import chunked_selective_scan

        return chunked_selective_scan(x, dt, A, Bm, Cm, h0, interpret=mode == "interpret")
    return ref.selective_scan(x, dt, A, Bm, Cm, h0)


def mlstm(q, k, v, i_gate, f_gate, *, chunk=128):
    mode = _mode()
    if mode != "ref" and q.shape[1] % chunk == 0:
        from .mlstm_chunk import chunked_mlstm

        return chunked_mlstm(
            q, k, v, i_gate, f_gate, chunk=chunk, interpret=mode == "interpret"
        )
    return ref.mlstm_chunked(q, k, v, i_gate, f_gate, chunk=chunk)
