"""ServiceTimeSource backends and the control plane's profile correction
(ISSUE-6): the simulator-to-serving bridge.

Covers: the analytic backend's bit-exactness (source unset vs an explicit
`AnalyticServiceTime` — flat and pipelined), trace-backend determinism under
a fixed seed (and divergence from analytic once samples differ), the trace
key ladder ((module, batch, hardware) before (module, batch) before module),
live-backend measurement/caching/`to_trace` freezing, `resolve_service_time`
spec normalization, and `ControlRuntime` correction convergence — a
1.3x-miscalibrated profile's model-vs-measured `duration_err` collapses
within two epochs once replans run against the corrected profiles.
"""
import numpy as np
import pytest

from repro.core import Planner
from repro.core.dispatch import Config, Machine
from repro.serving import (
    AnalyticServiceTime,
    ControlLoopConfig,
    FrontendConfig,
    LiveServiceTime,
    ServingEngine,
    TraceServiceTime,
    resolve_service_time,
)
from repro.workloads import synth_profiles
from repro.workloads.apps import app_by_name, make_workload

PROFILES = synth_profiles()


def _face_plan(rate=150.0, slo=2.5):
    wl = make_workload(app_by_name("face"), rate, slo)
    plan = Planner().plan(wl, PROFILES)
    assert plan.feasible
    return plan


def _machine(module="m", batch=8, duration=0.05, hardware="tpu-v4"):
    cfg = Config(batch=batch, duration=duration, hardware=hardware)
    return Machine(mid=0, config=cfg, rate=1.0)


class TestAnalyticBitExact:
    """service_time=None and an explicit analytic source are the same run."""

    @pytest.mark.parametrize("pipeline", [False, True])
    def test_bit_exact(self, pipeline):
        plan = _face_plan()
        eng = ServingEngine(plan)
        kw = dict(arrivals="poisson", seed=3, pipeline=pipeline)
        base = eng.run(2000, 150.0, **kw)
        explicit = eng.run(2000, 150.0, service_time=AnalyticServiceTime(), **kw)
        assert np.array_equal(
            base.e2e_latencies, explicit.e2e_latencies, equal_nan=True
        )

    def test_analytic_string_resolves_to_none(self):
        assert resolve_service_time(None) is None
        assert resolve_service_time("analytic") is None


class TestTraceBackend:
    def test_deterministic_under_seed(self):
        plan = _face_plan()
        eng = ServingEngine(plan)
        samples = {
            m: [c.duration * f for c in PROFILES[m].configs for f in (0.9, 1.2)]
            for m in plan.schedules
        }
        mk = lambda: TraceServiceTime(samples, jitter=0.1, seed=7)
        a = eng.run(1500, 150.0, arrivals="poisson", pipeline=True,
                    service_time=mk())
        b = eng.run(1500, 150.0, arrivals="poisson", pipeline=True,
                    service_time=mk())
        assert np.array_equal(a.e2e_latencies, b.e2e_latencies, equal_nan=True)

    def test_differs_from_analytic(self):
        plan = _face_plan()
        eng = ServingEngine(plan)
        src = TraceServiceTime(
            {m: [c.duration * 1.5 for c in PROFILES[m].configs]
             for m in plan.schedules}
        )
        base = eng.run(1500, 150.0, arrivals="poisson", pipeline=True)
        traced = eng.run(1500, 150.0, arrivals="poisson", pipeline=True,
                         service_time=src)
        assert not np.array_equal(
            base.e2e_latencies, traced.e2e_latencies, equal_nan=True
        )

    def test_key_ladder(self):
        m4 = _machine(batch=8, duration=0.05, hardware="tpu-v4")
        m5 = _machine(batch=8, duration=0.05, hardware="tpu-v5p")
        src = TraceServiceTime({
            ("m", 8, "tpu-v4"): [0.11],
            ("m", 8): [0.22],
            "m": [0.33],
        })
        assert src.duration("m", m4, 8) == pytest.approx(0.11)
        assert src.duration("m", m5, 8) == pytest.approx(0.22)
        m_other = _machine(batch=4, duration=0.05)
        assert src.duration("m", m_other, 4) == pytest.approx(0.33)
        # no samples at all: profiled fallback
        assert src.duration("other", m4, 8) == pytest.approx(0.05)

    def test_sequence_axis_and_reset(self):
        src = TraceServiceTime({("m", 8): [0.1, 0.2, 0.3]})
        m = _machine(batch=8)
        draws = [src.duration("m", m, 8) for _ in range(4)]
        assert draws == pytest.approx([0.1, 0.2, 0.3, 0.1])  # k mod len
        src.reset()
        assert src.duration("m", m, 8) == pytest.approx(0.1)

    def test_validation(self):
        with pytest.raises(ValueError):
            TraceServiceTime({("m", 8): [0.1, -0.2]})
        with pytest.raises(ValueError):
            TraceServiceTime({}, jitter=-1.0)


class TestLiveBackend:
    def test_measures_and_caches(self):
        calls = []
        src = LiveServiceTime({"m": lambda b: calls.append(b)}, warmup=1)
        m = _machine(batch=8)
        for _ in range(4):
            d = src.duration("m", m, 8)
            assert d > 0.0
        # warmup + 1 timed calls, then the cached steady mean is served
        assert calls == [8, 8]
        assert ("m", 8) in src.measured

    def test_no_executor_falls_back_to_profile(self):
        src = LiveServiceTime({"other": lambda b: None})
        assert src.duration("m", _machine(duration=0.07), 8) == pytest.approx(0.07)

    def test_to_trace_freezes_post_warmup(self):
        src = LiveServiceTime({"m": lambda b: None}, warmup=1, cache=False)
        m = _machine(batch=8)
        for _ in range(3):
            src.duration("m", m, 8)
        trace = src.to_trace()
        assert trace.samples[("m", 8)] == src.measured[("m", 8)][1:]

    def test_resolve_live_requires_executors(self):
        with pytest.raises(ValueError):
            resolve_service_time("live")
        src = resolve_service_time("live", {"m": lambda b: None})
        assert isinstance(src, LiveServiceTime)

    def test_resolve_rejects_bad_specs(self):
        with pytest.raises(ValueError):
            resolve_service_time("trace")
        with pytest.raises(TypeError):
            resolve_service_time(123)

    @pytest.mark.slow
    def test_live_engine_smoke(self):
        plan = _face_plan()
        eng = ServingEngine(
            plan, executors={m: (lambda b: None) for m in plan.schedules}
        )
        res = eng.run(300, 150.0, arrivals="poisson", pipeline=True,
                      service_time="live")
        lat = np.asarray(res.e2e_latencies)
        assert np.isfinite(lat[~np.isnan(lat)]).all()


class TestCorrectionConvergence:
    def test_converges_within_two_epochs(self):
        """A 1.3x-miscalibrated profile: epoch 1 audits duration_err ~0.3,
        the replan adopts the corrected profiles, and the error collapses
        (the active plan's modeled durations now match the trace)."""
        rate, slo = 150.0, 2.5
        plan = _face_plan(rate, slo)
        samples = {
            (m, c.batch, c.hardware): [c.duration * 1.3]
            for m, p in PROFILES.items()
            for c in p.configs
        }
        src = TraceServiceTime(samples)
        ctrl = ControlLoopConfig(interval=4.0, profiles=PROFILES, margin=0.2)
        eng = ServingEngine(plan)
        res = eng.run(
            4000, rate, arrivals="poisson", pipeline=True,
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
            timeout="budget", control=ctrl, service_time=src,
        )
        errs = [e.duration_err for e in res.epochs]
        assert len(errs) >= 4
        # epoch 1 closes on the uncorrected plan: full 30% model error
        assert errs[1] == pytest.approx(0.3, abs=0.05)
        # within two epochs the replan runs on corrected profiles
        assert all(e <= 0.05 for e in errs[3:] if e > 0.0)
        corrected = [e.corrections for e in res.epochs if e.corrections]
        assert corrected, "no profile correction was recorded"
        for m, s in corrected[-1].items():
            assert s == pytest.approx(1.3, rel=0.05)

    def test_corrections_off(self):
        """correct_profiles=False still audits the error but never repairs."""
        rate = 150.0
        plan = _face_plan(rate)
        src = TraceServiceTime({
            (m, c.batch, c.hardware): [c.duration * 1.3]
            for m, p in PROFILES.items()
            for c in p.configs
        })
        ctrl = ControlLoopConfig(
            interval=4.0, profiles=PROFILES, margin=0.2,
            correct_profiles=False,
        )
        res = ServingEngine(plan).run(
            3000, rate, arrivals="poisson", pipeline=True,
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
            timeout="budget", control=ctrl, service_time=src,
        )
        errs = [e.duration_err for e in res.epochs if e.duration_err > 0.0]
        assert errs and all(e == pytest.approx(0.3, abs=0.06) for e in errs)
        assert not any(e.corrections for e in res.epochs)
