"""Dummy-request injection: stream the plan's priced phantom traffic.

The scheduler prices dummy traffic two ways (`core.residual.apply_dummy`,
Theorem 2 padding, and `core.scheduler._dummy_fill`, the residual machine),
but a plan's ``Alloc.dummy`` / ``ModuleSchedule.dummy`` rates only matter at
serving time if the frontend actually *streams* them: phantom requests join
batch formation so batches collect at the provisioned rate — that is what
makes the modeled WCL (``d + b/w`` with ``w`` including dummy rate)
achievable — then their slots are excluded from every latency/attainment
statistic.

The injector is adaptive: it pads the module's observed real request rate up
to the plan's total collection rate, so driving a module *above* its
provisioned rate injects proportionally fewer (eventually zero) phantoms,
exactly like a real frontend that only fills otherwise-idle batch slots.
"""
from __future__ import annotations

import math

import numpy as np


def phantom_times(ready: np.ndarray, target_rate: float) -> np.ndarray:
    """Phantom arrival times padding ``ready`` up to ``target_rate`` req/s.

    ``ready`` is the module's sorted real request stream.  Phantoms are paced
    evenly at the deficit rate ``target_rate - observed_rate`` over the real
    stream's span (the frontend generates them, so it can pace perfectly),
    phase-offset by half a period so they interleave with real traffic.
    Returns an empty array when the real stream already meets the target.
    """
    n = ready.size
    if n < 2 or target_rate <= 0.0:
        return np.zeros(0)
    t0, t1 = float(ready[0]), float(ready[-1])
    span = t1 - t0
    if span <= 0.0:
        return np.zeros(0)
    observed = (n - 1) / span
    pad = target_rate - observed
    if pad <= 1e-9:
        return np.zeros(0)
    k = int(math.floor(pad * span))
    if k <= 0:
        return np.zeros(0)
    return t0 + (np.arange(k, dtype=np.float64) + 0.5) / pad


def merge_phantoms(
    ready: np.ndarray, phantoms: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Merge a sorted real stream with phantom times.

    Returns ``(merged_ready, phantom_mask)`` with the merge stable (real
    requests win ties, and the real sub-stream keeps its original order, so
    real results can be sliced back out with the mask).
    """
    if phantoms.size == 0:
        return ready, np.zeros(ready.size, dtype=bool)
    merged = np.concatenate([ready, phantoms])
    mask = np.concatenate(
        [np.zeros(ready.size, dtype=bool), np.ones(phantoms.size, dtype=bool)]
    )
    order = np.argsort(merged, kind="stable")
    return merged[order], mask[order]
