"""Vectorized per-machine batch-replay kernel — the simulator hot path.

Replays the same batch-formation/service semantics as the event-driven core
(`repro.serving.events`) in O(batches) numpy work instead of a per-event
Python loop, so replaying 10^6 requests across the 1131-workload suite takes
seconds.  The two key identities:

* batch boundaries under a deadline are *usually* the plain ``batch``-sized
  reshape — one vectorized check confirms no deadline fires mid-stream and
  falls back to a per-batch greedy scan (still O(batches)) when traffic is
  bursty enough that it does;
* the FIFO service chain ``end_g = max(ready_g, end_{g-1}) + d`` runs as one
  short loop per *batch* in exactly the event core's operation order, so the
  kernel's finish times are BIT-identical to the event-driven cores (the
  prefix-max closed form is the same value only to float association) —
  which is what lets the pipelined co-simulation's segment fast-path
  (`repro.serving.pipeline.fastpath`) delegate to this kernel without
  perturbing a single bit.

**Causal arrival order.**  The pipelined event loop is the authoritative
semantics: end-of-stream tail flushes (``timeout=None``) happen only once
everything upstream has drained, so their downstream cascades deliver
*strictly after* all normal completions — round by round — even though the
flush itself backdates ``batch_ready`` to the tail's last real arrival.  A
module's replay stream must therefore be ordered by ``(quiescence depth,
ready, frame id)`` (:func:`causal_order`), not by ready time alone: at a DAG
join a backdated tail completion on one branch may carry an *earlier* time
than a sibling's normal completions, yet it still arrives *later*.  The
stream handed to :func:`replay_machine` is non-decreasing in time *within*
each depth level only; batch closure uses the causally-last member's ready
(what the event core's ``now`` is at close), and the end-of-stream tail
flushes at the max ready over its members (the event loop's quiescence
``t_last``).  :func:`propagate_depth` carries the depth bookkeeping through
a module's service so downstream joins can re-establish the order.

Property tests (tests/test_event_core.py) pin this kernel to the event core,
and golden tests pin both to the frozen seed loops in
`repro.serving.reference` on uniform arrivals (the causal tail order
deviates from the seed loops only on the rare join corner the seed got
wrong — see tests/test_golden_equivalence.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from ..core.dispatch import Machine
from .events import simulate_module_events


@dataclass
class ModuleReplay:
    """Result of replaying one module over a request stream."""

    finish: np.ndarray  # absolute completion time per request (NaN = dropped)
    assignment: np.ndarray  # serving machine id per request
    batches: dict[int, int]  # executed batches per machine
    phantom: np.ndarray | None = None  # frontend dummy-request mask (None = none)

    @property
    def done(self) -> np.ndarray:
        return ~np.isnan(self.finish)

    @property
    def real(self) -> np.ndarray:
        """Mask of real (non-phantom) requests — the only ones stats count."""
        if self.phantom is None:
            return np.ones(self.finish.size, dtype=bool)
        return ~self.phantom

    @property
    def n_batches(self) -> int:
        return sum(self.batches.values())


def runs_to_assignment(runs: Sequence[tuple[int, int]], n: int) -> np.ndarray:
    """Expand ``dispatch_runs`` run-length pairs to a per-request mid array."""
    if not runs:
        return np.zeros(0, dtype=np.int64)
    mids = np.fromiter((mid for mid, _ in runs), np.int64, len(runs))
    counts = np.fromiter((c for _, c in runs), np.int64, len(runs))
    out = np.repeat(mids, counts)
    if out.size != n:
        raise ValueError(f"runs cover {out.size} requests, expected {n}")
    return out


def causal_order(
    ready: np.ndarray,
    depth: np.ndarray | None = None,
    emit: np.ndarray | None = None,
) -> np.ndarray:
    """Delivery order of the pipelined event loop at a DAG join.

    Normal completions (depth 0) deliver in time order; end-of-stream
    tail-flush cascades (depth ``r`` >= 1) deliver strictly after every
    normal event, round by round, each round processing in event-time
    order.  A join frame's delivery *instant* (``emit``) is the processing
    time of its last-resolving parent — the lexicographic ``(depth, time)``
    max over parent completions — which can be EARLIER than its ``ready``
    value (the max parent finish) when a backdated cascade completion joins
    a normal completion from the sibling branch.  So arrivals order by
    ``(quiescence depth, emit, frame id)``; with no positive depth
    ``emit == ready`` everywhere and this is exactly the stable ready-sort
    the flat engine always used.
    """
    if depth is None or not depth.any():
        return np.argsort(ready, kind="stable")
    # lexsort: last key is primary; stable, so equal (depth, emit) pairs
    # keep ascending id — matching the event loop's same-instant delivery
    return np.lexsort((ready if emit is None else emit, depth))


def lexmax_fold(
    frames: np.ndarray,
    depth_i: np.ndarray,
    emit_i: np.ndarray,
    out_depth: np.ndarray,
    out_emit: np.ndarray,
) -> None:
    """Per-frame resolve key at one module: the lexicographic
    ``(depth, emit)`` max over the frame's completed instances — a frame
    resolves when its last instance's completion event processes, which is
    the deepest round's latest event, not necessarily the max finish value.
    Writes into the per-frame output columns in place.
    """
    if frames.size == 0:
        return
    ordk = np.lexsort((emit_i, depth_i, frames))
    fs = frames[ordk]
    last = np.flatnonzero(np.r_[fs[1:] != fs[:-1], True])
    sel = ordk[last]
    out_depth[frames[sel]] = depth_i[sel]
    out_emit[frames[sel]] = emit_i[sel]


def lexmax_parents(
    depths: Sequence[np.ndarray], emits: Sequence[np.ndarray]
) -> tuple[np.ndarray, np.ndarray]:
    """A join frame's delivery key: the lexicographic ``(depth, emit)`` max
    over its parents' per-frame resolve keys (it is delivered when the last
    parent resolves in the event loop's processing order)."""
    d = depths[0].copy()
    e = emits[0].copy()
    for dp, ep in zip(depths[1:], emits[1:]):
        take = (dp > d) | ((dp == d) & (ep > e))
        d = np.where(take, dp, d)
        e = np.where(take, ep, e)
    return d, e


def propagate_depth(
    in_depth: np.ndarray,
    assignment: np.ndarray,
    finish: np.ndarray,
    machines: Sequence[Machine],
    timeout: "float | None | Mapping[int, float]",
    tail: str,
    anc_round: int,
) -> tuple[np.ndarray, int]:
    """Quiescence-depth bookkeeping through one module's service.

    A batch is ONE completion event: every member inherits the batch's
    depth — the max over member arrival depths (a round-``r`` cascade
    arrival that fills a batch carries its depth-0 members into round
    ``r`` with it).  FIFO service serializes a machine's batches, so depth
    also accumulates batch-to-batch (a batch cannot complete before
    earlier-queued work that includes a round-``r`` member).  Batch
    boundaries are recovered from ``finish``: the FIFO chain is strictly
    increasing per machine, so members share a batch iff they share a
    finish value.  A machine whose stream leaves a flushed partial tail
    (``timeout=None``, ``tail="flush"``) holds it until the module's own
    quiescence round — one past the deepest round any ancestor flushes in
    (``anc_round``) — and the tail's completions carry that depth
    downstream.

    Returns ``(out_depth, tail_round)`` where ``out_depth`` is per-instance
    (aligned with ``assignment``) and ``tail_round`` is the module's own
    flush round (0 when no machine flushes a partial tail).
    """
    out = in_depth.astype(np.int64, copy=True)
    if not machines:
        return out, 0

    def _w(mid: int):
        return timeout.get(mid) if isinstance(timeout, Mapping) else timeout

    order = np.argsort(assignment, kind="stable")
    sorted_mid = assignment[order]
    has_tail = False
    spans: list[tuple[Machine, np.ndarray]] = []
    for mm in machines:
        lo = int(np.searchsorted(sorted_mid, mm.mid, side="left"))
        hi = int(np.searchsorted(sorted_mid, mm.mid, side="right"))
        if lo == hi:
            continue
        idx = order[lo:hi]
        spans.append((mm, idx))
        if (
            tail == "flush"
            and _w(mm.mid) is None
            and idx.size % mm.config.batch != 0
        ):
            has_tail = True
    tail_round = anc_round + 1 if has_tail else 0
    if tail_round == 0 and not in_depth.any():
        return out, 0  # fully normal-phase module: nothing to propagate
    for mm, idx in spans:
        d = in_depth[idx]
        f = finish[idx]
        gid = np.cumsum(np.r_[True, f[1:] != f[:-1]]) - 1
        gmax = np.zeros(int(gid[-1]) + 1, dtype=np.int64)
        np.maximum.at(gmax, gid, d)
        rem = idx.size % mm.config.batch
        if rem and tail == "flush" and _w(mm.mid) is None:
            gmax[-1] = max(gmax[-1], tail_round)
        out[idx] = np.maximum.accumulate(gmax)[gid]
    return out, tail_round


def _batch_bounds(
    ready: np.ndarray,
    batch: int,
    timeout: float | None,
    tail: str,
    phantom: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Group a machine's sorted ready times into batches.

    Returns ``(sizes, g_ready)``: per-batch request counts (consecutive,
    starting at request 0; a dropped tail is simply not covered) and the time
    each batch is handed to the machine.

    ``phantom`` marks frontend dummy requests.  They fill batch slots like
    real traffic, but a flush deadline is armed only by the batch's first
    *real* request (the deadline exists to bound real latency), and a
    leftover batch containing only phantoms is discarded at end of stream
    instead of executed (the frontend stops injecting when the stream ends).
    """
    n = ready.size
    has_phantom = phantom is not None and bool(phantom.any())
    if timeout is None:
        n_full, tail_sz = divmod(n, batch)
        flush_tail = bool(tail_sz) and tail == "flush"
        if flush_tail and has_phantom and bool(phantom[n_full * batch:].all()):
            flush_tail = False  # phantom-only tail: nothing real to flush for
        ng = n_full + (1 if flush_tail else 0)
        if ng == 0:
            return np.zeros(0, np.int64), np.zeros(0)
        last = np.minimum(np.arange(1, ng + 1) * batch, n) - 1
        sizes = np.diff(np.concatenate([[0], last + 1]))
        g_ready = ready[last]
        if flush_tail:
            # the end-of-stream flush happens at the tail's last arrival in
            # TIME, not in stream position: the quiescence flush reads
            # ``t_last = max(member ready)``, and under causal order a
            # backdated cascade member may sit after the time-max one.  For
            # sorted streams the max IS the last element — bit-identical.
            g_ready = g_ready.astype(np.float64, copy=True)
            if has_phantom:
                # ... and only REAL arrivals count (the frontend stops
                # injecting once the stream ends) — trailing phantoms must
                # not inflate real tail latency
                tail_real = np.flatnonzero(~phantom[n_full * batch:])
                g_ready[-1] = ready[n_full * batch + tail_real].max()
            else:
                g_ready[-1] = ready[n_full * batch:].max()
        return sizes, g_ready
    if has_phantom:
        # greedy scan with real-opener deadlines (phantom streams are rare
        # and short — engine runs — so the O(batches) loop is fine)
        real_idx = np.flatnonzero(~phantom)
        sizes_l: list[int] = []
        gr_l: list[float] = []
        i = 0
        ri = 0
        while i < n:
            while ri < real_idx.size and real_idx[ri] < i:
                ri += 1
            if ri >= real_idx.size:
                # only phantoms remain: full batches still close by fill
                # (the machine cannot know), the partial remainder is never
                # time-flushed and drops at end of stream
                while i + batch <= n:
                    sizes_l.append(batch)
                    gr_l.append(float(ready[i + batch - 1]))
                    i += batch
                break
            deadline = float(ready[real_idx[ri]]) + timeout
            j = i + batch
            j_dl = int(np.searchsorted(ready, deadline, side="right"))
            if j <= j_dl:  # fills before the first real request's deadline
                r = float(ready[j - 1])
            else:
                j = j_dl
                r = deadline
            sizes_l.append(j - i)
            gr_l.append(r)
            i = j
        return np.asarray(sizes_l, np.int64), np.asarray(gr_l)
    # deadline semantics: tentative reshape boundaries are valid iff every
    # group's opener deadline covers the group's last member (and the tail's
    # covers the end of stream)
    nb = math.ceil(n / batch)
    starts = np.arange(nb) * batch
    ends = np.minimum(starts + batch, n)
    if np.all(ready[ends - 1] <= ready[starts] + timeout):
        g_ready = ready[ends - 1].astype(np.float64, copy=True)
        if ends[-1] - starts[-1] < batch:  # partial tail flushes at deadline
            g_ready[-1] = ready[starts[-1]] + timeout
        return ends - starts, g_ready
    # bursty fallback: greedy scan, one iteration per *batch* (not request)
    sizes_l = []
    gr_l = []
    i = 0
    while i < n:
        deadline = ready[i] + timeout
        j = i + batch
        j_dl = int(np.searchsorted(ready, deadline, side="right"))
        if j <= j_dl:  # fills before the deadline
            r = float(ready[j - 1])
        else:  # deadline flush: everything arrived by then (>= the opener)
            j = j_dl
            r = deadline
        sizes_l.append(j - i)
        gr_l.append(r)
        i = j
    return np.asarray(sizes_l, np.int64), np.asarray(gr_l)


def replay_machine(
    ready: np.ndarray,
    batch: int,
    duration: float,
    *,
    timeout: float | None = None,
    tail: str = "flush",
    phantom: np.ndarray | None = None,
) -> tuple[np.ndarray, int]:
    """Replay one machine; returns ``(finish, n_batches)``.

    ``ready`` must be in causal order (sorted by time within each quiescence
    depth level — plain sorted when no tail cascades are present; see the
    module docstring).  ``finish[i]`` is the absolute completion time
    of request ``i`` (NaN when the tail is dropped).  ``phantom`` marks
    frontend dummy requests (see `_batch_bounds` for their semantics).
    """
    if tail not in ("flush", "drop"):
        raise ValueError(f"unknown tail policy {tail!r}")
    ready = np.asarray(ready, dtype=np.float64)
    n = ready.size
    finish = np.full(n, np.nan)
    if n == 0:
        return finish, 0
    sizes, g_ready = _batch_bounds(ready, batch, timeout, tail, phantom)
    ng = sizes.size
    if ng == 0:
        return finish, 0
    # FIFO service chain: end_g = max(ready_g, end_{g-1}) + d, evaluated
    # with exactly the event core's operation order so the kernel is
    # BIT-identical to `simulate_module_events` (and to the pipelined
    # co-simulation's MachineCore chain), not merely equal to ~1e-15 — the
    # prefix-max closed form `d*(g+1) + cummax(ready_g - d*g)` is the same
    # number algebraically but associates the additions differently.  One
    # Python iteration per *batch* keeps this O(n / batch), a rounding
    # error on the kernel's total runtime.
    end_l: list[float] = []
    append = end_l.append
    prev = -math.inf
    for r in g_ready.tolist():
        if prev > r:
            r = prev
        prev = r + duration
        append(prev)
    end = np.asarray(end_l)
    covered = int(sizes.sum())
    finish[:covered] = np.repeat(end, sizes)
    return finish, ng


def replay_module(
    machines: Sequence[Machine],
    ready: np.ndarray,
    runs: Sequence[tuple[int, int]],
    *,
    timeout: "float | None | Mapping[int, float]" = None,
    tail: str = "flush",
    method: str = "vectorized",
    phantom: np.ndarray | None = None,
) -> ModuleReplay:
    """Replay one module's machines over a sorted request-ready stream.

    ``runs`` is the dispatcher's run-length assignment (`dispatch_runs`).
    ``timeout`` may be one deadline for all machines or a per-machine-id
    mapping (machines with longer service need shorter collection windows to
    meet the same budget).  ``method="events"`` routes through the reference
    event core instead of the vectorized kernel (identical results; used for
    cross-validation and whenever real executors are involved).  ``phantom``
    marks frontend dummy requests: they fill batch slots but never arm flush
    deadlines or force end-of-stream flushes, and callers exclude them from
    latency statistics via ``ModuleReplay.real``.
    """
    ready = np.asarray(ready, dtype=np.float64)
    n = ready.size
    assignment = runs_to_assignment(runs, n)
    if phantom is not None:
        phantom = np.asarray(phantom, dtype=bool)
        if phantom.shape != ready.shape:
            raise ValueError("phantom mask must match the request stream")
    if method == "events":
        finish, batches = simulate_module_events(
            machines, ready, assignment, timeout=timeout, tail=tail, phantom=phantom
        )
        return ModuleReplay(finish, assignment, batches, phantom)
    if method != "vectorized":
        raise ValueError(f"unknown method {method!r}")
    finish = np.full(n, np.nan)
    batches: dict[int, int] = {}
    # one stable argsort groups requests by machine while preserving arrival
    # order within each group (much cheaper than a per-machine == scan)
    order = np.argsort(assignment, kind="stable")
    sorted_mid = assignment[order]
    for m in machines:
        lo = int(np.searchsorted(sorted_mid, m.mid, side="left"))
        hi = int(np.searchsorted(sorted_mid, m.mid, side="right"))
        if lo == hi:
            batches[m.mid] = 0
            continue
        idx = order[lo:hi]
        w = timeout.get(m.mid) if isinstance(timeout, Mapping) else timeout
        f, nb = replay_machine(
            ready[idx], m.config.batch, m.config.duration, timeout=w, tail=tail,
            phantom=None if phantom is None else phantom[idx],
        )
        finish[idx] = f
        batches[m.mid] = nb
    return ModuleReplay(finish, assignment, batches, phantom)


def fanout_counts(n: int, fanout: float) -> np.ndarray:
    """Per-position instance counts of the seed fractional accumulator.

    Position ``i`` (0-based, in stream order) contributes
    ``floor(S_i) - floor(S_{i-1})`` instances where ``S_i = fanout *
    (i+1)``.  Fanouts that are multiples of 0.5 (every seed app) are exact
    in binary floating point, so the vectorized floor-difference is
    bit-identical to the accumulator loop; other fanouts take the loop to
    preserve its exact rounding drift (`pipeline.fanout.AccumulatorFanout`
    realizes the same semantics one frame at a time).
    """
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    if float(2.0 * fanout).is_integer():
        cum = np.floor(fanout * np.arange(1, n + 1))
        return np.diff(np.concatenate([[0.0], cum])).astype(np.int64)
    counts_l = []
    acc = 0.0
    for _ in range(n):
        acc += fanout
        k = int(acc)
        acc -= k
        counts_l.append(k)
    return np.asarray(counts_l, np.int64)


def expand_fanout(frames: np.ndarray, fanout: float) -> np.ndarray:
    """Expand ready-ordered frame ids into module-level request instances
    (see `fanout_counts` for the accumulator semantics)."""
    if frames.size == 0:
        return frames[:0]
    return np.repeat(frames, fanout_counts(frames.size, fanout))
