"""Burst-aware budget deadlines for dummy streaming (PR-4 finding closure).

The PR-4 ROADMAP finding: ``timeout="budget"`` + dummy streaming collapses
in pipeline mode downstream of batched stages — the zero-slack
``budget - d`` deadline flushes a partial batch whenever an upstream
inter-completion gap straddles it, and the wasted partial services snowball
at 100% utilization (attainment below 0.5 at 1.0x provisioning on uniform
arrivals).  ``FrontendConfig(burst_deadline=True)`` closes it by mirroring
the burst-aware WCL quantum on the deadline side (one upstream
batch-arrival quantum, `engine.plan_burst`) plus the padded-fill floor
(the adaptive injector's 1.5-slot pacing law bounds how fast phantoms can
actually fill a batch).  Flag off preserves the exact PR-4 semantics —
collapse included — so golden equivalence is untouched.
"""
import numpy as np
import pytest

from repro.core.dag import AppDAG, Leaf, Workload, series
from repro.core.dispatch import Policy, expand_machines
from repro.core.harpagon import Plan, PlannerOptions
from repro.core.profiles import Config, ModuleProfile
from repro.core.residual import schedule_module
from repro.serving import ServingEngine
from repro.serving.engine import plan_burst, resolve_module_timeout
from repro.serving.frontend import FrontendConfig


def chain_plan(specs, rate: float, slo: float) -> Plan:
    leaves = [Leaf(n) for n, _, _ in specs]
    app = AppDAG("chain", series(*leaves))
    scheds, rates = {}, {}
    for name, cfgs, budget in specs:
        s = schedule_module(
            name, rate, budget, ModuleProfile(name, tuple(cfgs)), Policy.TC,
            use_dummy=False,
        )
        assert s is not None, name
        scheds[name] = s
        rates[name] = rate
    return Plan(Workload(app, rates, slo), PlannerOptions(), scheds, True, 0.0)


def collapse_plan() -> Plan:
    """A (batch 16) -> B (batch 6) at one shared rate: every upstream
    completion delivers 16 instances = 2 full B batches + a 4-instance
    leftover whose opener must survive the 0.8 s inter-completion gap
    against a 0.3 s zero-slack deadline — the gap-straddle flush, every
    cycle, with a full-duration service wasted each time."""
    return chain_plan(
        [("A", [Config(16, 0.8)], 1.61), ("B", [Config(6, 0.3)], 0.61)],
        20.0, 3.2,
    )


class TestCollapseRegression:
    def test_pipeline_collapse_and_closure(self):
        """Satellite acceptance: the <0.5-attainment collapse reproduces
        with the flag off and closes completely with it on."""
        eng = ServingEngine(collapse_plan())
        base = eng.run(
            600, 20.0, timeout="budget",
            frontend=FrontendConfig(dummies=True), pipeline=True,
        )
        fixed = eng.run(
            600, 20.0, timeout="budget",
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
            pipeline=True,
        )
        assert base.attainment < 0.5  # the finding, reproduced
        assert fixed.attainment == 1.0
        # the fix works by NOT flushing the straddled leftover: fewer,
        # fuller batches at B instead of a wasted partial every cycle
        assert fixed.module_stats["B"].batches < base.module_stats["B"].batches

    def test_flat_engine_inherits_fix(self):
        """The flat engine shares the deadline semantics (and the finding);
        the flag must behave the same there."""
        eng = ServingEngine(collapse_plan())
        base = eng.run(
            600, 20.0, timeout="budget",
            frontend=FrontendConfig(dummies=True),
        )
        fixed = eng.run(
            600, 20.0, timeout="budget",
            frontend=FrontendConfig(dummies=True, burst_deadline=True),
        )
        assert base.attainment < 0.5
        assert fixed.attainment >= 0.99

    def test_flag_off_is_bit_exact_with_pr4_semantics(self):
        """burst_deadline=False must not perturb a single bit."""
        eng = ServingEngine(collapse_plan())
        a = eng.run(
            300, 20.0, timeout="budget",
            frontend=FrontendConfig(dummies=True), pipeline=True,
        )
        b = eng.run(
            300, 20.0, timeout="budget",
            frontend=FrontendConfig(dummies=True, burst_deadline=False),
            pipeline=True,
        )
        np.testing.assert_array_equal(a.pipeline.e2e, b.pipeline.e2e)


class TestDeadlineResolution:
    def test_plan_burst_is_upstream_quantum(self):
        plan = collapse_plan()
        assert plan_burst(plan, "A") == 0.0  # source: no upstream batching
        # B's quantum: one upstream batch's arrival time b_up / rate_up
        assert plan_burst(plan, "B") == pytest.approx(16 / 20.0)

    def test_burst_deadline_adds_quantum_and_floor(self):
        plan = collapse_plan()
        s = plan.schedules["B"]
        machines = expand_machines(list(s.allocs))
        off = resolve_module_timeout(s, machines, "budget", Policy.TC, dummies=True)
        on = resolve_module_timeout(
            s, machines, "budget", Policy.TC, dummies=True,
            burst=plan_burst(plan, "B"),
        )
        coll = sum(a.rate + a.dummy for a in s.allocs)
        for mm in machines:
            assert off[mm.mid] == pytest.approx(
                max(s.budget - mm.config.duration, 0.0)
            )
            floor = 2.0 * (mm.config.batch + 1.5) / coll
            assert on[mm.mid] == pytest.approx(
                max(s.budget - mm.config.duration, floor) + 16 / 20.0
            )
            assert on[mm.mid] > off[mm.mid]

    def test_non_dummy_and_fixed_timeouts_unaffected(self):
        plan = collapse_plan()
        s = plan.schedules["B"]
        machines = expand_machines(list(s.allocs))
        # the flag only touches the dummy-streaming "budget" branch
        assert resolve_module_timeout(s, machines, None, Policy.TC, burst=1.0) is None
        assert resolve_module_timeout(s, machines, 0.25, Policy.TC, burst=1.0) == 0.25
        w_real = resolve_module_timeout(
            s, machines, "budget", Policy.TC, dummies=False, burst=1.0
        )
        assert w_real == resolve_module_timeout(
            s, machines, "budget", Policy.TC, dummies=False
        )
