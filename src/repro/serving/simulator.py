"""Discrete-event dispatch simulator: empirical validation of Theorem 1.

Requests arrive at a uniform rate (streaming-video regime, as in the paper);
the dispatcher assigns them to machines under TC / RR policy via the literal
`core.dispatch.dispatch_trace`; machines execute full batches taking the
profiled duration.  The maximum observed request latency is compared against
the analytic worst-case L_wc of `core.dispatch.module_wcl`.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.dispatch import Alloc, Machine, Policy, dispatch_trace, expand_machines


@dataclass
class SimResult:
    max_latency: float
    mean_latency: float
    per_machine_max: dict[int, float]
    n_requests: int


def simulate(
    allocs: list[Alloc],
    total_rate: float,
    *,
    policy: Policy = Policy.TC,
    n_requests: int = 2000,
) -> SimResult:
    machines = expand_machines(allocs)
    trace = dispatch_trace(machines, n_requests, policy)
    arrivals = [i / total_rate for i in range(n_requests)]

    by_machine: dict[int, list[int]] = {m.mid: [] for m in machines}
    for rid, mid in trace:
        by_machine[mid].append(rid)

    latency = [0.0] * n_requests
    per_machine_max: dict[int, float] = {}
    for m in machines:
        rids = by_machine[m.mid]
        b, d = m.config.batch, m.config.duration
        free_at = 0.0
        worst = 0.0
        for i in range(0, len(rids), b):
            group = rids[i : i + b]
            if len(group) < b:
                break  # incomplete tail batch: not in steady state, drop
            ready = arrivals[group[-1]]
            start = max(ready, free_at)
            finish = start + d
            free_at = finish
            for rid in group:
                lat = finish - arrivals[rid]
                latency[rid] = lat
                worst = max(worst, lat)
        per_machine_max[m.mid] = worst
    done = [l for l in latency if l > 0]
    return SimResult(
        max_latency=max(done) if done else 0.0,
        mean_latency=sum(done) / len(done) if done else 0.0,
        per_machine_max=per_machine_max,
        n_requests=len(done),
    )
