"""Arrival-process generators: determinism, sortedness, mean rate, burstiness."""
import numpy as np
import pytest

from repro.serving.arrivals import (
    ARRIVALS,
    make_arrivals,
    mmpp_arrivals,
    poisson_arrivals,
    trace_arrivals,
    uniform_arrivals,
)

KINDS = ("uniform", "poisson", "mmpp", "diurnal")


@pytest.mark.parametrize("kind", KINDS)
def test_deterministic_under_seed(kind):
    a = make_arrivals(kind, 4000, 80.0, seed=42)
    b = make_arrivals(kind, 4000, 80.0, seed=42)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.float64 and a.shape == (4000,)
    assert np.all(np.diff(a) >= 0)


@pytest.mark.parametrize("kind", ("poisson", "mmpp", "diurnal"))
def test_seed_actually_matters(kind):
    a = make_arrivals(kind, 2000, 80.0, seed=1)
    b = make_arrivals(kind, 2000, 80.0, seed=2)
    assert not np.array_equal(a, b)


def test_uniform_exact():
    t = uniform_arrivals(10, 50.0)
    np.testing.assert_allclose(t, np.arange(10) / 50.0)


def test_poisson_mean_rate():
    n, rate = 40000, 120.0
    t = poisson_arrivals(n, rate, seed=5)
    realized = n / t[-1]
    assert realized == pytest.approx(rate, rel=0.05)


def test_mmpp_mean_rate_and_burstiness():
    n, rate = 40000, 120.0
    t = mmpp_arrivals(n, rate, seed=5, mean_dwell=0.5)
    realized = n / t[-1]
    assert realized == pytest.approx(rate, rel=0.10)
    # burstiness: squared coefficient of variation of inter-arrivals well
    # above the Poisson value of 1
    gaps = np.diff(t)
    scv = gaps.var() / gaps.mean() ** 2
    assert scv > 1.5, scv
    pois = np.diff(poisson_arrivals(n, rate, seed=5))
    scv_pois = pois.var() / pois.mean() ** 2
    assert scv_pois == pytest.approx(1.0, abs=0.2)


def test_diurnal_mean_rate_over_full_periods():
    n, rate = 30000, 150.0  # ~200 s of traffic, 100 periods of 2 s
    t = trace_arrivals(n, rate, seed=3, period=2.0)
    assert n / t[-1] == pytest.approx(rate, rel=0.10)


def test_trace_profile_from_samples_normalized():
    # an unnormalized sample trace must still deliver mean `rate`
    samples = [5.0, 5.0, 0.5, 0.5]
    n, rate = 30000, 100.0
    t = trace_arrivals(n, rate, seed=0, profile=samples, period=1.0)
    assert n / t[-1] == pytest.approx(rate, rel=0.10)


def test_explicit_array_passthrough_and_validation():
    arr = np.array([0.0, 0.5, 1.5])
    np.testing.assert_array_equal(make_arrivals(arr, 3, 10.0), arr)
    with pytest.raises(ValueError, match="length"):
        make_arrivals(arr, 5, 10.0)
    with pytest.raises(ValueError, match="sorted"):
        make_arrivals(np.array([1.0, 0.5]), 2, 10.0)


def test_unknown_kind_and_bad_params():
    with pytest.raises(ValueError, match="unknown arrival"):
        make_arrivals("fractal", 10, 1.0)
    with pytest.raises(ValueError):
        poisson_arrivals(10, -1.0)
    with pytest.raises(ValueError):
        mmpp_arrivals(10, 1.0, burst=0.5)
    assert set(KINDS) <= set(ARRIVALS)
