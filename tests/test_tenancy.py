"""Multi-tenant shared pool (ISSUE-8): device plans, global allocation,
interference-aware co-location.

Pins the subsystem's load-bearing properties: the device-centric view
round-trips exactly to each plan's `machine_fractions` machine multiset;
calibration of the interference model is seeded-deterministic and its
slowdowns are monotone in co-resident occupancy; the FFD allocator
consolidates fractional residues (pool cost strictly below the dedicated
integer-device bill) while the e2e-SLO feasibility guard marks residues
that could not survive a partner; per-app frame accounting conserves
under the shared pool; a pool with tenancy disabled is BIT-exact with
per-app `ServingEngine` runs; and repack deltas yield the colocate/evict
events the observability layer records.  Satellite: the pipeline path's
admission sheds land in the trace at decision resolution without double
counting.
"""
import numpy as np
import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.core.dispatch import expand_machines, machine_fractions
from repro.profiling.interference import InterferenceModel, calibrate
from repro.serving import (
    ClosedLoopClients,
    ControlLoopConfig,
    FrontendConfig,
    InterferenceServiceTime,
    ServingEngine,
    SharedPool,
    TenancyConfig,
    TokenBucket,
)
from repro.serving.tenancy import (
    AllocatorConfig,
    GlobalAllocator,
    dedicated_cost,
    diff_device_plans,
    plan_slots,
)
from repro.workloads import synth_profiles
from repro.workloads.apps import app_by_name, make_workload

PROFILES = synth_profiles()

# the five paper apps at 1/8 rate: low-rate plans strand large fractional
# residues, the regime consolidation exists to recover
SEEDS = [
    ("traffic", 100.0, 2.0),
    ("face", 150.0, 2.5),
    ("pose", 60.0, 3.0),
    ("caption", 90.0, 2.5),
    ("actdet", 80.0, 3.0),
]
SCALE = 0.125

_PLANS: dict = {}


def pool_plans(scale=SCALE):
    if scale not in _PLANS:
        planner = Planner(B.HARPAGON)
        plans = {}
        for name, rate, slo in SEEDS:
            p = planner.plan(
                make_workload(app_by_name(name), rate * scale, slo), PROFILES
            )
            assert p.feasible
            plans[name] = p
        _PLANS[scale] = plans
    return dict(_PLANS[scale])


# ---------------------------------------------------------- interference


class TestInterference:
    def test_calibration_deterministic(self):
        a, b = calibrate(seed=0), calibrate(seed=0)
        assert a == b
        assert calibrate(seed=1) != a

    def test_slowdown_monotone_and_bounded(self):
        m = calibrate(seed=0)
        for hw in ("tpu-v5e", "tpu-v4", "tpu-v5p", "default"):
            prev = 1.0
            assert m.slowdown(0.0, hw) == 1.0
            for occ in (0.1, 0.3, 0.5, 0.8, 1.0):
                s = m.slowdown(occ, hw)
                assert s >= prev - 1e-12
                prev = s
            # occupancy saturates at a full device
            assert m.slowdown(2.0, hw) == pytest.approx(m.slowdown(1.0, hw))

    def test_inflate_scales_duration_only(self):
        m = InterferenceModel(alpha={"default": 0.5})
        plan = pool_plans()["traffic"]
        cfg = next(iter(plan.schedules.values())).allocs[0].config
        inflated = m.inflate(cfg, 0.5)
        assert inflated.duration == pytest.approx(cfg.duration * 1.25)
        assert inflated.batch == cfg.batch
        assert inflated.hardware == cfg.hardware

    def test_interference_service_time_factors(self):
        plans = pool_plans()
        pool = SharedPool(plans)
        factors = pool.device_plan.interference_factors(pool.model)
        assert factors  # shared devices exist at this scale
        assert all(f > 1.0 for f in factors.values())
        with pytest.raises(ValueError):
            InterferenceServiceTime({("m", 0): 0.5})

    def test_factors_mapping_held_live(self):
        """The factors dict is held by reference: the pool's repack hook
        mutates it in place and the next duration() must see the change."""
        plan = pool_plans()["traffic"]
        module, sched = next(iter(plan.schedules.items()))
        mach = expand_machines(list(sched.allocs))[0]
        factors: dict = {}
        src = InterferenceServiceTime(factors)
        assert src.duration(module, mach, 1) == mach.config.duration
        factors[(module, mach.mid)] = 2.0
        assert src.duration(module, mach, 1) == pytest.approx(
            2.0 * mach.config.duration
        )
        factors.clear()  # eviction: the slowdown must go away too
        assert src.duration(module, mach, 1) == mach.config.duration


# ------------------------------------------------- device plan round-trip


class TestDevicePlan:
    def test_round_trip_module_machines(self):
        plans = pool_plans()
        pool = SharedPool(plans)
        for app, plan in plans.items():
            mm = pool.device_plan.module_machines(app)
            assert set(mm) == set(plan.schedules)
            for m, s in plan.schedules.items():
                ref = [
                    (a.config, f) for a, f in machine_fractions(list(s.allocs))
                ]
                got = mm[m]
                assert len(got) == len(ref)
                for (c0, f0), (c1, f1) in zip(ref, got):
                    assert c0 == c1
                    assert f0 == pytest.approx(f1, abs=1e-12)

    def test_full_covers_never_share(self):
        pool = SharedPool(pool_plans())
        for d in pool.device_plan.devices:
            if any(s.fraction >= 1.0 - 1e-12 for s in d.slots):
                assert len(d.slots) == 1

    def test_occupancy_and_coresident_caps(self):
        pool = SharedPool(pool_plans())
        for d in pool.device_plan.devices:
            assert d.occupancy <= 1.0 + 1e-9
            assert len(d.slots) <= 2

    def test_diff_colocate_evict(self):
        plans = pool_plans()
        alloc = GlobalAllocator(
            AllocatorConfig(interference=calibrate(seed=0))
        )
        dp0 = alloc.pack(plans)
        assert dp0.n_shared > 0
        # dropping one app repartners / evicts its co-residents
        remaining = {k: v for k, v in plans.items() if k != "face"}
        alloc2 = GlobalAllocator(
            AllocatorConfig(interference=calibrate(seed=0))
        )
        dp1 = alloc2.pack(remaining)
        delta = diff_device_plans(dp0, dp1)
        assert delta.evicted  # face's pairings are gone
        assert all(
            key[0] != "face" for _, key in delta.colocated
        )  # nothing new pairs with a departed tenant
        # identical packing diffs empty
        assert diff_device_plans(dp0, dp0).empty


# ------------------------------------------------------- global allocator


class TestAllocator:
    def test_consolidation_beats_dedicated(self):
        plans = pool_plans()
        pool = SharedPool(plans)
        assert pool.device_plan.n_shared > 0
        assert pool.device_plan.cost < dedicated_cost(plans) - 1e-9

    def test_pool_cost_counts_whole_devices(self):
        plans = pool_plans()
        pool = SharedPool(plans)
        expect = sum(d.unit_price for d in pool.device_plan.devices)
        assert pool.device_plan.cost == pytest.approx(expect)

    def test_hardware_never_mixes_on_a_device(self):
        pool = SharedPool(pool_plans())
        for d in pool.device_plan.devices:
            assert len({s.config.hardware for s in d.slots}) == 1

    def test_guard_blocks_infeasible_pairings(self):
        plans = pool_plans()
        # a brutal interference model: any sharing doubles the duration
        brutal = InterferenceModel(
            alpha={
                "tpu-v5e": 9.0, "tpu-v4": 9.0, "tpu-v5p": 9.0, "default": 9.0,
            }
        )
        guarded = GlobalAllocator(
            AllocatorConfig(interference=brutal, guard=True)
        ).pack(plans)
        unguarded = GlobalAllocator(
            AllocatorConfig(interference=brutal, guard=False)
        ).pack(plans)
        assert guarded.n_shared < unguarded.n_shared
        # residues the guard kept exclusive carry the dedicated marker
        assert any(d.dedicated for d in guarded.devices)

    def test_submit_returns_delta(self):
        plans = pool_plans()
        alloc = GlobalAllocator(
            AllocatorConfig(interference=calibrate(seed=0))
        )
        alloc.pack(plans)
        v0 = alloc.device_plan.version
        new, delta = alloc.submit("traffic", plans["traffic"])
        assert new.version == v0 + 1
        assert delta.empty  # same plan resubmitted -> same packing

    def test_slots_partition_plan_machines(self):
        plans = pool_plans()
        for app, plan in plans.items():
            full, resid = plan_slots(app, plan)
            n = sum(
                len(machine_fractions(list(s.allocs)))
                for s in plan.schedules.values()
            )
            assert len(full) + len(resid) == n
            assert all(s.fraction >= 1.0 - 1e-12 for s in full)
            assert all(s.fraction < 1.0 - 1e-12 for s in resid)


# ------------------------------------------------------------ shared pool


class TestSharedPool:
    def test_conservation_under_shared_pool(self):
        pool = SharedPool(pool_plans())
        res = pool.run(400)
        assert all(res.conservation().values())
        for r in res.results.values():
            assert r.offered == len(r.e2e_latencies) + r.shed + r.dropped

    def test_consolidated_cheaper_at_equal_attainment(self):
        pool = SharedPool(pool_plans())
        res = pool.run(400)
        assert res.savings >= 1.15
        assert res.attainment >= 0.97

    def test_disabled_pool_bit_exact_with_engine(self):
        plans = pool_plans()
        pool = SharedPool(plans, tenancy=None)
        assert pool.device_plan.n_shared == 0
        res = pool.run(300)
        for rank, app in enumerate(sorted(plans)):
            wl = plans[app].workload
            rate = wl.rates[wl.app.modules[0]]
            direct = ServingEngine(plans[app]).run(
                300, rate, seed=rank, pipeline=True
            )
            assert res.results[app].e2e_latencies == direct.e2e_latencies
            assert res.results[app].shed == direct.shed
            assert res.results[app].dropped == direct.dropped

    def test_interference_slows_colocated_apps(self):
        plans = pool_plans()
        on = SharedPool(plans).run(400)
        off = SharedPool(plans, tenancy=None).run(400)
        slowed = 0
        for app in plans:
            mean_on = float(np.mean(on.results[app].e2e_latencies))
            mean_off = float(np.mean(off.results[app].e2e_latencies))
            assert mean_on >= mean_off - 1e-9
            if mean_on > mean_off + 1e-9:
                slowed += 1
        assert slowed > 0  # co-located batches honestly ran slower

    def test_pool_trace_records_colocations(self):
        pool = SharedPool(pool_plans())
        res = pool.run(200, observability=True)
        names = [e[4] for e in res.trace.events() if e[0] == 1]
        assert names.count("colocate") == sum(
            len(d.slots) for d in pool.device_plan.devices if d.shared
        )
        counters = {e[4] for e in res.trace.events() if e[0] == 2}
        assert any(c.endswith("_occupancy") for c in counters)

    def test_control_loop_repacks(self):
        pool = SharedPool(pool_plans())
        res = pool.run(
            600,
            control=ControlLoopConfig(interval=5.0, profiles=PROFILES),
            arrivals="poisson",
            observability=True,
        )
        assert res.repacks  # every epoch swap arbitrated through the pool
        assert all(res.conservation().values())
        names = [e[4] for e in res.trace.events() if e[0] == 1]
        assert "colocate" in names

    def test_repack_factors_reach_batch_durations(self):
        """The pool's repack mechanism end-to-end: an ``on_swap`` in-place
        mutation of the factors mapping changes the durations of batches
        started *after* the swap (regression: a copied mapping silently
        froze the initial-pack factors forever)."""
        plan = pool_plans()["traffic"]
        wl = plan.workload
        rate = wl.rates[wl.app.modules[0]]
        log: list = []

        class Recording(InterferenceServiceTime):
            def duration(self, module, machine, n_members):
                d = super().duration(module, machine, n_members)
                log.append((machine.config.duration, d))
                return d

        factors: dict = {}

        def on_swap(t, new_plan):
            factors.clear()
            factors.update({
                (m, mm.mid): 3.0
                for m, s in new_plan.schedules.items()
                for mm in expand_machines(list(s.allocs))
            })
            log.append("swap")

        res = ServingEngine(plan).run(
            600, rate,
            arrivals="poisson",
            offered_rate=rate * 1.6,
            control=ControlLoopConfig(
                interval=5.0, profiles=PROFILES, on_swap=on_swap
            ),
            service_time=Recording(factors),
            pipeline=True,
        )
        assert "swap" in log  # the control loop swapped at least once
        pre = log[: log.index("swap")]
        assert all(d == base for base, d in pre)  # no slowdown before swaps
        post = [e for e in log[log.index("swap"):] if e != "swap"]
        assert any(
            d == pytest.approx(3.0 * base) for base, d in post
        )  # post-swap batch starts read the mutated factors


# ------------------------- satellite: pipeline-path admission shed events


class TestPipelineShedTelemetry:
    def test_open_loop_shed_instants_match_exactly(self):
        plan = Planner(B.HARPAGON).plan(
            make_workload(app_by_name("traffic"), 100.0, 2.0), PROFILES
        )
        res = ServingEngine(plan).run(
            1000, 100.0, arrivals="mmpp", offered_rate=130.0,
            frontend=FrontendConfig(admission=TokenBucket(burst=4)),
            pipeline=True, observability=True,
        )
        assert res.shed > 0
        n_inst = sum(
            1 for e in res.trace.events() if e[0] == 1 and e[4] == "shed"
        )
        # wired at decision resolution, no double count with the loop's
        # terminal emit: open loop has exactly one decision per shed frame
        assert n_inst == res.shed

    def test_closed_loop_shed_instants_match_terminal(self):
        """Closed loop: interim denials the client re-issues are tagged
        "shed_retry", so "shed" instants stay summable as terminal sheds."""
        plan = Planner(B.HARPAGON).plan(
            make_workload(app_by_name("traffic"), 100.0, 2.0), PROFILES
        )
        res = ServingEngine(plan).run(
            600, 100.0,
            frontend=FrontendConfig(
                admission=TokenBucket(rate=60.0, burst=2.0),
                clients=ClosedLoopClients(
                    n_clients=64, retry_on_shed=True, max_retries=2,
                    backoff=0.01,
                ),
            ),
            pipeline=True, observability=True,
        )
        names = [e[4] for e in res.trace.events() if e[0] == 1]
        # with retry_on_shed every terminal denial follows a re-offer, so
        # it is an exhausted-retry DROP under its own instant name; the
        # instants stay summable as terminals per cause
        assert res.dropped > 0 and res.shed == 0
        assert names.count("shed_retry") > 0  # interim denials are distinct
        assert names.count("retry_exhausted") == res.dropped
        assert names.count("shed") == res.shed
