"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Backbone only: the ViT vision encoder + projector are a stub — ``input_specs``
feeds precomputed patch embeddings and (t, h, w) M-RoPE position streams.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    source="arXiv:2409.12191",
    qkv_bias=True,
    mrope_sections=(16, 24, 24),  # sums to head_dim/2 = 64
    rope_theta=1_000_000.0,
    input_mode="embeds",
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mrope_sections=(4, 6, 6),  # head_dim 32 -> 16 rotary pairs
    param_dtype="float32",
    compute_dtype="float32",
)
