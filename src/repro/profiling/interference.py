"""Calibrated co-location interference: slowdown vs co-resident occupancy.

When two module residues share one physical device (MPS-style space
sharing), each one's batch durations stretch: the co-tenant competes for
HBM bandwidth, MXU issue slots, and the on-chip working set.  The tenancy
allocator (`repro.serving.tenancy`) models that honestly instead of
pretending packed residues run at profiled speed:

* :class:`InterferenceModel` — a per-hardware-class multiplicative
  slowdown ``1 + alpha_hw * occ^gamma`` where ``occ`` is the *co-resident*
  occupancy (the sum of the OTHER tenants' capacity fractions on the
  device, in ``[0, 1)``).  Self-occupancy never slows a slot down — a
  residue alone on a device runs at exactly the profiled duration, which
  is what keeps tenancy-off runs bit-exact.
* :meth:`InterferenceModel.inflate` — a profile :class:`Config` row whose
  duration includes the contention term.  This is the thread into
  `core.dispatch.config_wcl`: the allocator's feasibility guard evaluates
  Theorem-1 worst-case latency on the inflated row, so a co-location that
  would break a module's latency budget is rejected *with the same WCL
  algebra the planner provisioned under*.
* :func:`calibrate` — a seeded synthetic co-location measurement
  campaign fitted by least squares.  Stand-in for the one-off offline
  pass a real deployment runs (pin two modules on one chip, sweep the
  co-tenant's occupancy, regress the duration stretch); deterministic
  under a fixed seed so plans, benches, and tests are replayable.

The magnitudes follow the memory-bandwidth-contention shape reported for
MPS co-location studies (OCTOPINF, PAPERS.md): roughly linear in the
co-tenant's occupancy, worse on the cheaper bandwidth-lean tiers, on the
order of 10-35% at high co-residency — large enough that a latency-tight
module must fall back to a dedicated device, small enough that packing
low-rate residues is usually a win.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping

import numpy as np

from ..core.profiles import Config

_EPS = 1e-12

#: latent contention pressure per hardware class used by the synthetic
#: measurement campaign: bandwidth-lean cheap tiers contend hardest
_LATENT_PRESSURE = {
    "tpu-v5e": 0.30,
    "tpu-v4": 0.22,
    "tpu-v5p": 0.16,
    "default": 0.25,
}


@dataclass(frozen=True)
class InterferenceModel:
    """Multiplicative co-location slowdown ``1 + alpha_hw * occ^gamma``.

    ``alpha`` maps hardware-class name -> contention coefficient (the
    fitted duration stretch at full co-resident occupancy); unknown
    classes fall back to ``"default"``.  ``gamma`` is the convexity of
    the occupancy response (1 = linear, the fitted campaigns below stay
    linear; >1 models contention that only bites near saturation).
    """

    alpha: Mapping[str, float] = field(default_factory=dict)
    gamma: float = 1.0

    def __post_init__(self):
        if self.gamma <= 0.0:
            raise ValueError("gamma must be positive")
        for hw, a in self.alpha.items():
            if a < 0.0:
                raise ValueError(f"alpha[{hw!r}] must be >= 0")

    def coefficient(self, hardware: str) -> float:
        a = self.alpha.get(hardware)
        if a is None:
            a = self.alpha.get("default", 0.0)
        return a

    def slowdown(self, coresident: float, hardware: str = "default") -> float:
        """Duration factor for a slot sharing its device with ``coresident``
        total capacity-fraction of other tenants (0 = alone = exactly 1.0)."""
        if coresident <= _EPS:
            return 1.0
        occ = min(1.0, float(coresident))
        return 1.0 + self.coefficient(hardware) * occ ** self.gamma

    def inflate(self, config: Config, coresident: float) -> Config:
        """The profile row with contention folded into its duration.

        Feeding this row to `config_wcl` (and a machine built from it to
        the service-time hook) is how co-located batches honestly run —
        and are *budgeted* — slower."""
        s = self.slowdown(coresident, config.hardware)
        if s <= 1.0 + _EPS:
            return config
        return dataclasses.replace(config, duration=config.duration * s)


def calibrate(
    seed: int = 0,
    hardware: tuple[str, ...] = ("tpu-v5e", "tpu-v4", "tpu-v5p", "default"),
    *,
    gamma: float = 1.0,
    points: int = 9,
    noise: float = 0.03,
) -> InterferenceModel:
    """Fit an :class:`InterferenceModel` from a seeded synthetic campaign.

    For each hardware class: sweep the co-tenant occupancy over ``points``
    levels in ``[0.1, 0.9]``, "measure" the duration stretch (the latent
    linear pressure curve times seeded lognormal measurement noise), and
    least-squares fit ``stretch - 1 = alpha * occ^gamma``.  Deterministic
    under a fixed seed: per-class streams are derived from the root
    ``SeedSequence`` in ``hardware`` order.
    """
    if points < 2:
        raise ValueError("points must be >= 2")
    if noise < 0.0:
        raise ValueError("noise must be >= 0")
    occ = np.linspace(0.1, 0.9, points)
    alpha: dict[str, float] = {}
    for i, hw in enumerate(hardware):
        rng = np.random.default_rng(np.random.SeedSequence([seed, i]))
        latent = _LATENT_PRESSURE.get(hw, _LATENT_PRESSURE["default"])
        measured = (1.0 + latent * occ) * np.exp(
            noise * rng.standard_normal(points)
        )
        x = occ ** gamma
        y = measured - 1.0
        alpha[hw] = max(0.0, float((x @ y) / (x @ x)))
    return InterferenceModel(alpha=alpha, gamma=gamma)


__all__ = ["InterferenceModel", "calibrate"]
