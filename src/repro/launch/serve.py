"""Serving launcher: plan with Harpagon, then serve batched requests.

Plans a (possibly multi-module) session over the analytic TPU profiles and
runs the serving engine.  With --real, module executors are real jitted JAX
forwards of reduced models on CPU; otherwise profiled durations drive an
event simulation at full scale.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --rate 200 --slo 0.5 --requests 2000
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b,qwen1.5-4b \
      --rate 120 --slo 1.0            # two-module chain
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --real \
      --pipeline --epoch 2.0          # pipelined co-sim against measured
                                      # step times + epoch audit/replan
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --pipeline --epoch 2.0 --arrivals diurnal --trace trace.json
                                      # observability on: per-epoch metrics,
                                      # SLO-miss forensics, Perfetto trace
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --pipeline --epoch 2.0 --chaos  # one seeded machine crash per epoch:
                                      # watchdog detection, re-queue recovery,
                                      # failure replan + warm-spare promotion
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..core import Leaf, Workload, series
from ..core.baselines import ALL_SYSTEMS
from ..core.dag import AppDAG
from ..core.harpagon import Planner
from ..models import Model
from ..profiling import arch_profile
from ..serving import ControlLoopConfig, FaultConfig, ServingEngine, SharedPool
from ..serving.arrivals import trace_arrivals


def _make_faults(args) -> "FaultConfig | None":
    """Resolve --chaos into a `FaultConfig` (None when the flag is absent).

    ``--chaos MTBF`` arms the seeded exponential crash process; a bare
    ``--chaos`` derives a deterministic schedule instead — one crash per
    epoch midpoint under ``--epoch``, a single mid-run crash otherwise.
    """
    if args.chaos is None:
        return None
    if args.chaos > 0.0:
        return FaultConfig(mtbf=args.chaos)
    horizon = args.requests / args.rate
    if args.epoch:
        sched = tuple(
            (args.epoch * (k + 0.5), "crash")
            for k in range(int(horizon / args.epoch))
        )
    else:
        sched = ((horizon / 2.0, "crash"),)
    return FaultConfig(schedule=sched)


def _serve_pool(args, archs, profiles) -> None:
    """--pool: each arch is its own single-module tenant; one shared pool."""
    plans = {}
    for a in archs:
        wl = Workload(AppDAG(a, series(Leaf(a))), {a: args.rate}, args.slo)
        plan = Planner().plan(wl, {a: profiles[a]})
        print(plan.summary())
        if not plan.feasible:
            raise SystemExit(f"infeasible workload for tenant {a}")
        plans[a] = plan
    pool = SharedPool(plans)
    print(pool.device_plan.summary())
    control = (
        ControlLoopConfig(interval=args.epoch, profiles=profiles)
        if args.epoch
        else None
    )
    if args.arrivals == "diurnal":
        arrivals = "uniform"  # per-tenant diurnal traces need per-app seeds
        print("(--pool serves diurnal tenants via --epoch control; "
              "arrival curve fixed to uniform per tenant)")
    else:
        arrivals = args.arrivals
    res = pool.run(
        args.requests,
        args.rate,
        arrivals=arrivals,
        pipeline=True,
        control=control,
        observability=args.trace is not None,
        faults=_make_faults(args),
    )
    print(res.summary())
    print(
        f"consolidated {len(plans)} tenants onto "
        f"{len(res.device_plan.devices)} devices "
        f"({res.device_plan.n_shared} shared): pool cost {res.pool_cost:.4g} "
        f"vs dedicated {res.dedicated_cost:.4g} — {res.savings:.3f}x cheaper"
    )
    if args.trace is not None and res.trace is not None:
        path = res.trace.export(args.trace)
        print(f"wrote {len(res.trace.events())} pool trace events to {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help="comma-separated chain of archs")
    ap.add_argument("--rate", type=float, default=100.0)
    ap.add_argument("--slo", type=float, default=1.0)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--real", action="store_true", help="execute reduced models on CPU")
    ap.add_argument("--compare", action="store_true", help="plan with all 5 systems")
    ap.add_argument(
        "--pipeline", action="store_true",
        help="serve through the pipelined DAG co-simulation (with --real, "
        "batch service times are measured executor forwards)",
    )
    ap.add_argument(
        "--epoch", type=float, default=0.0,
        help="control-loop epoch interval in seconds (0 = control off); "
        "with --real each epoch audits modeled vs measured service time "
        "and replans against the corrected profiles",
    )
    ap.add_argument(
        "--arrivals", default="uniform",
        choices=["uniform", "poisson", "mmpp", "diurnal"],
        help="arrival process (diurnal = sinusoidal day/night trace whose "
        "period spans the run — the control plane's natural stressor)",
    )
    ap.add_argument(
        "--pool", action="store_true",
        help="serve each arch as an independent tenant on ONE shared device "
        "pool (multi-tenant: fractional machine residues co-located under "
        "the calibrated interference model, cost compared against dedicated "
        "per-tenant devices) instead of chaining the archs in series",
    )
    ap.add_argument(
        "--chaos", type=float, nargs="?", const=0.0, default=None,
        metavar="MTBF",
        help="seeded fault injection (requires --pipeline): machine crashes "
        "with the given mean-time-between-failures in seconds (omit the "
        "value for one crash per epoch with --epoch, or one mid-run crash "
        "without it) — exercises watchdog detection, frame-conserving "
        "re-queue, failure replans, and warm-spare promotion",
    )
    ap.add_argument(
        "--trace", nargs="?", const="trace.json", default=None, metavar="PATH",
        help="enable the observability layer: print the per-epoch metrics "
        "table and the SLO-miss forensics report, and export a Chrome/"
        "Perfetto trace-event JSON to PATH (default trace.json) — load it "
        "at https://ui.perfetto.dev",
    )
    args = ap.parse_args()
    if args.epoch and not args.pipeline:
        ap.error("--epoch requires --pipeline (the control loop lives in "
                 "the pipelined serving loop)")
    if args.chaos is not None and not args.pipeline:
        ap.error("--chaos requires --pipeline (faults fire as events in "
                 "the pipelined serving loop)")

    archs = args.arch.split(",")
    profiles = {a: arch_profile(get_config(a), seq=args.seq) for a in archs}

    if args.pool:
        if args.compare:
            ap.error("--pool and --compare are mutually exclusive")
        _serve_pool(args, archs, profiles)
        return

    dag = AppDAG("session", series(*[Leaf(a) for a in archs]))
    wl = Workload(dag, {a: args.rate for a in archs}, args.slo)

    if args.compare:
        for opts in ALL_SYSTEMS:
            plan = Planner(opts).plan(wl, profiles)
            print(plan.summary())
        return

    plan = Planner().plan(wl, profiles)
    print(plan.summary())
    if not plan.feasible:
        raise SystemExit("infeasible workload")

    faults = _make_faults(args)

    executors = {}
    if args.real:
        for a in archs:
            cfg = get_config(a, smoke=True)
            model = Model(cfg)
            params = model.init(jax.random.key(0))
            fwd = jax.jit(lambda p, t, m=model: m.forward(p, t).logits)

            def ex(b, fwd=fwd, params=params, cfg=cfg):
                toks = jnp.zeros((b, 32), jnp.int32)
                fwd(params, toks).block_until_ready()

            ex(1)  # warm the jit cache
            executors[a] = ex

    engine = ServingEngine(plan, executors=executors)
    control = (
        ControlLoopConfig(interval=args.epoch, profiles=profiles)
        if args.epoch
        else None
    )
    if args.arrivals == "diurnal":
        # one full day/night cycle across the run: the rate swings around
        # the provisioned one, which is what gives the control plane (and
        # the miss forensics' epoch attribution) something to chase
        arrivals = trace_arrivals(
            args.requests, args.rate, seed=0, period=args.requests / args.rate
        )
    else:
        arrivals = args.arrivals
    res = engine.run(
        args.requests,
        args.rate,
        arrivals=arrivals,
        pipeline=args.pipeline,
        control=control,
        service_time="live" if (args.real and args.pipeline) else None,
        observability=args.trace is not None,
        faults=faults,
    )
    print(
        f"served {len(res.e2e_latencies)} requests: SLO attainment "
        f"{100 * res.attainment:.2f}%  p99={res.p99:.4f}s  slo={args.slo}s"
    )
    if res.faults is not None:
        print(
            f"  chaos: {res.faults['injected']} faults injected, "
            f"{res.faults['killed']} machines declared dead, "
            f"{res.faults['requeued']} frames re-queued to survivors"
        )
    for m, st in res.module_stats.items():
        print(f"  {m}: batches={st.batches} max_latency={st.max_latency:.4f}s")
    if res.epochs:
        # the control loop's model-vs-measured audit: mean relative
        # |measured - modeled| service time per epoch, plus the profile
        # corrections the replan ran under
        for e in res.epochs:
            corr = (
                " corrections=" + ",".join(
                    f"{m}:{s:.2f}" for m, s in sorted(e.corrections.items())
                )
                if e.corrections
                else ""
            )
            print(
                f"  epoch t={e.t:8.3f}s target={e.target:8.1f}/s "
                f"cost={e.cost:7.1f} duration_err={e.duration_err:.3f}{corr}"
            )
    if args.trace is not None:
        if res.metrics is not None and res.metrics.rows:
            print(res.metrics.table())
        if res.pipeline is not None:
            print(res.miss_report().table())
        if res.trace is not None:
            path = res.trace.export(args.trace)
            n_ev = len(res.trace.events())
            print(
                f"wrote {n_ev} trace events to {path} "
                f"(load at https://ui.perfetto.dev)"
            )


if __name__ == "__main__":
    main()
