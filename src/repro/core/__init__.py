"""Harpagon's core: dispatching, scheduling and latency splitting (the paper)."""
from .dag import AppDAG, Leaf, Par, Series, Workload, par, series
from .dispatch import Alloc, Policy, config_wcl, module_wcl, total_cost
from .harpagon import Plan, Planner, PlannerOptions, plan
from .profiles import Config, Hardware, ModuleProfile, TABLE1
from .residual import ModuleSchedule, schedule_module
from .scheduler import generate_config, generate_config_ktuple

__all__ = [
    "AppDAG",
    "Alloc",
    "Config",
    "Hardware",
    "Leaf",
    "ModuleProfile",
    "ModuleSchedule",
    "Par",
    "Plan",
    "Planner",
    "PlannerOptions",
    "Policy",
    "Series",
    "TABLE1",
    "Workload",
    "config_wcl",
    "generate_config",
    "generate_config_ktuple",
    "module_wcl",
    "par",
    "plan",
    "schedule_module",
    "series",
    "total_cost",
]
