"""Deprecated alias of :mod:`repro.profiling.analytic`.

The parameter / FLOPs / KV-cache accounting that used to live here was
merged into ``analytic.py`` (the two names kept drifting apart by one
letter while covering the same analytic chain).  This shim re-exports the
public surface so existing imports keep working; new code should import
from ``repro.profiling.analytic`` (or the ``repro.profiling`` package
root) directly.
"""
from __future__ import annotations

from .analytic import (  # noqa: F401
    flops_per_token,
    kv_cache_bytes_per_token,
    layer_flops_per_token,
    layer_params,
    param_count,
)

__all__ = [
    "flops_per_token",
    "kv_cache_bytes_per_token",
    "layer_flops_per_token",
    "layer_params",
    "param_count",
]
