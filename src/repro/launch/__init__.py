# NOTE: do not import .dryrun here — it mutates XLA_FLAGS on import and must
# only be used as a dedicated entrypoint (python -m repro.launch.dryrun).
from .mesh import dp_axes, make_debug_mesh, make_production_mesh, model_axis

__all__ = ["dp_axes", "make_debug_mesh", "make_production_mesh", "model_axis"]
