"""Shared decoder layers: norms, RoPE / M-RoPE, GQA + MLA attention, gated MLPs.

Everything is a pure function over parameter pytrees (plain dicts); no flax.
Attention math is delegated to `repro.kernels.ops` so the same model runs the
jnp oracle on CPU and the Pallas kernels on TPU.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerSpec
from ..kernels import ops

Params = dict[str, Any]


# --------------------------------------------------------------------- init
def _dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False) -> Params:
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (d_in ** -0.5)
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(cfg: ArchConfig, d: int, dtype) -> Params:
    if cfg.norm == "ln":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    # gemma-style (1 + w) stores zeros
    w = jnp.zeros((d,), dtype) if cfg.gemma_norm else jnp.ones((d,), dtype)
    return {"w": w}


def apply_norm(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "ln":
        x32 = x.astype(jnp.float32)
        mu = x32.mean(-1, keepdims=True)
        var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + 1e-5)
        return (y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)
    return ops.rmsnorm(x, p["w"], gemma=cfg.gemma_norm)


# --------------------------------------------------------------------- RoPE
def _rope_angles(pos: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """pos (..., S) -> cos/sin (..., S, dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = pos.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jax.Array,  # (B, S, H, D)
    positions: jax.Array,  # (B, S) or (3, B, S) for M-RoPE
    theta: float,
    mrope_sections: tuple[int, ...] | None = None,
) -> jax.Array:
    D = x.shape[-1]
    if mrope_sections is None:
        cos, sin = _rope_angles(positions, D, theta)  # (B, S, D/2)
    else:
        # Qwen2-VL M-RoPE: the D/2 rotary frequencies are split into
        # (temporal, height, width) sections, each driven by its own 1-D
        # position stream.  Text tokens carry identical t/h/w positions, so
        # M-RoPE degenerates to 1-D RoPE for them.
        assert positions.ndim == 3 and sum(mrope_sections) == D // 2
        cos_full, sin_full = _rope_angles(positions, D, theta)  # (3, B, S, D/2)
        chunks_c, chunks_s = [], []
        off = 0
        for i, sec in enumerate(mrope_sections):
            chunks_c.append(cos_full[i, ..., off : off + sec])
            chunks_s.append(sin_full[i, ..., off : off + sec])
            off += sec
        cos = jnp.concatenate(chunks_c, -1)
        sin = jnp.concatenate(chunks_s, -1)
    cos = cos[:, :, None, :]  # (B, S, 1, D/2)
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def attn_init(key, cfg: ArchConfig, dtype) -> Params:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    ks = jax.random.split(key, 6)
    p = {
        "q": _dense_init(ks[0], d, H * Dh, dtype, bias=cfg.qkv_bias),
        "k": _dense_init(ks[1], d, Hkv * Dh, dtype, bias=cfg.qkv_bias),
        "v": _dense_init(ks[2], d, Hkv * Dh, dtype, bias=cfg.qkv_bias),
        "o": _dense_init(ks[3], H * Dh, d, dtype),
    }
    if cfg.qk_norm:
        p["qn"] = {"w": jnp.ones((Dh,), dtype)}
        p["kn"] = {"w": jnp.ones((Dh,), dtype)}
    return p


def _qk_norm(cfg: ArchConfig, p: Params, q: jax.Array, k: jax.Array):
    if not cfg.qk_norm:
        return q, k
    return (
        ops.rmsnorm(q, p["qn"]["w"], gemma=cfg.gemma_norm),
        ops.rmsnorm(k, p["kn"]["w"], gemma=cfg.gemma_norm),
    )


def attn_cache_init(cfg: ArchConfig, spec: LayerSpec, batch: int, max_seq: int, dtype):
    size = min(max_seq, spec.window) if spec.window else max_seq
    Hkv, Dh = cfg.n_kv_heads, cfg.hdim
    return {
        "k": jnp.zeros((batch, size, Hkv, Dh), dtype),
        "v": jnp.zeros((batch, size, Hkv, Dh), dtype),
    }


def attn_forward(
    p: Params,
    cfg: ArchConfig,
    spec: LayerSpec,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,
    *,
    cache: Params | None = None,
    idx: jax.Array | None = None,  # scalar cache fill level (decode)
) -> tuple[jax.Array, Params | None]:
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    theta = spec.rope_theta or cfg.rope_theta
    q = dense(p["q"], x).reshape(B, S, H, Dh)
    k = dense(p["k"], x).reshape(B, S, Hkv, Dh)
    v = dense(p["v"], x).reshape(B, S, Hkv, Dh)
    q, k = _qk_norm(cfg, p, q, k)
    q = apply_rope(q, positions, theta, cfg.mrope_sections)
    k = apply_rope(k, positions, theta, cfg.mrope_sections)

    if cache is None:  # train / prefill without cache
        out = ops.attention(q, k, v, causal=True, window=spec.window)
        new_cache = None
    elif S > 1:  # prefill into cache
        size = cache["k"].shape[1]
        k_in, v_in = k[:, -size:], v[:, -size:]
        if spec.window and S > size:
            # ring buffer: absolute position p lives in slot p % size
            k_in = jnp.roll(k_in, S % size, axis=1)
            v_in = jnp.roll(v_in, S % size, axis=1)
        kc = jax.lax.dynamic_update_slice(cache["k"], k_in, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v_in, (0, 0, 0, 0))
        out = ops.attention(q, k, v, causal=True, window=spec.window)
        new_cache = {"k": kc, "v": vc}
    else:  # single-token decode
        size = cache["k"].shape[1]
        write = idx % size if spec.window else jnp.minimum(idx, size - 1)
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, write, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, write, 0, 0))
        lengths = jnp.full((B,), jnp.minimum(idx + 1, size), jnp.int32)
        ring = spec.window is not None
        out = ops.decode_attention(
            q[:, 0],
            kc,
            vc,
            lengths,
            window=None if ring else spec.window,
        )[:, None]
        new_cache = {"k": kc, "v": vc}
    y = ops.row_parallel_dense(out.reshape(B, S, H * Dh), p["o"]["w"])
    return y, new_cache


# ---------------------------------------------------------------- MLA (deepseek)
def mla_init(key, cfg: ArchConfig, dtype) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    dq, dc, dr = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
    dn, dv = cfg.hdim, cfg.vdim
    ks = jax.random.split(key, 8)
    p: Params = {
        "kv_a": _dense_init(ks[2], d, dc + dr, dtype),  # down-proj + shared k_rope
        "kv_norm": {"w": jnp.ones((dc,), dtype)},
        "k_b": _dense_init(ks[3], dc, H * dn, dtype),  # W_UK
        "v_b": _dense_init(ks[4], dc, H * dv, dtype),  # W_UV
        "o": _dense_init(ks[5], H * dv, d, dtype),
    }
    if dq:
        p["q_a"] = _dense_init(ks[0], d, dq, dtype)
        p["q_norm"] = {"w": jnp.ones((dq,), dtype)}
        p["q_b"] = _dense_init(ks[1], dq, H * (dn + dr), dtype)
    else:
        p["q_b"] = _dense_init(ks[1], d, H * (dn + dr), dtype)
    return p


def mla_cache_init(cfg: ArchConfig, batch: int, max_seq: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype),
    }


def _mla_q(p: Params, cfg: ArchConfig, x, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.hdim, cfg.rope_head_dim
    if "q_a" in p:
        qa = ops.rmsnorm(dense(p["q_a"], x), p["q_norm"]["w"])
        q = dense(p["q_b"], qa)
    else:
        q = dense(p["q_b"], x)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Params | None = None,
    idx: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    """Multi-head Latent Attention.  Prefill runs the naive (expanded) form;
    decode runs the absorbed form against the compressed cache — a single
    MQA-style flash-decode with K = [c_kv ; k_rope], V = c_kv."""
    B, S, _ = x.shape
    H, dn, dv = cfg.n_heads, cfg.hdim, cfg.vdim
    dc, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    scale = (dn + dr) ** -0.5
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    kv = dense(p["kv_a"], x)
    ckv = ops.rmsnorm(kv[..., :dc], p["kv_norm"]["w"])
    kr = apply_rope(kv[..., dc:][:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if S > 1 or cache is None:
        # naive form: expand per-head K/V from the latent; the head-concat of
        # the rope halves happens inside the (possibly shard_mapped) op
        k_nope = dense(p["k_b"], ckv).reshape(B, S, H, dn)
        vfull = dense(p["v_b"], ckv).reshape(B, S, H, dv)
        out = ops.mla_prefill_attention(q_nope, q_rope, k_nope, kr, vfull, scale=scale)
        new_cache = None
        if cache is not None:
            size = cache["ckv"].shape[1]
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv[:, -size:], (0, 0, 0)),
                "kr": jax.lax.dynamic_update_slice(cache["kr"], kr[:, -size:], (0, 0, 0)),
            }
    else:
        # absorbed decode: q' = q_nope @ W_UK  ->  (B, H, dc)
        wk = p["k_b"]["w"].astype(jnp.float32).reshape(dc, H, dn)
        q_abs = jnp.einsum("bhd,chd->bhc", q_nope[:, 0].astype(jnp.float32), wk)
        q_cat = jnp.concatenate([q_abs.astype(x.dtype), jnp.broadcast_to(
            q_rope[:, 0], (B, H, dr))], -1)  # (B, H, dc + dr)
        ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, idx, 0))
        kr_c = jax.lax.dynamic_update_slice(cache["kr"], kr, (0, idx, 0))
        kcat = jnp.concatenate([ckv_c, kr_c], -1)[:, :, None, :]  # MQA: 1 kv head
        lengths = jnp.full((B,), idx + 1, jnp.int32)
        ctx = ops.decode_attention(
            q_cat, kcat, ckv_c[:, :, None, :], lengths, scale=scale
        )  # (B, H, dc)
        wv = p["v_b"]["w"].astype(jnp.float32).reshape(dc, H, dv)
        out = jnp.einsum("bhc,chd->bhd", ctx.astype(jnp.float32), wv).astype(x.dtype)
        out = out[:, None]  # (B, 1, H, dv)
        new_cache = {"ckv": ckv_c, "kr": kr_c}
    y = ops.row_parallel_dense(out.reshape(B, S, H * dv), p["o"]["w"])
    return y, new_cache


# --------------------------------------------------------------------- MLP
def mlp_init(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": _dense_init(k1, d, d_ff, dtype),  # gate
        "w3": _dense_init(k2, d, d_ff, dtype),  # up
        "w2": _dense_init(k3, d_ff, d, dtype),  # down
    }


def mlp_forward(p: Params, x: jax.Array, act: str) -> jax.Array:
    g = dense(p["w1"], x)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    h = g * dense(p["w3"], x)
    return ops.row_parallel_dense(h, p["w2"]["w"])


# --------------------------------------------------------------- embeddings
def embed_init(key, cfg: ArchConfig, dtype) -> Params:
    w = jax.random.normal(key, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
    return {"w": w.astype(dtype)}


def embed(p: Params, cfg: ArchConfig, tokens: jax.Array, compute_dtype) -> jax.Array:
    x = p["w"].astype(compute_dtype)[tokens]
    if cfg.gemma_norm:
        x = x * math.sqrt(cfg.d_model)
    return x
