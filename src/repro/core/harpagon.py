"""The Harpagon planner: dispatch model ∘ latency splitting ∘ module scheduling.

``Planner`` composes the three levels of the paper (Fig. 3):

1. pick the dispatch policy (which fixes every L_wc estimate),
2. split the end-to-end SLO into per-module budgets (Sec. III-D),
3. schedule each module with Algorithm 1 + residual optimizers (Sec. III-C),
4. reassign leftover end-to-end latency to residual workloads (Sec. III-C).

Every baseline system and every Harp-* ablation of the paper is an options
preset over the same composition (see `repro.core.baselines`).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, replace
from typing import Mapping

from .dag import Workload
from .dispatch import Policy
from .profiles import ModuleProfile
from .residual import ModuleSchedule, apply_reassign, schedule_module
from . import splitter as sp

_EPS = 1e-9


@dataclass(frozen=True)
class PlannerOptions:
    name: str = "harpagon"
    policy: Policy = Policy.TC
    k_tuples: int | None = None          # None = multi-tuple (Algorithm 1)
    split: str = "lc"                    # lc | throughput | even | quantized
    quantize: float = 0.01               # interval for split="quantized"
    node_merge: bool = True
    cost_direct: bool = True
    use_dummy: bool = True
    reassign: int = 10 ** 6              # max reassigner iterations (0 / 1 / many)
    hardware: str | None = None          # None=all, "cheapest", "most_expensive"
    max_batch: int | None = None         # 1 => batching disabled (Harp-nb)
    headroom: float = 0.0                # provision machines at t*(1-headroom):
    #   slack absorbs timeout-flushed partial batches (multi-tuple scheduler
    #   only; 0.0 = paper's zero-slack pacing).  Costs ~1/(1-headroom) more.


@dataclass(frozen=True)
class Plan:
    workload: Workload
    options: PlannerOptions
    schedules: Mapping[str, ModuleSchedule]
    feasible: bool
    runtime_s: float

    @property
    def cost(self) -> float:
        if not self.feasible:
            return math.inf
        return sum(s.cost for s in self.schedules.values())

    @property
    def e2e_latency(self) -> float:
        if not self.feasible:
            return math.inf
        return self.workload.app.latency({m: s.wcl for m, s in self.schedules.items()})

    def summary(self) -> str:
        hr = f" headroom={self.options.headroom:g}" if self.options.headroom else ""
        lines = [
            f"plan[{self.options.name}] app={self.workload.app.name} slo={self.workload.slo}"
            f" feasible={self.feasible} cost={self.cost:.4g} e2e={self.e2e_latency:.4g}"
            f"{hr} runtime={self.runtime_s * 1e3:.2f}ms"
        ]
        for m, s in self.schedules.items():
            dummy = f" dummy={s.dummy:.3g}" if s.dummy else ""
            lines.append(
                f"  {m}: rate={s.rate:.4g}{dummy} budget={s.budget:.4g} "
                f"wcl={s.wcl:.4g} cost={s.cost:.4g} allocs={list(s.allocs)}"
            )
        return "\n".join(lines)


_INFEASIBLE = object()


class Planner:
    def __init__(self, options: PlannerOptions | None = None):
        self.options = options or PlannerOptions()

    # -- profile preparation -------------------------------------------------
    def _profiles(
        self, profiles: Mapping[str, ModuleProfile]
    ) -> Mapping[str, ModuleProfile] | None:
        o = self.options
        out = {}
        for m, p in profiles.items():
            hw = None
            if o.hardware == "cheapest":
                hw = [p.cheapest_hardware()]
            elif o.hardware == "most_expensive":
                hw = [p.most_expensive_hardware()]
            p = p.restrict(max_batch=o.max_batch, hardware=hw)
            if not p.configs:
                return None
            out[m] = p
        return out

    # -- splitting ------------------------------------------------------------
    def _split_with(
        self, wl: Workload, profiles: Mapping[str, ModuleProfile], split: str
    ) -> dict[str, float] | None:
        o = self.options
        if split in ("lc", "lc_int"):
            return sp.split_lc(
                wl,
                profiles,
                o.policy,
                node_merge=o.node_merge,
                cost_direct=o.cost_direct,
                integer_tails=split == "lc_int",
            )
        if split == "throughput":
            return sp.split_throughput(wl, profiles, o.policy)
        if split in ("even", "even_int"):
            return sp.split_even(
                wl, profiles, o.policy, integer_tails=split == "even_int"
            )
        if split == "quantized":
            return sp.split_quantized(wl, profiles, o.policy, q=o.quantize)
        raise ValueError(f"unknown splitter {split}")

    # -- full pipeline ---------------------------------------------------------
    def plan(self, wl: Workload, profiles: Mapping[str, ModuleProfile]) -> Plan:
        """Split -> schedule -> residual-optimize.

        Per the paper (Fig. 3) the module scheduler and latency splitter
        iterate: when the LC split's fractionally-tight budgets turn out to
        be integer-unschedulable, Harpagon retries with progressively looser
        splitting strategies and keeps the cheapest feasible plan.
        """
        t0 = time.perf_counter()
        o = self.options
        best: Plan | None = None
        cascade = [o.split]
        if o.split == "lc":
            # schedule-aware refinement (paper Fig. 3's scheduler<->splitter
            # iteration): looser heuristics + integer-tail-aware budgets
            cascade += ["throughput", "lc_int", "even_int"]
        for split in cascade:
            plan = self._plan_with_split(wl, profiles, split, t0)
            if plan.feasible and (best is None or plan.cost < best.cost - 1e-12):
                best = plan
        if best is not None:
            return best
        return Plan(wl, o, {}, False, time.perf_counter() - t0)

    def _plan_with_split(
        self,
        wl: Workload,
        profiles: Mapping[str, ModuleProfile],
        split: str,
        t0: float,
    ) -> Plan:
        o = self.options
        restricted = self._profiles(profiles)
        if restricted is None:
            return Plan(wl, o, {}, False, time.perf_counter() - t0)
        budgets = self._split_with(wl, restricted, split)
        if budgets is None:
            return Plan(wl, o, {}, False, time.perf_counter() - t0)

        # per-module scheduling (Algorithm 1 / k-tuple variants + dummy)
        schedules: dict[str, ModuleSchedule] = {}
        gap = wl.slo - wl.app.latency(budgets)
        for m in wl.app.modules:
            s = schedule_module(
                m,
                wl.rates[m],
                budgets[m],
                restricted[m],
                o.policy,
                use_dummy=o.use_dummy and o.k_tuples is None,
                k_tuples=o.k_tuples,
                headroom=o.headroom,
            )
            if s is None and gap > _EPS:
                # fallback: spend the global slack on this module's budget
                s = schedule_module(
                    m,
                    wl.rates[m],
                    budgets[m] + gap,
                    restricted[m],
                    o.policy,
                    use_dummy=o.use_dummy and o.k_tuples is None,
                    k_tuples=o.k_tuples,
                    headroom=o.headroom,
                )
                if s is not None:
                    gap = max(0.0, gap - max(0.0, s.wcl - budgets[m]))
            if s is None:
                return Plan(wl, o, {}, False, time.perf_counter() - t0)
            schedules[m] = s

        # latency reassigner: hand the remaining end-to-end gap to residuals
        if o.reassign > 0 and o.k_tuples is None:
            self._reassign(wl, restricted, schedules)

        e2e = wl.app.latency({m: s.wcl for m, s in schedules.items()})
        feasible = e2e <= wl.slo + 1e-6
        return Plan(wl, o, schedules, feasible, time.perf_counter() - t0)

    def _reassign(
        self,
        wl: Workload,
        profiles: Mapping[str, ModuleProfile],
        schedules: dict[str, ModuleSchedule],
    ) -> None:
        o = self.options
        for _ in range(min(o.reassign, 64)):
            e2e = wl.app.latency({m: s.wcl for m, s in schedules.items()})
            gap = wl.slo - e2e
            if gap <= 1e-9:
                return
            best: tuple[float, str, ModuleSchedule] | None = None
            for m, s in schedules.items():
                new_allocs, _over = apply_reassign(
                    s.rate + s.dummy, s.budget, gap, profiles[m], list(s.allocs),
                    o.policy, headroom=o.headroom,
                )
                cand = replace(s, allocs=tuple(new_allocs))
                dcost = s.cost - cand.cost
                if dcost <= 1e-12:
                    continue
                # feasibility: the module's wcl may grow, re-check end-to-end
                trial = {
                    k: (cand.wcl if k == m else v.wcl) for k, v in schedules.items()
                }
                if wl.app.latency(trial) <= wl.slo + 1e-9 and (
                    best is None or dcost > best[0]
                ):
                    best = (dcost, m, cand)
            if best is None:
                return
            schedules[best[1]] = best[2]


def plan(wl: Workload, profiles: Mapping[str, ModuleProfile], options: PlannerOptions | None = None) -> Plan:
    return Planner(options).plan(wl, profiles)
