"""Kahn toposort (`core.dag.topo_sort`): deep/wide DAGs, determinism, cycles."""
import random

import pytest

from repro.core.dag import AppDAG, Leaf, par, series, topo_sort


def _assert_topological(order, nodes, edges):
    assert sorted(order) == sorted(nodes)
    pos = {m: i for i, m in enumerate(order)}
    for u, v in edges:
        assert pos[u] < pos[v], (u, v)


def test_deep_chain():
    n = 500
    nodes = [f"m{i}" for i in range(n)]
    edges = [(f"m{i}", f"m{i+1}") for i in range(n - 1)]
    shuffled = nodes[:]
    random.Random(0).shuffle(shuffled)
    _assert_topological(topo_sort(shuffled, edges), nodes, edges)


def test_wide_diamond_deterministic():
    mid = [f"p{i}" for i in range(300)]
    nodes = ["src"] + mid + ["sink"]
    edges = [("src", p) for p in mid] + [(p, "sink") for p in mid]
    order = topo_sort(nodes, edges)
    _assert_topological(order, nodes, edges)
    # among simultaneously-ready nodes, input order is preserved
    assert order == nodes
    assert topo_sort(nodes, edges) == order


def test_random_layered_dag():
    rng = random.Random(7)
    layers = [[f"l{d}_{i}" for i in range(rng.randint(2, 8))] for d in range(12)]
    nodes = [m for layer in layers for m in layer]
    edges = []
    for a, b in zip(layers, layers[1:]):
        for v in b:
            for u in rng.sample(a, k=rng.randint(1, len(a))):
                edges.append((u, v))
    shuffled = nodes[:]
    rng.shuffle(shuffled)
    _assert_topological(topo_sort(shuffled, edges), nodes, edges)


def test_cycle_detection():
    with pytest.raises(ValueError, match="cycle"):
        topo_sort(["a", "b", "c"], [("a", "b"), ("b", "c"), ("c", "a")])
    with pytest.raises(ValueError, match="cycle"):
        topo_sort(["a"], [("a", "a")])
    # cycle hanging off an acyclic prefix
    with pytest.raises(ValueError, match="cycle"):
        topo_sort(["a", "b", "c"], [("a", "b"), ("b", "c"), ("c", "b")])


def test_unknown_node_in_edge():
    with pytest.raises(ValueError, match="unknown"):
        topo_sort(["a"], [("a", "zz")])


def test_appdag_topo_order():
    app = AppDAG("t", series(Leaf("a"), par(Leaf("b"), Leaf("c")), Leaf("d")))
    order = app.topo_order()
    _assert_topological(order, app.modules, app.edges)
