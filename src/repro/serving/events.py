"""Discrete-event core of the serving simulator (reference semantics).

One module = a set of machines fed by a dispatcher.  The dispatcher's static
request->machine assignment is computed up front (`core.dispatch`); what this
core simulates is *batch formation and service* with real deadline semantics:

* a machine's batch **opens** when a request lands in its empty formation
  buffer, **closes** when it reaches the configured batch size — or, with a
  finite ``timeout``, when the opener has waited ``timeout`` seconds (partial
  flush, exactly what a real frontend does because it cannot know whether
  more requests are coming);
* closed batches queue FIFO at the machine; service takes the profiled
  duration (or a real measured executor call) and the machine frees.

The per-machine mechanics live in :class:`MachineCore` — a composable stage
brick with no event loop of its own.  Two owners drive it: the single-module
reference loop below (`simulate_module_events`, one priority queue over
arrival / batch-flush / machine-free events) and the multi-module pipelined
co-simulation (`repro.serving.pipeline`), where many cores across DAG stages
share one global event loop and upstream batch completions feed downstream
formation buffers.

This is the *reference* implementation: it supports real executors and
arbitrary arrival patterns, and the vectorized hot path
(`repro.serving.replay`) is property-tested to agree with it.  End-of-stream
handling when ``timeout is None`` is governed by ``tail``:

* ``"flush"`` — execute the partial tail batch as soon as its last request
  has arrived (the seed engine's behavior);
* ``"drop"``  — discard tail requests (the seed simulator's behavior, i.e.
  steady-state-only accounting).
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Callable, Mapping, Sequence

import numpy as np

from ..core.dispatch import Machine

_ARRIVE, _FLUSH, _FREE = 0, 1, 2


class MachineCore:
    """Batch formation + FIFO service state of ONE machine.

    The owner's event loop calls into it; the core never touches a heap
    itself, which is what makes it composable across stages:

    * :meth:`add` appends a member to the open formation buffer and returns
      a flush deadline to arm when this member is the batch's first *real*
      request (phantoms fill slots but never arm deadlines — the deadline
      exists to bound real latency);
    * :meth:`close` moves the buffer to the FIFO service queue and bumps
      ``token`` so stale flush events become void;
    * :meth:`start` pops the next queued batch when the machine is idle and
      returns its completion time — the owner schedules the free event;
    * :meth:`free` / :meth:`discard` complete the lifecycle.

    Members are opaque to the core (request ids here, per-frame instance
    entities in the pipelined co-simulation).

    A core can be marked ``draining`` (control-plane hot swap): the owner
    stops dispatching new members to it, its already-queued batches run to
    completion, and once :attr:`drained` it holds no work and can be
    retired — no in-flight member is ever dropped by a drain.
    """

    __slots__ = (
        "machine", "timeout", "buf", "token", "armed", "armed_at", "queue",
        "free_at", "busy", "draining", "failed", "n_closed", "n_done",
    )

    def __init__(self, machine: Machine, timeout: "float | None" = None):
        self.machine = machine
        self.timeout = timeout
        self.buf: list = []          # open formation buffer
        self.token = 0               # bumped on close; voids stale flush events
        self.armed = False           # a flush deadline exists for the open batch
        self.armed_at = 0.0          # when it was armed (deadline re-anchor)
        self.queue: deque = deque()  # closed batches: (batch_ready, members)
        self.free_at = 0.0
        self.busy = False
        self.draining = False        # excluded from dispatch; finishes its work
        self.failed = False          # fenced dead (fault injection); never serves
        self.n_closed = 0            # batches closed — watchdog heartbeat seq
        self.n_done = 0              # batches whose service completed

    @property
    def drained(self) -> bool:
        """True when the core holds no work at any lifecycle stage."""
        return not self.buf and not self.queue and not self.busy

    def add(self, member, t: float, is_real: bool) -> "float | None":
        """Append one member at time ``t``; returns a deadline to arm (the
        first REAL member of an un-armed batch under a finite timeout)."""
        self.buf.append(member)
        if is_real and not self.armed and self.timeout is not None:
            self.armed = True
            self.armed_at = t
            return t + self.timeout
        return None

    @property
    def full(self) -> bool:
        return len(self.buf) >= self.machine.config.batch

    def close(self, batch_ready: float) -> None:
        """Move the open buffer to the service queue (fill or flush)."""
        self.queue.append((batch_ready, self.buf))
        self.buf = []
        self.token += 1
        self.armed = False
        self.n_closed += 1

    def retime(self, timeout: "float | None") -> "float | None":
        """Change the open batch's flush deadline in place (control-plane
        deadline relaxation).  The token bump voids any pending flush event;
        returns the new deadline re-anchored at ``armed_at`` for the owner
        to push (None: nothing armed, or deadlines now disabled)."""
        self.timeout = timeout
        if not self.armed:
            return None
        self.token += 1
        if timeout is None:
            self.armed = False
            return None
        return self.armed_at + timeout

    def discard(self) -> list:
        """Drop the open buffer (end-of-stream leftovers); returns it."""
        dropped, self.buf = self.buf, []
        self.token += 1
        self.armed = False
        return dropped

    def start(self, now: float, duration: Callable[[list], float]) -> "tuple[float, list] | None":
        """Start the next queued batch if idle; returns ``(end, members)``.

        ``duration(members)`` supplies the service time (profiled constant or
        a real measured executor call); the owner schedules the free event at
        ``end`` and records per-member completion.
        """
        if self.busy or self.failed or not self.queue:
            return None
        batch_ready, members = self.queue.popleft()
        start = max(batch_ready, self.free_at, now)
        end = start + duration(members)
        self.busy = True
        return end, members

    def free(self, t: float) -> None:
        self.busy = False
        self.free_at = t

    def fail(self) -> list:
        """Machine death: fence the core and surrender its unfinished work.

        Returns every member held in the open formation buffer and the
        queued (closed, not yet started) batches — the in-service batch is
        the owner's to reclaim, since the owner tracks started members
        against its own free event.  The token bump voids pending flush
        events; ``failed`` voids pending free events (the owner checks it)
        and refuses any future start.  A failed core reads as
        ``draining`` + ``drained`` so the next plan hot-swap retires it
        without ever reviving it.
        """
        members = list(self.buf)
        self.buf = []
        for _, batch in self.queue:
            members.extend(batch)
        self.queue.clear()
        self.token += 1
        self.armed = False
        self.busy = False
        self.failed = True
        self.draining = True
        return members


def simulate_module_events(
    machines: Sequence[Machine],
    ready: np.ndarray,
    assignment: np.ndarray,
    *,
    timeout: "float | None | Mapping[int, float]" = None,
    tail: str = "flush",
    executor: Callable[[Machine, int], float] | None = None,
    phantom: np.ndarray | None = None,
    on_batch: "Callable[[Machine, float, float, list], None] | None" = None,
) -> tuple[np.ndarray, dict[int, int]]:
    """Simulate one module; returns ``(finish, batches_per_machine)``.

    ``ready`` is the per-request ready time in causal order (plain sorted
    when no upstream tail cascades are present); ``assignment[i]`` the
    machine id serving request ``i``.  ``timeout`` may be a single deadline
    or a per-machine-id mapping.  ``finish[i]`` is the absolute completion
    time (``np.nan`` for dropped tail requests).  ``executor`` (when given)
    is called at each batch start with ``(machine, group_size)`` and must
    return the measured service duration in seconds.

    ``phantom`` marks frontend dummy requests.  They occupy batch slots and
    are executed with the batch (an executor sees the full batch size), but
    a flush deadline is armed only when a *real* request lands in the
    formation buffer, and a leftover buffer holding only phantoms is
    discarded at end of stream instead of flushed.

    ``on_batch`` (when given) is a passive observer called at every batch
    start with ``(machine, start, end, members)`` — the observability
    layer's per-batch span feed; it never influences the simulation.
    """
    if tail not in ("flush", "drop"):
        raise ValueError(f"unknown tail policy {tail!r}")
    if isinstance(timeout, Mapping):
        timeouts = {m.mid: timeout.get(m.mid) for m in machines}
    else:
        timeouts = {m.mid: timeout for m in machines}
    ready = np.asarray(ready, dtype=np.float64)
    n = ready.size
    real = np.ones(n, dtype=bool) if phantom is None else ~np.asarray(phantom, bool)
    finish = np.full(n, np.nan)
    cores = {m.mid: MachineCore(m, timeouts[m.mid]) for m in machines}
    batches = {m.mid: 0 for m in machines}
    heap: list[tuple[float, int, int, int]] = []  # (time, kind, mid, payload)

    def start_next(mid: int, now: float) -> None:
        core = cores[mid]
        m = core.machine
        if on_batch is None:
            dur = (
                (lambda rids: executor(m, len(rids)))
                if executor is not None
                else (lambda rids: m.config.duration)
            )
        else:
            drawn: list[float] = []

            def dur(rids, _d=drawn) -> float:
                d = (
                    executor(m, len(rids))
                    if executor is not None
                    else m.config.duration
                )
                _d.append(d)
                return d

        started = core.start(now, dur)
        if started is None:
            return
        end, rids = started
        batches[mid] += 1
        finish[rids] = end
        if on_batch is not None:
            on_batch(m, end - drawn[0], end, rids)
        heapq.heappush(heap, (end, _FREE, mid, 0))

    def close_batch(mid: int, batch_ready: float, now: float) -> None:
        cores[mid].close(batch_ready)
        start_next(mid, now)

    ai = 0  # pointer into the (sorted) arrival stream
    tails_done = False
    while True:
        # merge the sorted arrival stream with the flush/free heap; arrivals
        # win ties (a request landing exactly at a deadline joins the batch)
        if ai < n and (not heap or (ready[ai], _ARRIVE) <= heap[0][:2]):
            t, rid = float(ready[ai]), ai
            ai += 1
            mid = int(assignment[rid])
            core = cores[mid]
            deadline = core.add(rid, t, bool(real[rid]))
            if deadline is not None:
                heapq.heappush(heap, (deadline, _FLUSH, mid, core.token))
            if core.full:
                close_batch(mid, batch_ready=t, now=t)
            continue
        if heap:
            t, kind, mid, payload = heapq.heappop(heap)
            if kind == _FLUSH:
                if payload == cores[mid].token and cores[mid].buf:
                    close_batch(mid, batch_ready=t, now=t)
            else:  # _FREE
                cores[mid].free(t)
                start_next(mid, now=t)
            continue
        if not tails_done:
            # stream over, queues drained: resolve leftover partial batches
            tails_done = True
            for mid, core in cores.items():
                buf = core.buf
                has_real = any(real[r] for r in buf)
                if buf and has_real and timeouts[mid] is None and tail == "flush":
                    # flush at the last REAL member's arrival: the frontend
                    # stops injecting phantoms once the stream ends, so
                    # trailing phantoms must not inflate real tail latency
                    # max over VALUES, not stream positions: under causal
                    # order a backdated cascade member may sit after the
                    # time-max one (identical for sorted streams)
                    t_last = max(float(ready[r]) for r in buf if real[r])
                    close_batch(mid, batch_ready=t_last, now=t_last)
                elif buf:
                    core.discard()  # drop (finish stays NaN)
            continue
        break
    return finish, batches
