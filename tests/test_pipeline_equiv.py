"""Macro-event pipeline core == event-by-event oracle loop (ISSUE-5).

The performance rebuild of the pipelined co-simulation (struct-of-arrays
frame state, bulk fanout delivery, bucketed calendar queue, segment
fast-path to the vectorized flat kernel) must be *result-invariant*:
``PipelineConfig(reference=True)`` pins the pre-macro-event loop (global
heapq, scalar per-instance delivery, no fast path) as the oracle, and every
test here demands BIT-identical per-frame records against it — per-frame
issue/e2e/avail/finish, shed/dropped/skipped masks, per-stage batch counts
and latency multisets, and (under a control loop) the epoch records.

Two regimes:

* fast-path-eligible runs (open loop, unbounded queues, deterministic
  fanout, no phantoms/admission/control) exercise the flat-kernel
  delegation — exact equality holds because the kernel's FIFO chain now
  evaluates in the event core's operation order;
* general-path runs (backpressure, stochastic fanout, dummy streaming,
  admission, closed-loop clients, control epochs, calendar queue) exercise
  the macro-event loop itself against the scalar loop.
"""
import numpy as np
import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.serving import ControlLoopConfig, ServingEngine
from repro.serving.frontend import ClosedLoopClients, FrontendConfig, TokenBucket
from repro.serving.pipeline import (
    CalendarQueue,
    FanoutSpec,
    HeapQueue,
    PipelineConfig,
    TCDispatcher,
)
from repro.workloads import synth_profiles
from repro.workloads.apps import ACTDET, CAPTION, FACE, TRAFFIC, make_workload

PROFILES = synth_profiles()
REF = PipelineConfig(reference=True)

_PLANS = {}


def suite_plan(app, rate, slo):
    key = (app.name, rate, slo)
    if key not in _PLANS:
        plan = Planner(B.HARPAGON).plan(make_workload(app, rate=rate, slo=slo), PROFILES)
        assert plan.feasible
        _PLANS[key] = plan
    return _PLANS[key]


def assert_bit_identical(a, b):
    """Every frame-level record of two ServeResults must agree exactly."""
    pa, pb = a.pipeline, b.pipeline
    assert pa.modules == pb.modules
    np.testing.assert_array_equal(pa.issue, pb.issue)
    np.testing.assert_array_equal(pa.e2e, pb.e2e)
    for m in pa.modules:
        np.testing.assert_array_equal(pa.avail[m], pb.avail[m], err_msg=m)
        np.testing.assert_array_equal(pa.finish[m], pb.finish[m], err_msg=m)
        assert pa.stats[m].batches == pb.stats[m].batches, m
        assert pa.stats[m].dropped == pb.stats[m].dropped, m
        assert pa.stats[m].phantom == pb.stats[m].phantom, m
        # the fast path records instance latencies in stream order, the
        # event loop in completion order: the multiset is the invariant
        np.testing.assert_array_equal(
            np.sort(pa.stats[m].latencies), np.sort(pb.stats[m].latencies), err_msg=m
        )
    np.testing.assert_array_equal(pa.shed, pb.shed)
    np.testing.assert_array_equal(pa.dropped, pb.dropped)
    np.testing.assert_array_equal(pa.skipped, pb.skipped)
    assert a.attempts == b.attempts
    assert (a.shed, a.dropped) == (b.shed, b.dropped)
    if a.epochs is not None or b.epochs is not None:
        assert a.epochs == b.epochs


# ------------------------------------------------ fast-path (flat delegation)


class TestFastPathBitExact:
    @pytest.mark.parametrize(
        "app,rate,slo",
        [(FACE, 150.0, 2.5), (TRAFFIC, 100.0, 2.0), (CAPTION, 90.0, 2.5),
         (ACTDET, 80.0, 3.0)],
    )
    @pytest.mark.parametrize("kind", ["uniform", "poisson", "mmpp"])
    def test_open_loop_matches_oracle(self, app, rate, slo, kind):
        eng = ServingEngine(suite_plan(app, rate, slo))
        for timeout in (None, "budget"):
            fast = eng.run(400, rate, arrivals=kind, seed=5, timeout=timeout,
                           pipeline=True)
            ref = eng.run(400, rate, arrivals=kind, seed=5, timeout=timeout,
                          pipeline=REF)
            assert_bit_identical(fast, ref)

    def test_tail_drop_matches_oracle(self):
        eng = ServingEngine(suite_plan(FACE, 150.0, 2.5))
        fast = eng.run(300, 150.0, arrivals="poisson", seed=2, tail="drop",
                       pipeline=True)
        ref = eng.run(300, 150.0, arrivals="poisson", seed=2, tail="drop",
                      pipeline=REF)
        assert_bit_identical(fast, ref)

    def test_fast_path_actually_engages(self):
        """The eligible default run must delegate (no scalar Instance churn):
        detectable through the loop-only attempt counter staying 0 and, more
        directly, `fastpath.eligible` holding on the engine-built stages."""
        from repro.serving.pipeline import fastpath

        plan = suite_plan(FACE, 150.0, 2.5)
        wl = plan.workload
        from repro.core.dispatch import expand_machines
        from repro.serving.pipeline import ModuleStage, make_stage_fanouts
        from repro.core.dag import topo_sort
        from repro.core.dispatch import Policy

        topo = topo_sort(wl.app.modules, wl.app.edges)
        fanouts = make_stage_fanouts(
            FanoutSpec(), {m: wl.rates[m] / 150.0 for m in topo},
            [m for m in topo if not wl.app.parents(m)], 100,
        )
        stages = {
            m: ModuleStage(
                m, expand_machines(list(plan.schedules[m].allocs)), Policy.TC,
                fanout=fanouts[m],
            )
            for m in topo
        }
        assert fastpath.eligible(wl.app, stages)
        stages[topo[0]].phantom_target = 10.0
        assert not fastpath.eligible(wl.app, stages)


# ------------------------------------------------ general path (macro-events)


class TestGeneralPathBitExact:
    """Regimes the fast path must refuse: the macro-event general loop
    (bulk delivery, optional calendar queue) against the scalar oracle."""

    def test_backpressure(self):
        eng = ServingEngine(suite_plan(FACE, 150.0, 2.5))
        for q in ("heap", "calendar"):
            new = eng.run(300, 150.0, arrivals="mmpp", seed=3,
                          pipeline=PipelineConfig(queue_cap=8, event_queue=q))
            ref = eng.run(300, 150.0, arrivals="mmpp", seed=3,
                          pipeline=PipelineConfig(queue_cap=8, reference=True))
            assert_bit_identical(new, ref)

    def test_stochastic_fanout(self):
        eng = ServingEngine(suite_plan(TRAFFIC, 100.0, 2.0))
        cfg = FanoutSpec(mode="stochastic", cv=0.6, correlation=0.7)
        new = eng.run(300, 100.0, arrivals="poisson", seed=4,
                      pipeline=PipelineConfig(fanout=cfg))
        ref = eng.run(300, 100.0, arrivals="poisson", seed=4,
                      pipeline=PipelineConfig(fanout=cfg, reference=True))
        assert_bit_identical(new, ref)

    def test_dummy_streaming_budget_timeout(self):
        eng = ServingEngine(suite_plan(FACE, 150.0, 2.5))
        fe = FrontendConfig(dummies=True)
        new = eng.run(300, 150.0, arrivals="poisson", seed=1, timeout="budget",
                      frontend=fe, pipeline=True)
        ref = eng.run(300, 150.0, arrivals="poisson", seed=1, timeout="budget",
                      frontend=fe, pipeline=REF)
        assert_bit_identical(new, ref)

    def test_admission_shedding(self):
        eng = ServingEngine(suite_plan(TRAFFIC, 100.0, 2.0))
        fe = FrontendConfig(admission=TokenBucket(rate=60.0, burst=3.0))
        new = eng.run(300, 100.0, arrivals="mmpp", seed=6,
                      offered_rate=130.0, frontend=fe, pipeline=True)
        ref = eng.run(300, 100.0, arrivals="mmpp", seed=6,
                      offered_rate=130.0, frontend=fe, pipeline=REF)
        assert new.shed > 0
        assert_bit_identical(new, ref)

    def test_closed_loop_clients(self):
        eng = ServingEngine(suite_plan(FACE, 150.0, 2.5))
        fe = FrontendConfig(clients=ClosedLoopClients(
            n_clients=32, think_time=0.05, retry_on_shed=True, backoff=0.01,
        ))
        for q in ("heap", "calendar"):
            new = eng.run(200, 150.0, frontend=fe, seed=2,
                          pipeline=PipelineConfig(event_queue=q))
            ref = eng.run(200, 150.0, frontend=fe, seed=2, pipeline=REF)
            assert_bit_identical(new, ref)

    def test_control_loop_epochs(self):
        plan = suite_plan(ACTDET, 80.0, 3.0)
        eng = ServingEngine(plan)
        ctrl = ControlLoopConfig(interval=1.0, profiles=PROFILES, margin=0.2)
        fe = FrontendConfig(dummies=True)
        new = eng.run(400, 80.0, arrivals="mmpp", seed=7, timeout="budget",
                      frontend=fe, pipeline=True, control=ctrl)
        ref = eng.run(400, 80.0, arrivals="mmpp", seed=7, timeout="budget",
                      frontend=fe, pipeline=REF, control=ctrl)
        assert new.epochs is not None and len(new.epochs) > 1
        assert_bit_identical(new, ref)

    def test_fast_path_off_still_exact_on_eligible_run(self):
        """fast_path=False keeps the macro-event general loop on an
        eligible run — still bit-identical, just slower (the bench knob)."""
        eng = ServingEngine(suite_plan(CAPTION, 90.0, 2.5))
        new = eng.run(300, 90.0, arrivals="poisson", seed=9,
                      pipeline=PipelineConfig(fast_path=False))
        ref = eng.run(300, 90.0, arrivals="poisson", seed=9, pipeline=REF)
        assert_bit_identical(new, ref)


# ------------------------------------------------ queue + dispatcher bricks


class TestEventQueueOrder:
    def test_calendar_serves_heap_order(self):
        rng = np.random.default_rng(0)
        heap, cal = HeapQueue(), CalendarQueue(quantum=0.37)
        seq = 0
        for _ in range(5):  # interleave pushes and pops
            for _ in range(400):
                t = float(rng.uniform(0, 100))
                kind = int(rng.integers(0, 4))
                entry = (t, kind, seq, None, ("payload", seq))
                heap.push(entry)
                cal.push(entry)
                seq += 1
            for _ in range(250):
                assert heap.peek() == cal.peek()
                assert heap.pop() == cal.pop()
        while heap:
            assert len(heap) == len(cal)
            assert heap.pop() == cal.pop()
        assert not cal and cal.peek() is None

    def test_same_quantum_ties_resolve_by_kind_then_seq(self):
        cal = CalendarQueue(quantum=1.0)
        cal.push((0.5, 1, 2, None, "b"))
        cal.push((0.5, 0, 3, None, "c"))
        cal.push((0.5, 1, 1, None, "a"))
        assert [cal.pop()[4] for _ in range(3)] == ["c", "a", "b"]

    def test_quantum_validation(self):
        with pytest.raises(ValueError):
            CalendarQueue(quantum=0.0)


class TestBulkDispatch:
    def test_tc_assign_run_matches_scalar(self):
        from repro.core.dispatch import Machine
        from repro.core.profiles import Config

        machines = [
            Machine(0, Config(8, 0.2), 40.0),
            Machine(1, Config(4, 0.15), 20.0),
            Machine(2, Config(4, 0.15), 6.5),
        ]
        rng = np.random.default_rng(1)
        a, b = TCDispatcher(machines), TCDispatcher(machines)
        got, want = [], []
        for _ in range(60):
            k = int(rng.integers(1, 13))
            for mid, cnt in a.assign_run(k):
                got.extend([mid] * cnt)
            want.extend(b.assign() for _ in range(k))
        assert got == want


# ------------------------------------------------ property sweep

_APPS = [
    (FACE, 150.0, 2.5), (TRAFFIC, 100.0, 2.0),
    (CAPTION, 90.0, 2.5), (ACTDET, 80.0, 3.0),
]


def check_combo(
    app_i, kind, seed, queue_cap, stochastic, correlation,
    control_on, dummies, budget, calendar,
):
    """One point of the equivalence property: default path == oracle, bit
    for bit, at an arbitrary feature combination."""
    app, rate, slo = _APPS[app_i]
    eng = ServingEngine(suite_plan(app, rate, slo))
    fanout = (
        FanoutSpec(mode="stochastic", cv=0.5, correlation=correlation)
        if stochastic
        else FanoutSpec()
    )
    kw = dict(
        arrivals=kind,
        seed=seed,
        timeout="budget" if budget else None,
        frontend=FrontendConfig(dummies=dummies),
        control=(
            ControlLoopConfig(interval=1.2, profiles=PROFILES, margin=0.2)
            if control_on
            else None
        ),
    )
    new = eng.run(
        160, rate,
        pipeline=PipelineConfig(
            fanout=fanout, queue_cap=queue_cap,
            event_queue="calendar" if calendar else "heap",
        ),
        **kw,
    )
    ref = eng.run(
        160, rate,
        pipeline=PipelineConfig(fanout=fanout, queue_cap=queue_cap, reference=True),
        **kw,
    )
    assert_bit_identical(new, ref)


# deterministic slice of the property (always runs, hypothesis or not):
# backpressure x control x correlated fanout x dummies x budget x queue
_COMBOS = [
    # app, kind, seed, cap, stoch, rho, control, dummies, budget, calendar
    (0, "uniform", 0, None, False, 1.0, False, False, False, False),
    (1, "mmpp", 2, 6, False, 1.0, False, False, True, True),
    (2, "poisson", 1, None, True, 0.0, False, True, True, False),
    (3, "mmpp", 3, 16, True, 1.0, True, True, True, False),
    (0, "poisson", 4, None, False, 1.0, True, False, False, True),
    (1, "uniform", 5, 6, True, 0.0, True, True, False, True),
]


class TestPropertyEquivalence:
    """Satellite acceptance: macro-event results pinned exactly to the
    reference loop across apps x arrival processes x (backpressure on/off,
    control on/off, correlated fanout on/off, dummies, budget timeouts)."""

    @pytest.mark.parametrize("combo", _COMBOS, ids=[str(i) for i in range(len(_COMBOS))])
    def test_fixed_matrix(self, combo):
        check_combo(*combo)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - dev dependency (requirements-dev.txt)
    pass
else:

    class TestPropertyEquivalenceHypothesis:
        @given(
            app_i=st.integers(0, 3),
            kind=st.sampled_from(["uniform", "poisson", "mmpp"]),
            seed=st.integers(0, 5),
            queue_cap=st.sampled_from([None, 6, 16]),
            stochastic=st.booleans(),
            correlation=st.sampled_from([0.0, 1.0]),
            control_on=st.booleans(),
            dummies=st.booleans(),
            budget=st.booleans(),
            calendar=st.booleans(),
        )
        @settings(max_examples=20, deadline=None)
        def test_matches_reference(
            self, app_i, kind, seed, queue_cap, stochastic, correlation,
            control_on, dummies, budget, calendar,
        ):
            check_combo(
                app_i, kind, seed, queue_cap, stochastic, correlation,
                control_on, dummies, budget, calendar,
            )
