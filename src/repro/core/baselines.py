"""Baseline systems (Table III) and Harp-* ablations (Sec. IV-C) as planner presets.

Every system in the paper's evaluation is the same three-level composition
with different choices — which is exactly how the paper frames them:

| system    | L_wc model | #configs | hetero | residual opt | latency split       |
|-----------|-----------|----------|--------|--------------|---------------------|
| Harpagon  | d + b/w   | any      | yes    | dummy+reassign | latency-cost eff. |
| Nexus     | 2d        | 2        | no     | —            | quantized interval  |
| Scrooge   | d + b/t   | 2        | yes    | —            | throughput-based    |
| InferLine | 2d        | 1        | yes    | —            | throughput-based    |
| Clipper   | 2d        | 1        | no     | —            | even splitting      |
"""
from __future__ import annotations

from .dispatch import Policy
from .harpagon import PlannerOptions

# ---------------------------------------------------------------- systems
HARPAGON = PlannerOptions(name="harpagon")

NEXUS = PlannerOptions(
    name="nexus",
    policy=Policy.RR,
    k_tuples=2,
    split="quantized",
    quantize=0.01,
    use_dummy=False,
    reassign=0,
    hardware="cheapest",
)

SCROOGE = PlannerOptions(
    name="scrooge",
    policy=Policy.DT,
    k_tuples=2,
    split="throughput",
    use_dummy=False,
    reassign=0,
)

INFERLINE = PlannerOptions(
    name="inferline",
    policy=Policy.RR,
    k_tuples=1,
    split="throughput",
    use_dummy=False,
    reassign=0,
)

CLIPPER = PlannerOptions(
    name="clipper",
    policy=Policy.RR,
    k_tuples=1,
    split="even",
    use_dummy=False,
    reassign=0,
    hardware="cheapest",
)

BASELINES = (NEXUS, SCROOGE, INFERLINE, CLIPPER)

# ---------------------------------------------------------------- ablations
HARP_2D = PlannerOptions(name="harp-2d", policy=Policy.RR)     # RR dispatch
HARP_DT = PlannerOptions(name="harp-dt", policy=Policy.DT_OPT)  # literal d + b/t model
HARP_1C = PlannerOptions(name="harp-1c", k_tuples=1, use_dummy=False, reassign=0)
HARP_2C = PlannerOptions(name="harp-2c", k_tuples=2, use_dummy=False, reassign=0)
HARP_NB = PlannerOptions(name="harp-nb", max_batch=1)          # no batching
HARP_NHC = PlannerOptions(name="harp-nhc", hardware="cheapest")
HARP_NHE = PlannerOptions(name="harp-nhe", hardware="most_expensive")
HARP_ND = PlannerOptions(name="harp-nd", use_dummy=False)      # no dummy
HARP_0RE = PlannerOptions(name="harp-0re", reassign=0)
HARP_1RE = PlannerOptions(name="harp-1re", reassign=1)
HARP_TB = PlannerOptions(name="harp-tb", split="throughput")
HARP_Q001 = PlannerOptions(name="harp-q0.01", split="quantized", quantize=0.01)
HARP_Q01 = PlannerOptions(name="harp-q0.1", split="quantized", quantize=0.1)
HARP_NNM = PlannerOptions(name="harp-nnm", node_merge=False)
HARP_NCD = PlannerOptions(name="harp-ncd", cost_direct=False)

ABLATIONS = (
    HARP_2D,
    HARP_DT,
    HARP_1C,
    HARP_2C,
    HARP_NB,
    HARP_NHC,
    HARP_NHE,
    HARP_ND,
    HARP_0RE,
    HARP_1RE,
    HARP_TB,
    HARP_Q001,
    HARP_Q01,
    HARP_NNM,
    HARP_NCD,
)

ALL_SYSTEMS = (HARPAGON,) + BASELINES
