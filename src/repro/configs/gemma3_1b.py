"""gemma3-1b [dense] — 5:1 local:global, 128k context [hf:google/gemma-3-1b-pt]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    arch_type="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,  # MQA
    d_ff=6912,
    vocab_size=262144,
    source="hf:google/gemma-3-1b-pt",
    head_dim=256,
    act="gelu",
    gemma_norm=True,
    qk_norm=True,
    tie_embeddings=True,
    local_global=(5, 1),
    sliding_window=512,
    rope_theta=1_000_000.0,  # global layers
    rope_theta_local=10_000.0,  # local layers
    max_seq_len=131_072,
)

SMOKE = CONFIG.replace(
    n_layers=6,  # one full 5:1 macro-block
    d_model=128,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    sliding_window=16,
    param_dtype="float32",
    compute_dtype="float32",
)
