"""Launch layer: roofline parsing, scan correction, specs, skip logic."""
import jax
import jax.numpy as jnp
import pytest

# JAX-compile-heavy (jits real kernels/models); deselect with -m "not slow"
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, SHAPES
from repro.launch.roofline import (
    CollectiveStats,
    Roofline,
    _shape_bytes,
    parse_collectives,
)
from repro.launch.specs import SKIPS, WINDOW_OVERRIDE, effective_config, input_specs
from repro.models import Model


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[]") == 1  # scalar: product of no dims = 1


_HLO = """
HloModule test

%body.1 (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %ag = f32[8,128]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[8,128]{1,0} all-reduce(%ag), to_apply=%add.0
  ROOT %t = tuple(...)
}

%cond.1 (p: (s32[], f32[8,128])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %w = (s32[], f32[8,128]) while(%init), condition=%cond.1, body=%body.1
  %ag2 = f32[4,4]{1,0} all-gather(%a), dimensions={0}
  ROOT %out = f32[8,128] get-tuple-element(%w), index=1
}
"""


def test_parse_collectives_with_trip_counts():
    stats = parse_collectives(_HLO)
    # in-loop collectives weighted by trip count 10; entry all-gather once
    assert stats.count_by_op["all-gather"] == 11
    assert stats.count_by_op["all-reduce"] == 10
    expect_ag = 10 * 8 * 128 * 4 + 4 * 4 * 4
    assert stats.bytes_by_op["all-gather"] == expect_ag
    # wire model: all-reduce counts 2x
    assert stats.wire_bytes == expect_ag + 2 * 10 * 8 * 128 * 4


def test_roofline_terms_and_dominance():
    rl = Roofline(
        flops_per_device=197e12,  # exactly 1s of compute
        bytes_per_device=819e9 / 2,  # 0.5s memory
        collective_bytes_per_device=50e9 / 4,  # 0.25s collective
        chips=256,
    )
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(0.5)
    assert rl.collective_s == pytest.approx(0.25)
    assert rl.dominant == "compute"
    assert rl.step_s == pytest.approx(1.0)


def test_scan_correction_grows_with_layers():
    from repro.launch.dryrun import scan_correction  # noqa: avoids 512-dev init?

    # NOTE: importing dryrun sets XLA_FLAGS but does not initialize jax devices
    c_small = scan_correction(ARCHS["xlstm-125m"], 4096, False)
    c_big = scan_correction(ARCHS["qwen1.5-4b"], 4096, False)
    assert 1.0 < c_small < c_big  # 12-layer model corrects less than 40-layer
    # attention context term raises the correction with longer sequences
    assert scan_correction(ARCHS["smollm-360m"], 32768, False) > scan_correction(
        ARCHS["smollm-360m"], 4096, False
    )


def test_effective_config_and_skips():
    assert ("musicgen-medium", "long_500k") in SKIPS
    with pytest.raises(KeyError):
        effective_config(ARCHS["musicgen-medium"], SHAPES["long_500k"])
    cfg = effective_config(ARCHS["gemma-7b"], SHAPES["long_500k"])
    assert cfg.sliding_window == WINDOW_OVERRIDE["gemma-7b"]
    assert cfg.local_global is None
    # non-long shapes unchanged
    assert effective_config(ARCHS["gemma-7b"], SHAPES["train_4k"]) is ARCHS["gemma-7b"]


def test_input_specs_shapes():
    cfg = ARCHS["smollm-360m"]
    tr = input_specs(cfg, SHAPES["train_4k"])
    assert tr["tokens"].shape == (256, 4096) and tr["labels"].shape == (256, 4096)
    pf = input_specs(cfg, SHAPES["prefill_32k"])
    assert pf["tokens"].shape == (32, 32768)
    vl = input_specs(ARCHS["qwen2-vl-2b"], SHAPES["prefill_32k"])
    assert vl["embeds"].shape == (32, 32768, 1536)
    dec = input_specs(cfg, SHAPES["decode_32k"], Model(cfg))
    assert dec["tokens"].shape == (128, 1)
    leaves = jax.tree.leaves(dec["cache"])
    assert all(l.shape[-3] == 32768 or l.ndim < 3 or True for l in leaves)
    # caches are abstract — no allocation happened
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_decode_cache_ring_buffer_sizes():
    cfg = effective_config(ARCHS["gemma-7b"], SHAPES["long_500k"])
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 524288))
    sizes = {l.shape[-3] for l in jax.tree.leaves(cache) if l.ndim >= 4}
    # all layers are sliding-window: ring buffers of 8192, never 524288
    assert sizes == {8192}
