"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    source="arXiv:2403.08295",
    head_dim=256,
    act="gelu",
    gemma_norm=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    max_seq_len=8_192,
    remat=True,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
)
