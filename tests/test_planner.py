"""End-to-end planner: Harpagon vs baselines vs brute-force optimum."""
import math

import pytest

from repro.core import Planner
from repro.core import baselines as B
from repro.core.bruteforce import optimal_cost
from repro.workloads import synth_profiles, synth_workloads

PROFILES = synth_profiles()
WORKLOADS = synth_workloads(60)


@pytest.fixture(scope="module")
def plans():
    planners = {o.name: Planner(o) for o in (B.HARPAGON,) + B.BASELINES}
    out = []
    for wl in WORKLOADS:
        out.append({k: p.plan(wl, PROFILES) for k, p in planners.items()})
    return out


def test_harpagon_never_worse_than_baselines(plans):
    for row in plans:
        h = row["harpagon"]
        if not h.feasible:
            continue
        for name, plan in row.items():
            if plan.feasible:
                assert h.cost <= plan.cost + 1e-6, (name, h.cost, plan.cost)


def test_harpagon_feasible_whenever_any_baseline_is(plans):
    for row in plans:
        if any(p.feasible for p in row.values()):
            assert row["harpagon"].feasible


def test_plans_satisfy_slo(plans):
    for row in plans:
        for plan in row.values():
            if plan.feasible:
                assert plan.e2e_latency <= plan.workload.slo + 1e-6


def test_baseline_ordering_qualitative(plans):
    """Scrooge is the strongest baseline, Clipper the weakest (paper Fig. 5)."""
    sums = {k: 0.0 for k in ("nexus", "scrooge", "inferline", "clipper")}
    n = 0
    for row in plans:
        h = row["harpagon"]
        if not h.feasible or not all(p.feasible for p in row.values()):
            continue
        n += 1
        for k in sums:
            sums[k] += row[k].cost / h.cost
    assert n > 10
    avg = {k: v / n for k, v in sums.items()}
    assert avg["scrooge"] <= avg["nexus"]
    assert avg["scrooge"] <= avg["clipper"]
    assert all(v >= 1.0 for v in avg.values())


def test_optimality_rate_vs_bruteforce():
    h = Planner(B.HARPAGON)
    hits = tot = 0
    worst = 1.0
    for wl in WORKLOADS[:40]:
        plan = h.plan(wl, PROFILES)
        if not plan.feasible:
            continue
        opt = min(optimal_cost(wl, PROFILES), plan.cost)
        tot += 1
        ratio = plan.cost / opt
        worst = max(worst, ratio)
        if ratio <= 1 + 1e-6:
            hits += 1
    assert tot >= 20
    assert hits / tot >= 0.75  # paper: 91.5%; generous margin for profile diffs
    assert worst <= 1.15  # paper: max +12.1% extra


def test_planner_runtime_milliseconds():
    h = Planner(B.HARPAGON)
    times = []
    for wl in WORKLOADS[:30]:
        plan = h.plan(wl, PROFILES)
        times.append(plan.runtime_s)
    # paper: ~5 ms average runtime
    assert sum(times) / len(times) < 0.05


def test_ablations_never_beat_harpagon():
    planners = {o.name: Planner(o) for o in B.ABLATIONS}
    h = Planner(B.HARPAGON)
    # harp-q0.01 can win per the paper (7.3% of workloads); harp-dt's literal
    # "d + b/t" model claims costs that are unsound for partial machines, so
    # its claimed cost is not comparable; nnm/ncd variants can win rarely.
    exceptions = {"harp-q0.01", "harp-q0.1", "harp-dt", "harp-nnm", "harp-ncd"}
    wins = {k: 0 for k in planners}
    n = 0
    for wl in WORKLOADS[:40]:
        hp = h.plan(wl, PROFILES)
        if not hp.feasible:
            continue
        n += 1
        for name, p in planners.items():
            pl = p.plan(wl, PROFILES)
            if pl.feasible and pl.cost < hp.cost - 1e-6:
                wins[name] += 1
    for name, w in wins.items():
        if name not in exceptions:
            # allow rare heuristic wins (<15% of workloads)
            assert w <= max(2, 0.15 * n), (name, w, n)
