"""Frozen legacy pure-Python replay loops (the seed implementations).

Kept word-for-word as golden references: the event-driven core and the
vectorized replay kernel must reproduce these numbers on uniform arrivals
(tests/test_golden_equivalence.py), and the benchmark suite measures the
vectorized speedup against them (`benchmarks.run --only replay`).

Seed quirks are preserved on purpose — do NOT fix or optimize them here:

* ``simulate_reference`` drops incomplete tail batches outright (steady-state
  accounting only);
* ``engine_run_reference`` "flushes" tail batches with the no-op deadline
  ``t_ready = max(t_ready, t_ready)`` — i.e. executes them the moment their
  last request arrives, with no real timeout semantics.

The maintained semantics live in `repro.serving.events` and
`repro.serving.replay`.
"""
from __future__ import annotations

from ..core.dispatch import Alloc, Machine, Policy, expand_machines
from ..core.harpagon import Plan
from .engine import ModuleStats, ServeResult
from .simulator import SimResult


def dispatch_trace_reference(
    machines: list[Machine], n_requests: int, policy: Policy
) -> list[tuple[int, int]]:
    """The seed `core.dispatch.dispatch_trace` loop, verbatim.

    The live `dispatch_runs` is a vectorized merge-sort of the same periodic
    run slots; it can legitimately differ from this greedy walk on float
    near-ties (accumulated ``next_t += p`` vs ``k * p``).  Keeping the seed
    loop frozen here means the golden tests pin the *whole* seed pipeline,
    dispatcher included, rather than comparing the new dispatcher to itself.
    """
    out: list[tuple[int, int]] = []
    if policy is Policy.TC:
        next_t = [0.0] * len(machines)
        rid = 0
        while rid < n_requests:
            j = min(
                range(len(machines)),
                key=lambda i: (next_t[i], -machines[i].config.ratio, i),
            )
            m = machines[j]
            take = min(m.config.batch, n_requests - rid)
            for _ in range(take):
                out.append((rid, m.mid))
                rid += 1
            next_t[j] += m.config.batch / m.rate
        return out
    credit = [0.0] * len(machines)
    tot = sum(m.rate for m in machines)
    for rid in range(n_requests):
        for i, m in enumerate(machines):
            credit[i] += m.rate / tot
        j = max(range(len(machines)), key=lambda i: credit[i])
        credit[j] -= 1.0
        out.append((rid, machines[j].mid))
    return out


def simulate_reference(
    allocs: list[Alloc],
    total_rate: float,
    *,
    policy: Policy = Policy.TC,
    n_requests: int = 2000,
) -> SimResult:
    """The seed `serving.simulator.simulate` loop, verbatim."""
    machines = expand_machines(allocs)
    trace = dispatch_trace_reference(machines, n_requests, policy)
    arrivals = [i / total_rate for i in range(n_requests)]

    by_machine: dict[int, list[int]] = {m.mid: [] for m in machines}
    for rid, mid in trace:
        by_machine[mid].append(rid)

    latency = [0.0] * n_requests
    per_machine_max: dict[int, float] = {}
    for m in machines:
        rids = by_machine[m.mid]
        b, d = m.config.batch, m.config.duration
        free_at = 0.0
        worst = 0.0
        for i in range(0, len(rids), b):
            group = rids[i : i + b]
            if len(group) < b:
                break  # incomplete tail batch: not in steady state, drop
            ready = arrivals[group[-1]]
            start = max(ready, free_at)
            finish = start + d
            free_at = finish
            for rid in group:
                lat = finish - arrivals[rid]
                latency[rid] = lat
                worst = max(worst, lat)
        per_machine_max[m.mid] = worst
    done = [l for l in latency if l > 0]
    return SimResult(
        max_latency=max(done) if done else 0.0,
        mean_latency=sum(done) / len(done) if done else 0.0,
        per_machine_max=per_machine_max,
        n_requests=len(done),
    )


def engine_run_reference(
    plan: Plan, n_frames: int, frame_rate: float, *, policy: Policy = Policy.TC
) -> ServeResult:
    """The seed `serving.engine.ServingEngine.run` virtual-time loop, verbatim
    (minus the real-executor branch, which the seed example alone used)."""
    wl = plan.workload
    arrival = [i / frame_rate for i in range(n_frames)]
    finish_at = {m: [0.0] * n_frames for m in wl.app.modules}
    stats = {m: ModuleStats() for m in wl.app.modules}

    def _topo():
        seen: list[str] = []
        mods = list(wl.app.modules)
        while mods:
            for m in mods:
                if all(p in seen for p in wl.app.parents(m)):
                    seen.append(m)
                    mods.remove(m)
                    break
            else:
                raise RuntimeError("cycle in DAG")
        return seen

    def _run_module(m, ready, drop, fanout, finish, st: ModuleStats):
        sched = plan.schedules[m]
        machines = expand_machines(list(sched.allocs))
        order = sorted(range(n_frames), key=lambda i: ready[i])
        instances: list[int] = []
        acc = 0.0
        for i in order:
            if drop[i]:
                continue
            acc += fanout
            k = int(acc)
            acc -= k
            instances.extend([i] * k)
        n = len(instances)
        if n == 0:
            return
        trace = dispatch_trace_reference(machines, n, policy)
        by_machine: dict[int, list[int]] = {mm.mid: [] for mm in machines}
        for slot, mid in trace:
            by_machine[mid].append(instances[slot])
        for mm in machines:
            fids = by_machine[mm.mid]
            b, d = mm.config.batch, mm.config.duration
            free = 0.0
            for i in range(0, len(fids), b):
                group = fids[i : i + b]
                t_ready = max(ready[f] for f in group)
                if len(group) < b:
                    # tail batch: flushed on deadline (early-exec semantics)
                    t_ready = max(t_ready, t_ready)
                start = max(t_ready, free)
                end = start + d
                free = end
                st.batches += 1
                for f in group:
                    finish[f] = max(finish[f], end)
                    st.latencies.append(end - ready[f])

    for m in _topo():
        parents = wl.app.parents(m)
        ready = [
            max([arrival[i]] + [finish_at[p][i] for p in parents])
            for i in range(n_frames)
        ]
        drop = [
            any(finish_at[p][i] <= 0.0 for p in parents) for i in range(n_frames)
        ] if parents else [False] * n_frames
        fanout = wl.rates[m] / frame_rate
        _run_module(m, ready, drop, fanout, finish_at[m], stats[m])
    sinks = [m for m in wl.app.modules if not wl.app.children(m)]
    e2e = [
        max(finish_at[s][i] for s in sinks) - arrival[i]
        for i in range(n_frames)
        if all(finish_at[s][i] > 0 for s in sinks)
    ]
    return ServeResult(e2e, stats, wl.slo)
