"""Theorem 1 live: event-simulate TC vs RR dispatch on the paper's M4 example.

    PYTHONPATH=src python examples/dispatch_simulation.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import Alloc, Policy, module_wcl
from repro.core.profiles import TABLE1_M3, TABLE_M4
from repro.core.scheduler import generate_config
from repro.serving.simulator import simulate


def show(name, allocs, rate):
    print(f"\n{name}: {allocs}")
    for pol in (Policy.TC, Policy.RR):
        theory = module_wcl(allocs, pol)
        sim = simulate(allocs, rate, policy=pol, n_requests=4000)
        print(
            f"  {pol.name}: Theorem-1 L_wc = {theory:.4f}s | "
            f"simulated max = {sim.max_latency:.4f}s "
            f"(mean {sim.mean_latency:.4f}s over {sim.n_requests} reqs)"
        )


def main() -> None:
    # paper Sec. III-B worked example: A,B at b6 d2.0; C at b2 d1.0; T=8
    c6, c2 = TABLE_M4.configs
    show("M4 (paper Fig. 4)", [Alloc(c6, 2.0, 6.0), Alloc(c2, 1.0, 2.0)], 8.0)

    # Table II S3: M3 at 198 req/s under 1.0 s SLO
    ok, s3 = generate_config(198.0, 1.0, TABLE1_M3, Policy.TC)
    assert ok
    show("M3 S3 (paper Table II)", s3, 198.0)


if __name__ == "__main__":
    main()
