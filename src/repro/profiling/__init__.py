from .analytic import arch_profile, module_duration
from .analytics import flops_per_token, kv_cache_bytes_per_token, param_count
from .hardware import CATALOG, TARGET, TPUSpec

__all__ = [
    "CATALOG", "TARGET", "TPUSpec", "arch_profile", "flops_per_token",
    "kv_cache_bytes_per_token", "module_duration", "param_count",
]
