"""Segment fast-path: delegate a quiescent co-simulation to the flat kernel.

PR 3 established (property tests over all apps × arrival processes,
including ``timeout="budget"``) that the pipelined event loop and the flat
engine's vectorized per-module replay agree whenever queues are unbounded
and fanout is deterministic.  This module is that theorem turned into a
cache: when a segment of the run is *quiescent of everything only the
event loop can express* —

* open-loop issue times (no closed-loop clients),
* no admission shedding against live state,
* no control epochs (no machine-set hot-swaps mid-segment),
* every stage unbounded (``queue_cap is None``, no backpressure),
* deterministic accumulator fanout (`fanout.AccumulatorFanout`),
* no adaptive phantom streaming (``phantom_target == 0``)

— the whole segment replays in O(batches) numpy work per machine on the
vectorized kernel (`repro.serving.replay`), filling the same
`result.FrameTable` columns the event loop would have produced, with
finish times BIT-identical to the event cores (the kernel's FIFO chain
evaluates in their operation order).  Every eligibility condition above is
run-constant, so the quiescent segment is always the *entire* run and the
event-loop re-entry point is the end of stream.

**The causal order.**  One construct needs care in the flat replay: the
end-of-stream tail flush with ``timeout=None`` closes a partial batch at
its last member's ready time — *backdating* service into the past, because
the flat engine knows module-by-module that the stream has ended.  The
event loop only learns that once everything else has drained, so its tail
flushes (and their downstream cascades) happen strictly after all normal
events, round by round.  The fast path tracks a *quiescence depth* per
frame (0 = normal, r = produced in/fed by the r-th tail-flush round) and
orders every module's arrival stream by ``(depth, ready, frame id)``
(`replay.causal_order`) — exactly the event loop's delivery order, even
when a backdated tail on one branch of a join carries an earlier time
than a sibling's normal completions.  The flat kernel itself is causal
(`repro.serving.replay` handles non-monotone ready within a causal
stream), so the fast path never needs to bail to the event loop.

Speed: ~20-40x over the event-by-event loop at 10^4-10^6 frames on the
suite apps (see ``benchmarks.run --only pipeline_speed``), which is what
makes control-plane and SLO sweeps at the ROADMAP's million-frame scale
tractable.
"""
from __future__ import annotations

from typing import Mapping

import numpy as np

from ...core.dag import AppDAG
from ...core.dispatch import dispatch_runs
from ..replay import (
    causal_order,
    fanout_counts,
    lexmax_fold,
    lexmax_parents,
    propagate_depth,
    replay_module,
    runs_to_assignment,
)
from .fanout import AccumulatorFanout
from .result import FrameTable, PipelineResult
from .stages import ModuleStage


def eligible(dag: AppDAG, stages: Mapping[str, ModuleStage]) -> bool:
    """Stage-side fast-path eligibility (caller already checked that the
    run is open-loop with no admission and no control plane).

    A non-analytic service-time source (trace samples, live executor
    timing) is stateful per batch start, so those runs stay on the event
    loop; an analytic source is the profiled constant the kernel already
    uses."""
    return all(
        st.queue_cap is None
        and st.phantom_target <= 0.0
        and isinstance(st.fanout, AccumulatorFanout)
        and getattr(st.service_time, "kind", "analytic") == "analytic"
        for st in stages.values()
    )


def run_flat_segment(
    dag: AppDAG,
    stages: Mapping[str, ModuleStage],
    n_frames: int,
    issue: np.ndarray,
    tail: str,
    obs=None,
) -> PipelineResult:
    """Replay one quiescent segment (the whole eligible run) vectorized.

    Module-by-module in topological order — the flat engine's schedule,
    which the PR-3 ordering argument showed delivers every frame to every
    stage at the same instant and in the same arrival order as the global
    event loop (streams in causal ``(depth, ready, id)`` order; see module
    docstring).  Per-frame records land in the same `FrameTable` columns
    the event loop fills, so the returned `PipelineResult` is
    indistinguishable from the general path's.

    ``obs`` (an `observability.Observability`) receives *column-level*
    metrics only — per-module batch counts, occupancy, and exact busy time
    from the per-machine batch tallies — never per-event trace spans:
    keeping the fast path allocation-free per event is what holds sampled
    tracing inside the CI overhead gate.
    """
    topo = dag.topo_order()
    torder = {m: i for i, m in enumerate(topo)}
    parents = {m: sorted(dag.parents(m), key=torder.__getitem__) for m in topo}
    children = {m: sorted(dag.children(m), key=torder.__getitem__) for m in topo}
    sinks = [m for m in topo if not children[m]]
    ancestors = dag.ancestor_closure()

    ft = FrameTable(n_frames, topo, parents, len(sinks))
    ft.issue[:] = issue
    # ``bad[m][f]``: frame f produced no completion at m — voided by a bad
    # parent, skipped by a zero instance count, or every instance dropped
    # (the event loop's stage_resolved(done=False) propagation, columnar)
    bad = {m: np.zeros(n_frames, dtype=bool) for m in topo}
    # quiescence depth of f's completion at m: 0 = produced by the normal
    # event phase, r >= 1 = produced in (the cascade of) the r-th
    # quiescence flush round — the event loop flushes every
    # ancestors-drained stage per round, so round r's completions (and
    # their fill-cascades) all causally precede round r+1's
    depth = {m: np.zeros(n_frames, dtype=np.int64) for m in topo}
    # the processing instant of f's resolve at m — equal to the finish value
    # in the normal phase, but a cascade resolve can be backdated below a
    # sibling branch's finish while still processing after it (the join's
    # delivery order key, alongside depth; see `replay.causal_order`)
    emit = {m: np.zeros(n_frames) for m in topo}
    # the round in which m's own backdated tail (timeout None, flushed
    # partial) fires: one past the last round an ancestor still held work
    tail_round: dict[str, int] = {}

    for m in topo:
        st = stages[m]
        if parents[m]:
            pf = np.stack([ft.finish[p] for p in parents[m]])
            voided = np.isnan(pf).any(axis=0)
            ready = pf.max(axis=0)  # NaN only where voided (excluded below)
            in_depth, in_emit = lexmax_parents(
                [depth[p] for p in parents[m]],
                [emit[p] for p in parents[m]],
            )
        else:
            voided = np.zeros(n_frames, dtype=bool)
            ready = ft.issue
            in_depth = np.zeros(n_frames, dtype=np.int64)
            in_emit = ft.issue
        bad[m] |= voided
        # stage arrival order: causal — (quiescence depth, emit, frame id),
        # the order the event loop's (t, seq) heap + (topo, frame)
        # same-instant delivery + after-drain tail rounds realize
        order = causal_order(ready, in_depth, in_emit)
        alive = order[~voided[order]]
        counts = fanout_counts(alive.size, st.fanout.phi)
        ft.fan[m][alive] = counts
        taken = counts > 0
        entered = alive[taken]
        ft.avail[m][entered] = ready[entered]
        bad[m][alive[~taken]] = True  # zero-fanout skip: vacuously resolved

        instances = np.repeat(alive, counts)
        if instances.size == 0:
            tail_round[m] = 0
            continue
        ready_inst = ready[instances]
        machines = st.machines
        timeout = {mm.mid: st.cores[mm.mid].timeout for mm in machines}
        runs = dispatch_runs(machines, instances.size, st.policy)
        rep = replay_module(machines, ready_inst, runs, timeout=timeout, tail=tail)
        done = rep.done
        # per-frame finish = max over the frame's completed instances
        # (partial completion proceeds with the instances that did finish)
        fmax = np.full(n_frames, -np.inf)
        np.maximum.at(fmax, instances[done], rep.finish[done])
        has_done = fmax > -np.inf
        ft.finish[m][has_done] = fmax[has_done]
        had = np.zeros(n_frames, dtype=bool)
        had[entered] = True
        lost_here = had & ~has_done
        ft.lost |= lost_here
        bad[m] |= lost_here

        # propagate quiescence depth through service so downstream joins
        # can re-establish the causal order (`replay.propagate_depth`);
        # each frame's resolve key is the lexicographic (depth, finish)
        # max over its completed instances
        assignment = runs_to_assignment(runs, instances.size)
        out_inst, tail_round[m] = propagate_depth(
            in_depth[instances], assignment, rep.finish, machines, timeout,
            tail,
            max((tail_round.get(a, 0) for a in ancestors[m]), default=0),
        )
        lexmax_fold(
            instances[done], out_inst[done], rep.finish[done],
            depth[m], emit[m],
        )

        ss = st.stats
        ss.batches += rep.n_batches
        ss.dropped += instances.size - int(done.sum())
        ss.latencies.extend((rep.finish[done] - ready_inst[done]).tolist())
        if obs is not None:
            # exact column-level accounting: ModuleReplay tallies executed
            # batches per machine, so busy time and capacity slots come
            # from each machine's own config — no per-event hooks
            by_mid = {mm.mid: mm.config for mm in machines}
            obs.bulk_module(
                m,
                batches=rep.n_batches,
                members=int(done.sum()),
                phantoms=0,
                slots=sum(
                    k * by_mid[mid].batch for mid, k in rep.batches.items()
                ),
                busy=sum(
                    k * by_mid[mid].duration for mid, k in rep.batches.items()
                ),
            )

    sink_finish = np.stack([ft.finish[s] for s in sinks])
    ok = ~np.isnan(sink_finish).any(axis=0)
    ft.e2e[ok] = sink_finish.max(axis=0)[ok] - ft.issue[ok]
    ft.resolved[:] = True  # every frame is accounted: done, skipped, or lost
    return ft.finalize(dag, {m: stages[m].stats for m in topo}, attempts=0)
