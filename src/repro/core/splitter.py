"""Latency splitting: Algorithm 2 (latency-cost efficiency) + optimizers + baselines.

Paper Sec. III-D.  During splitting each module is represented by a single
*split configuration* ``c``; its fractional-packing cost is
``C_M(c) = p_c * T_M / t_c`` and its latency contribution is
``GetWCL(c) = d + b / T_M`` under TC dispatch (the whole module rate is the
batch-collection rate for the majority machines).

Splitters implemented:

* ``split_lc``          — Algorithm 2: greedy max latency-cost efficiency
                          ``LC = dCost / dL_wc``; optional *node merger*
                          (sibling joint upgrades) and *cost-direct* (re-do
                          the last R iterations greedily by raw cost delta).
* ``split_throughput``  — Scrooge/InferLine-style: greedy by throughput.
* ``split_even``        — Clipper-style: ``L / depth`` per module.
* ``split_quantized``   — Nexus-style: exact DP over a discretized budget
                          grid on the SP tree (interval ``q``).

Each returns ``{module: budget}`` — the per-module latency budget handed to
the module scheduler — and is feasible by construction
(``critical-path latency <= SLO``) or ``None`` when even the least-demanding
configuration cannot meet the SLO.
"""
from __future__ import annotations

import math
from typing import Mapping

from .dag import AppDAG, Leaf, Par, Series, SP, Workload
from .dispatch import Policy
from .profiles import Config, ModuleProfile
from .scheduler import get_wcl

_EPS = 1e-9
INF = math.inf


def split_cost(c: Config, T: float) -> float:
    """Fractional-packing cost of carrying rate T entirely on configuration c."""
    return c.unit_price * T / c.throughput


def split_wcl(c: Config, T: float, policy: Policy) -> float:
    """Module-level L_wc when the whole rate T rides configuration c
    (fractional-packing view: the tail machine is ignored)."""
    return get_wcl(c, policy, T, full=T >= c.throughput - _EPS)


def split_wcl_integer(c: Config, T: float, policy: Policy) -> float:
    """Integer-aware L_wc: accounts for the fractional tail machine, which
    either collects at its own small rate or is dummy-filled to a full
    machine (L_wc = 2d).  Budgets derived from this are schedulable by
    construction (the single-config integer cover fits)."""
    t = c.throughput
    if T < t - _EPS:
        # single partial machine — or dummy-filled if collection is too slow
        return min(get_wcl(c, policy, T, full=False), get_wcl(c, policy, t, full=True))
    full = get_wcl(c, policy, T, full=True)
    tail = T - math.floor(T / t + 1e-12) * t
    if tail <= _EPS:
        return full
    tail_wcl = min(
        get_wcl(c, policy, tail, full=False), get_wcl(c, policy, t, full=True)
    )
    return max(full, tail_wcl)


class _State:
    """Mutable Algorithm-2 state: one split config per module."""

    def __init__(
        self,
        wl: Workload,
        profiles: Mapping[str, ModuleProfile],
        policy: Policy,
        *,
        integer_tails: bool = False,
    ):
        self.wl = wl
        self.profiles = profiles
        self.policy = policy
        self.integer_tails = integer_tails
        self._wcl_fn = split_wcl_integer if integer_tails else split_wcl
        # Start at the least cost-efficient / lowest-latency configuration
        # (paper: batch 1 on the priciest hardware).  We pick the minimum-WCL
        # config (tie: highest unit price) so that the start is feasible
        # whenever any single-config assignment is.
        self.cfg: dict[str, Config] = {
            m: min(
                profiles[m].configs,
                key=lambda c: (self._wcl_fn(c, wl.rates[m], policy), -c.unit_price),
            )
            for m in wl.app.modules
        }

    def wcl(self, m: str, c: Config | None = None) -> float:
        return self._wcl_fn(c or self.cfg[m], self.wl.rates[m], self.policy)

    def cost(self, m: str, c: Config | None = None) -> float:
        return split_cost(c or self.cfg[m], self.wl.rates[m])

    def e2e(self, override: Mapping[str, Config] | None = None) -> float:
        def w(m: str) -> float:
            c = override.get(m) if override else None
            return self.wcl(m, c or self.cfg[m])

        return self.wl.app.latency({m: w(m) for m in self.wl.app.modules})

    def total_cost(self) -> float:
        return sum(self.cost(m) for m in self.wl.app.modules)

    def feasible(self) -> bool:
        return self.e2e() <= self.wl.slo + _EPS

    def budgets(self) -> dict[str, float]:
        return {m: self.wcl(m) for m in self.wl.app.modules}


def _candidates(st: _State, m: str) -> list[tuple[float, float, Config]]:
    """Cost-reducing upgrade candidates for module m: (dcost, dlat, config)."""
    out = []
    prev = st.cfg[m]
    c_prev, l_prev = st.cost(m), st.wcl(m)
    for c in st.profiles[m].configs:
        if c == prev:
            continue
        dcost = c_prev - st.cost(m, c)
        if dcost <= 1e-12:
            continue
        dlat = st.wcl(m, c) - l_prev
        out.append((dcost, dlat, c))
    return out


def _lc(dcost: float, dlat: float) -> float:
    """Latency-cost efficiency; free (non-latency-increasing) moves rank first."""
    return INF if dlat <= _EPS else dcost / dlat


def split_lc(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    *,
    node_merge: bool = True,
    cost_direct: bool = True,
    cost_direct_r: tuple[int, ...] = (1, 2, 3),
    integer_tails: bool = False,
) -> dict[str, float] | None:
    """Algorithm 2 + node merger + cost-direct.  Returns per-module budgets."""
    st = _State(wl, profiles, policy, integer_tails=integer_tails)
    if not st.feasible():
        return None
    groups = wl.app.sibling_groups() if node_merge else []
    history: list[dict[str, tuple[Config, Config]]] = []

    def step_lc() -> bool:
        """One Algorithm-2 iteration: apply the max-LC feasible operation."""
        best: tuple[float, float, dict[str, Config]] | None = None  # (lc, dcost, move)
        for m in wl.app.modules:
            for dcost, dlat, c in _candidates(st, m):
                move = {m: c}
                key = (_lc(dcost, dlat), dcost)
                if (best is None or key > (best[0], best[1])) and st.e2e(move) <= wl.slo + _EPS:
                    best = (key[0], dcost, move)
        # node merger: joint upgrade of sibling groups, LC summed
        for grp in groups:
            move: dict[str, Config] = {}
            dcost_sum, dlat_max = 0.0, 0.0
            for m in grp:
                cands = _candidates(st, m)
                if not cands:
                    continue
                dcost, dlat, c = max(cands, key=lambda x: _lc(x[0], x[1]))
                move[m] = c
                dcost_sum += dcost
                dlat_max = max(dlat_max, dlat)
            if len(move) < 2:
                continue
            key = (_lc(dcost_sum, dlat_max), dcost_sum)
            if (best is None or key > (best[0], best[1])) and st.e2e(move) <= wl.slo + _EPS:
                best = (key[0], dcost_sum, move)
        if best is None:
            return False
        record = {m: (st.cfg[m], c) for m, c in best[2].items()}
        st.cfg.update(best[2])
        history.append(record)
        return True

    while step_lc():
        pass

    if cost_direct and history:
        best_cfg = dict(st.cfg)
        best_cost = st.total_cost()
        for r in cost_direct_r:
            if r > len(history):
                continue
            # roll back the final r operations
            trial = _State(wl, profiles, policy, integer_tails=integer_tails)
            trial.cfg = dict(st.cfg)
            for record in reversed(history[-r:]):
                for m, (old, _new) in record.items():
                    trial.cfg[m] = old
            # greedy by raw cost delta
            while True:
                best_mv: tuple[float, dict[str, Config]] | None = None
                for m in wl.app.modules:
                    for dcost, _dlat, c in _candidates(trial, m):
                        if (best_mv is None or dcost > best_mv[0]) and trial.e2e(
                            {m: c}
                        ) <= wl.slo + _EPS:
                            best_mv = (dcost, {m: c})
                if best_mv is None:
                    break
                trial.cfg.update(best_mv[1])
            if trial.total_cost() < best_cost - 1e-12:
                best_cost = trial.total_cost()
                best_cfg = dict(trial.cfg)
        st.cfg = best_cfg

    return st.budgets()


def split_throughput(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
) -> dict[str, float] | None:
    """Scrooge/InferLine-style: greedily upgrade whichever module gains the
    highest throughput, ignoring latency-budget efficiency."""
    st = _State(wl, profiles, policy)
    if not st.feasible():
        return None
    while True:
        best: tuple[tuple[float, float], dict[str, Config]] | None = None
        for m in wl.app.modules:
            for dcost, _dlat, c in _candidates(st, m):
                key = (c.throughput, dcost)
                if (best is None or key > best[0]) and st.e2e({m: c}) <= wl.slo + _EPS:
                    best = (key, {m: c})
        if best is None:
            break
        st.cfg.update(best[1])
    return st.budgets()


def split_even(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.RR,
    *,
    integer_tails: bool = False,
) -> dict[str, float] | None:
    """Clipper-style: every module gets SLO / depth."""
    wf = split_wcl_integer if integer_tails else split_wcl
    per = wl.slo / wl.app.depth
    budgets = {}
    for m in wl.app.modules:
        feas = [
            c
            for c in profiles[m].configs
            if wf(c, wl.rates[m], policy) <= per + _EPS
        ]
        if not feas:
            return None
        budgets[m] = per
    return budgets


def _sp_quantized_dp(
    sp: SP, nq: int, q: float, cost_at: Mapping[str, list[float]]
) -> list[float]:
    """min-cost DP over the SP tree: dp[k] = min cost with latency <= k*q."""
    if isinstance(sp, Leaf):
        return cost_at[sp.name]
    if isinstance(sp, Series):
        dp = _sp_quantized_dp(sp.parts[0], nq, q, cost_at)
        for p in sp.parts[1:]:
            nxt = _sp_quantized_dp(p, nq, q, cost_at)
            out = [INF] * (nq + 1)
            # dp and nxt are monotone non-increasing in k; combine minimally.
            for a in range(nq + 1):
                if dp[a] is INF:
                    continue
                for b in range(nq + 1 - a):
                    v = dp[a] + nxt[b]
                    if v < out[a + b]:
                        out[a + b] = v
            # prefix-min to enforce monotonicity
            for k in range(1, nq + 1):
                out[k] = min(out[k], out[k - 1])
            dp = out
        return dp
    # Par: same budget for every branch
    parts = [_sp_quantized_dp(p, nq, q, cost_at) for p in sp.parts]
    return [sum(p[k] for p in parts) for k in range(nq + 1)]


def _sp_quantized_assign(
    sp: SP, k: int, nq: int, q: float, cost_at: Mapping[str, list[float]]
) -> dict[str, float]:
    """Recover per-module budgets from the DP solution with total budget k*q."""
    if isinstance(sp, Leaf):
        return {sp.name: k * q}
    if isinstance(sp, Par):
        out: dict[str, float] = {}
        for p in sp.parts:
            out.update(_sp_quantized_assign(p, k, nq, q, cost_at))
        return out
    # Series: re-run the pairwise combination tracking the split point
    tails = [_sp_quantized_dp(Series(sp.parts[i:]), nq, q, cost_at) for i in range(len(sp.parts))]
    out = {}
    rem = k
    for i, p in enumerate(sp.parts):
        head = _sp_quantized_dp(p, nq, q, cost_at)
        if i == len(sp.parts) - 1:
            out.update(_sp_quantized_assign(p, rem, nq, q, cost_at))
            break
        tail = tails[i + 1]
        best_a, best_v = 0, INF
        for a in range(rem + 1):
            v = head[a] + tail[rem - a]
            if v < best_v - 1e-15:
                best_v, best_a = v, a
        out.update(_sp_quantized_assign(p, best_a, nq, q, cost_at))
        rem -= best_a
    return out


def split_quantized(
    wl: Workload,
    profiles: Mapping[str, ModuleProfile],
    policy: Policy = Policy.TC,
    q: float = 0.01,
) -> dict[str, float] | None:
    """Nexus-style: exact DP over budgets quantized to multiples of ``q``."""
    nq = int(wl.slo / q)
    if nq < 1:
        return None
    cost_at: dict[str, list[float]] = {}
    for m in wl.app.modules:
        T = wl.rates[m]
        per = [INF] * (nq + 1)
        for c in profiles[m].configs:
            lw = split_wcl(c, T, policy)
            k0 = math.ceil(lw / q - 1e-9)
            if k0 > nq:
                continue
            cst = split_cost(c, T)
            for k in range(k0, nq + 1):
                if cst < per[k]:
                    per[k] = cst
        cost_at[m] = per
    dp = _sp_quantized_dp(wl.app.sp, nq, q, cost_at)
    if dp[nq] is INF or dp[nq] == INF:
        return None
    budgets = _sp_quantized_assign(wl.app.sp, nq, nq, q, cost_at)
    # guard: every module must have at least one feasible config at its budget
    for m, b in budgets.items():
        if cost_at[m][min(nq, int(b / q))] == INF:
            return None
    return budgets
