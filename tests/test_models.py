"""Per-architecture smoke tests: reduced configs, forward/train/decode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# JAX-compile-heavy (jits real kernels/models); deselect with -m "not slow"
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, SMOKE_ARCHS, SHAPES
from repro.configs.base import LayerSpec
from repro.data import lm_batches
from repro.models import Model, segmentize
from repro.training import OptConfig, adamw_init, make_train_step

ARCH_NAMES = sorted(SMOKE_ARCHS)


def _inputs(cfg, B=2, S=16, key=1):
    if cfg.input_mode == "embeds":
        return {"embeds": jax.random.normal(jax.random.key(key), (B, S, cfg.d_model)) * 0.1}
    return {"tokens": jax.random.randint(jax.random.key(key), (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = SMOKE_ARCHS[arch]
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    ins = _inputs(cfg)
    out = m.forward(params, ins.get("tokens"), embeds=ins.get("embeds"))
    assert out.logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_no_nans(arch):
    cfg = SMOKE_ARCHS[arch]
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, OptConfig(lr=1e-3, total_steps=10)))
    emb = cfg.d_model if cfg.input_mode == "embeds" else None
    batch = next(lm_batches(cfg.vocab_size, 2, 16, embeds_dim=emb))
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(params))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_full_forward(arch):
    cfg = SMOKE_ARCHS[arch]
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 12
    ins = _inputs(cfg, B, S)
    full = m.forward(params, ins.get("tokens"), embeds=ins.get("embeds"))
    cache = m.init_cache(B, 32)
    if "tokens" in ins:
        pre = m.forward(params, ins["tokens"][:, : S - 1], cache=cache, idx=0)
        dec = m.forward(params, ins["tokens"][:, S - 1 :], cache=pre.cache, idx=S - 1)
    else:
        pre = m.forward(params, embeds=ins["embeds"][:, : S - 1], cache=cache, idx=0)
        dec = m.forward(params, embeds=ins["embeds"][:, S - 1 :], cache=pre.cache, idx=S - 1)
    a = np.asarray(full.logits[:, -1], np.float32)
    b = np.asarray(dec.logits[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-3, err


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma3-1b", "jamba-v0.1-52b", "xlstm-125m"])
def test_multistep_decode_matches_full_forward(arch):
    cfg = SMOKE_ARCHS[arch]
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    B, S, n_dec = 1, 8, 5
    toks = jax.random.randint(jax.random.key(2), (B, S + n_dec), 0, cfg.vocab_size)
    full = m.forward(params, toks)
    cache = m.init_cache(B, 32)
    out = m.forward(params, toks[:, :S], cache=cache, idx=0)
    cache = out.cache
    for t in range(n_dec):
        out = m.forward(params, toks[:, S + t : S + t + 1], cache=cache, idx=S + t)
        cache = out.cache
        a = np.asarray(full.logits[:, S + t], np.float32)
        b = np.asarray(out.logits[:, 0], np.float32)
        err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert err < 5e-3, (t, err)


def test_ring_buffer_window_cache():
    """gemma3 local layers: ring cache smaller than the sequence still matches."""
    cfg = SMOKE_ARCHS["gemma3-1b"]  # sliding_window=16 in smoke config
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 1, 24  # prefill longer than the 16-slot ring
    toks = jax.random.randint(jax.random.key(3), (B, S + 2), 0, cfg.vocab_size)
    full = m.forward(params, toks)
    cache = m.init_cache(B, S + 2)
    out = m.forward(params, toks[:, :S], cache=cache, idx=0)
    for t in range(2):
        out = m.forward(params, toks[:, S + t : S + t + 1], cache=out.cache, idx=S + t)
        a = np.asarray(full.logits[:, S + t], np.float32)
        b = np.asarray(out.logits[:, 0], np.float32)
        err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert err < 5e-3, (t, err)


def test_segmentize_patterns():
    specs = ARCHS["deepseek-v3-671b"].layer_specs()
    segs = segmentize(specs)
    assert [(len(p), r) for p, r in segs] == [(1, 3), (1, 58)]
    segs = segmentize(ARCHS["jamba-v0.1-52b"].layer_specs())
    assert [(len(p), r) for p, r in segs] == [(8, 4)]
    segs = segmentize(ARCHS["gemma3-1b"].layer_specs())
    assert sum(len(p) * r for p, r in segs) == 26
    segs = segmentize(ARCHS["qwen1.5-4b"].layer_specs())
    assert [(len(p), r) for p, r in segs] == [(1, 40)]


def test_layer_specs_structure():
    cfg = ARCHS["jamba-v0.1-52b"]
    specs = cfg.layer_specs()
    assert sum(1 for s in specs if s.mixer == "attn") == 4  # 1:7 over 32 layers
    assert sum(1 for s in specs if s.ffn == "moe") == 16  # every other layer
    cfg = ARCHS["gemma3-1b"]
    specs = cfg.layer_specs()
    assert sum(1 for s in specs if s.window) >= 20  # 5:1 local:global
    cfg = ARCHS["deepseek-v3-671b"]
    specs = cfg.layer_specs()
    assert all(s.mixer == "mla" for s in specs)
    assert sum(1 for s in specs if s.ffn == "moe") == 58


def test_mrope_text_equals_1d_rope():
    """Identical t/h/w position streams must reduce M-RoPE to 1-D RoPE."""
    from repro.models.layers import apply_rope

    x = jax.random.normal(jax.random.key(0), (2, 8, 4, 32))
    pos1 = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos1, (3, 2, 8))
    a = apply_rope(x, pos1, 10000.0)
    b = apply_rope(x, pos3, 10000.0, mrope_sections=(4, 6, 6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
