"""SLO-miss forensics: classify every missed or shed frame into one cause.

A serve run's attainment number says *how many* frames missed the SLO; this
module says *why*, per frame, from the per-frame columns the pipelined
co-simulation already records (`pipeline.result.FrameTable`) plus the
control plane's epoch audit trail.  The taxonomy, in classification
priority order (each frame gets exactly ONE cause — the first that
applies):

============================ ===============================================
``admission_shed``           rejected at ingress by the admission policy
``machine_failure``          lost outright to a machine declared dead: its
                             in-flight work died with the machine and no
                             surviving sibling completed it
``recovery_transient``       late frame whose in-flight work was re-queued
                             off a dead machine — it completed, but paid
                             the detection latency + the re-queue wait
``admission_drop``           admitted, then lost mid-pipeline (tail drop,
                             zero-completion stage)
``cold_start_epoch``         late frame issued before the control plane's
                             first replan landed (the warm-up window a
                             misprovisioned initial plan has not yet been
                             repaired in)
``under_provisioned_epoch``  late frame issued in an epoch whose realized
                             offered rate exceeded the plan's provisioned
                             target — the estimator lagged the ramp
``backpressure_stall``       late frame that was parked by a bounded-queue
                             stage (cross-stage interference)
``flush_waste``              late frame served out of a deadline/drain/EOS
                             partial batch — capacity burned on unfilled
                             slots
``fanout_tail``              late frame whose critical-path-dominant stage
                             served it with fanout > 1 — its e2e waits on
                             the max over sibling instances
``service_overrun``          late frame with none of the above: plain
                             queueing + service beyond the budget
============================ ===============================================

The cascade is exhaustive by construction (``service_overrun`` absorbs the
remainder), which yields the **conservation invariant** every consumer can
assert:  ``sum(counts.values()) == misses == offered − completed-in-SLO``.

The columns feeding the middle rows (``stalled`` / ``flushed`` / ``fan``)
are always-on and cheap (one boolean/int write at an event that already
touches the frame), so forensics needs no opt-in: every ``pipeline=True``
result can answer ``miss_report()``.  One honest limitation: the segment
fast path never deadline-flushes (it only runs when the whole stream is
quiescent), so ``flushed`` stays ``False`` there and a would-be
``flush_waste`` frame classifies as ``service_overrun`` — conservation is
unaffected.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# classification priority order — index == cause code in ``cause_of``
MISS_CAUSES = (
    "admission_shed",
    "machine_failure",
    "recovery_transient",
    "admission_drop",
    "cold_start_epoch",
    "under_provisioned_epoch",
    "backpressure_stall",
    "flush_waste",
    "fanout_tail",
    "service_overrun",
)
_CODE = {c: i for i, c in enumerate(MISS_CAUSES)}


@dataclass
class MissReport:
    """Per-frame miss causes + the conservation bookkeeping around them."""

    cause_of: np.ndarray       # int8 per frame: MISS_CAUSES index, -1 = not a miss
    counts: dict[str, int]     # cause -> frame count (only the misses)
    offered: int               # completed + shed + dropped frames
    completed_in_slo: int      # completed frames with e2e <= slo
    slo: float

    @property
    def total(self) -> int:
        return int((self.cause_of >= 0).sum())

    @property
    def conserved(self) -> bool:
        """The invariant: cause counts sum exactly to total misses, and
        total misses equal offered − completed-in-SLO."""
        s = sum(self.counts.values())
        return s == self.total == self.offered - self.completed_in_slo

    @property
    def dominant(self) -> "str | None":
        """The most frequent miss cause (None when nothing missed)."""
        if not self.counts:
            return None
        return max(self.counts.items(), key=lambda kv: (kv[1], kv[0]))[0]

    def table(self) -> str:
        """Aligned text breakdown (``serve.py --trace`` / notebooks)."""
        total = self.total
        lines = [
            f"miss forensics: {total} misses / {self.offered} offered "
            f"(slo={self.slo:g}s, conserved={self.conserved})"
        ]
        for cause in MISS_CAUSES:
            n = self.counts.get(cause, 0)
            if n == 0:
                continue
            pct = 100.0 * n / max(total, 1)
            lines.append(f"  {cause:<24} {n:>8}  {pct:5.1f}%")
        return "\n".join(lines)


def classify_misses(pr, slo: float, epochs=None) -> MissReport:
    """Classify every miss of a `PipelineResult` (see module docstring).

    ``epochs`` is the control plane's ``ServeResult.epochs`` audit trail
    (or None when no control loop ran): it supplies the cold-start window
    and each epoch's provisioned target for the two epoch-level causes.
    """
    n = pr.e2e.size
    completed = pr.completed
    late = completed & (pr.e2e > slo + 1e-9)
    miss = pr.shed | pr.dropped | late
    offered = int(completed.sum() + pr.shed.sum() + pr.dropped.sum())
    in_slo = int((completed & ~late).sum())

    cause = np.full(n, -1, dtype=np.int8)

    def assign(mask: np.ndarray, name: str) -> None:
        take = miss & (cause < 0) & mask
        cause[take] = _CODE[name]

    assign(pr.shed, "admission_shed")
    # failure attribution trumps epoch attribution: a frame touched by a
    # dead machine missed because of the failure, whatever epoch it hit
    # (`failed` is None on pre-fault result objects — old pickles/tests)
    failed = getattr(pr, "failed", None)
    if failed is not None:
        assign(pr.dropped & failed, "machine_failure")
        assign(late & failed, "recovery_transient")
    assign(pr.dropped, "admission_drop")

    if epochs:
        ts = np.asarray([e.t for e in epochs], dtype=np.float64)
        issued = ~np.isnan(pr.issue)
        if ts.size >= 2:
            # cold start: issued before the first replan repaired the
            # initial plan (epochs[0] is the t=0 seed record)
            assign(late & issued & (pr.issue < ts[1]), "cold_start_epoch")
        # realized offered rate per epoch vs its provisioned target
        idx = np.searchsorted(ts, pr.issue[issued], side="right") - 1
        idx = np.clip(idx, 0, ts.size - 1)
        per_epoch = np.bincount(idx, minlength=ts.size).astype(np.float64)
        horizon = max(float(np.nanmax(pr.issue)), float(ts[-1]))
        spans = np.diff(np.append(ts, max(horizon, ts[-1] + 1e-12)))
        realized = per_epoch / np.maximum(spans, 1e-12)
        targets = np.asarray([e.target for e in epochs], dtype=np.float64)
        under = realized > targets * (1.0 + 1e-9)
        frame_epoch = np.zeros(n, dtype=np.int64)
        frame_epoch[issued] = idx
        assign(late & issued & under[frame_epoch], "under_provisioned_epoch")

    stalled = getattr(pr, "stalled", None)
    if stalled is not None:
        assign(late & stalled, "backpressure_stall")
    flushed = getattr(pr, "flushed", None)
    if flushed is not None:
        assign(late & flushed, "flush_waste")

    fan = getattr(pr, "fan", None)
    if fan is not None and late.any() and (cause[late] < 0).any():
        # dominant critical-path stage of each late frame: the one whose
        # sojourn the e2e decomposition charges the most to
        _, masks = pr.critical_path()
        soj = np.full((len(pr.modules), n), -np.inf)
        fans = np.zeros((len(pr.modules), n), dtype=np.int64)
        for i, m in enumerate(pr.modules):
            s = pr.sojourn(m)
            on = masks[m] & ~np.isnan(s)
            soj[i, on] = s[on]
            fans[i] = fan[m]
        dom = soj.argmax(axis=0)
        dom_fan = fans[dom, np.arange(n)]
        assign(late & (dom_fan > 1), "fanout_tail")

    assign(late, "service_overrun")  # exhaustive fallback

    codes, freq = np.unique(cause[cause >= 0], return_counts=True)
    counts = {MISS_CAUSES[c]: int(k) for c, k in zip(codes, freq)}
    return MissReport(
        cause_of=cause,
        counts=counts,
        offered=offered,
        completed_in_slo=in_slo,
        slo=slo,
    )
