"""Theorem 1 coverage: simulated worst latency vs the analytic module L_wc.

Property-style (seeded ``random`` loops, no hypothesis dependency): under
*uniform* arrivals — the paper's steady-state streaming regime — the
simulated max latency never exceeds the analytic worst case by more than the
one-batch fluid-limit jitter, across randomized profiles, rates, and both
TC and RR dispatch policies.

Theorem 1 is a *steady-state* bound: under Poisson arrivals at the same mean
rate the arrival process is no longer fluid, queues build during stochastic
bursts, and the observed max latency CAN exceed the analytic L_wc — the
final test documents exactly that, which is why the planner provisions
against the uniform-rate worst case, not against arbitrary stochastic
arrival processes.
"""
import random

import pytest

from repro.core import generate_config, module_wcl
from repro.core.dispatch import Policy, expand_machines
from repro.core.profiles import Config, ModuleProfile
from repro.serving import simulate


def _random_profile(rng: random.Random) -> ModuleProfile:
    cfgs = []
    base = rng.uniform(0.02, 0.5)
    for _ in range(rng.randint(2, 6)):
        b = 2 ** rng.randint(0, 6)
        beta = rng.uniform(0.1, 0.9)
        d = round(base * (1 + beta * b), 6)
        p = rng.choice([1.0, 1.35, 1.75])
        cfgs.append(Config(b, d, f"hw{p}", p))
    return ModuleProfile("m", tuple(cfgs))


@pytest.mark.parametrize("policy", [Policy.TC, Policy.RR])
def test_uniform_sim_bounded_by_analytic_wcl(policy):
    rng = random.Random(0 if policy is Policy.TC else 1)
    checked = 0
    for _ in range(120):
        profile = _random_profile(rng)
        T = rng.uniform(5.0, 300.0)
        L = rng.uniform(0.5, 10.0)
        ok, allocs = generate_config(T, L, profile, policy)
        if not ok or any(a.dummy > 0 for a in allocs):
            continue  # the simulator streams real requests only
        theory = module_wcl(allocs, policy)
        sim = simulate(allocs, T, policy=policy, n_requests=1200)
        if sim.n_requests == 0:
            continue
        # fluid-limit gap: the discrete dispatch walk can phase-shift a
        # machine's runs by up to one full round of everyone's batches,
        # transiently queueing one batch — so the tolerance is one round
        # (sum of batch sizes over the round) of arrivals, not one batch
        machines = expand_machines(allocs)
        jitter = sum(mm.config.batch for mm in machines) / T
        assert sim.max_latency <= theory + jitter + 1e-6, (
            policy,
            sim.max_latency,
            theory,
        )
        checked += 1
    assert checked >= 30, f"only {checked} feasible draws exercised"


def test_poisson_can_exceed_wcl_steady_state_assumption():
    """Documents the steady-state assumption: with Poisson arrivals at the
    provisioned mean rate, stochastic bursts push the observed max latency
    past the analytic (fluid) worst case."""
    rng = random.Random(3)
    exceeded = False
    tried = 0
    while tried < 40 and not exceeded:
        profile = _random_profile(rng)
        T = rng.uniform(50.0, 300.0)
        ok, allocs = generate_config(T, rng.uniform(0.5, 3.0), profile, Policy.TC)
        if not ok or any(a.dummy > 0 for a in allocs):
            continue
        theory = module_wcl(allocs, Policy.TC)
        tried += 1
        for seed in range(5):
            sim = simulate(
                allocs, T, policy=Policy.TC, n_requests=3000,
                arrivals="poisson", seed=seed,
            )
            if sim.n_requests and sim.max_latency > theory + 1e-9:
                exceeded = True
                break
    assert exceeded, "Poisson arrivals never exceeded the fluid worst case"
