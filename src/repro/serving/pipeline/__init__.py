"""Pipelined serving core: per-frame DAG co-simulation with backpressure.

The third simulation layer (after ``arrivals`` and ``frontend``): instead of
replaying modules one at a time with analytic hand-off (the flat engine),
every frame traverses the app DAG as a tracked entity inside one global
discrete-event loop — per-module ingress fed by upstream batch completions,
bounded queues exerting backpressure on upstream dispatch, seeded per-frame
fanout correlated across sibling modules, and closed-loop clients plus
admission control reacting to true instantaneous backlog.

Entry points:

* ``ServingEngine.run(..., pipeline=True)`` — the engine builds the stages
  from a plan and returns a ``ServeResult`` whose ``.pipeline`` field holds
  the full :class:`PipelineResult` (per-frame e2e latencies, per-module
  budget-overrun attribution).
* :func:`run_pipeline` — the raw co-simulation over hand-built
  :class:`ModuleStage` objects, for tests and custom topologies.
"""
from .core import PipelineConfig, run_pipeline
from .equeue import CalendarQueue, HeapQueue, make_queue
from .fanout import AccumulatorFanout, DrawnFanout, FanoutSpec, draw_counts, make_stage_fanouts
from .result import FrameTable, PipelineResult
from .stages import (
    Instance,
    ModuleStage,
    RRDispatcher,
    StageStats,
    StageUpdate,
    TCDispatcher,
    make_dispatcher,
)

__all__ = [
    "AccumulatorFanout",
    "CalendarQueue",
    "DrawnFanout",
    "FanoutSpec",
    "FrameTable",
    "HeapQueue",
    "Instance",
    "ModuleStage",
    "PipelineConfig",
    "PipelineResult",
    "RRDispatcher",
    "StageStats",
    "StageUpdate",
    "TCDispatcher",
    "draw_counts",
    "make_dispatcher",
    "make_queue",
    "make_stage_fanouts",
    "run_pipeline",
]
