"""Event-driven core vs vectorized replay kernel: they must agree exactly.

Also the tail-batch regression suite: the seed engine "flushed" tail batches
with a no-op deadline (`t_ready = max(t_ready, t_ready)`) and the seed
simulator dropped them outright; the unified core gives partial batches real
deadline semantics (flush when the opener has waited ``timeout``), mid-stream
and at end of stream.
"""
import random

import numpy as np
import pytest

from repro.core.dispatch import Machine, Policy, dispatch_runs
from repro.core.profiles import Config
from repro.serving import simulate, simulate_reference
from repro.serving.arrivals import make_arrivals
from repro.serving.events import simulate_module_events
from repro.serving.replay import replay_machine, replay_module, runs_to_assignment


def _random_machines(rng: random.Random) -> list[Machine]:
    machines = []
    for mid in range(rng.randint(1, 4)):
        b = 2 ** rng.randint(0, 5)
        d = round(rng.uniform(0.02, 0.4), 6)
        cfg = Config(b, d, "hw", rng.choice([1.0, 1.35, 1.75]))
        rate = cfg.throughput * rng.uniform(0.3, 1.0)
        machines.append(Machine(mid, cfg, rate))
    return machines


@pytest.mark.parametrize("policy", [Policy.TC, Policy.RR])
@pytest.mark.parametrize("kind", ["uniform", "poisson", "mmpp"])
def test_vectorized_matches_event_core(policy, kind):
    rng = random.Random(hash((policy.value, kind)) & 0xFFFF)
    for trial in range(8):
        machines = _random_machines(rng)
        n = rng.randint(30, 400)
        rate = sum(m.rate for m in machines)
        ready = make_arrivals(kind, n, rate, seed=trial)
        runs = dispatch_runs(machines, n, policy)
        timeout = rng.choice([None, 0.05, 0.5, 5.0])
        tail = rng.choice(["flush", "drop"]) if timeout is None else "flush"
        vec = replay_module(machines, ready, runs, timeout=timeout, tail=tail)
        ev = replay_module(
            machines, ready, runs, timeout=timeout, tail=tail, method="events"
        )
        np.testing.assert_array_equal(vec.assignment, ev.assignment)
        assert vec.batches == ev.batches, (trial, timeout, tail)
        np.testing.assert_allclose(
            vec.finish, ev.finish, rtol=0, atol=1e-9, equal_nan=True
        )


def test_per_machine_timeout_mapping():
    """`timeout` may be a per-machine-id mapping (shorter collection windows
    for slower machines); kernel and event core must agree on it."""
    rng = random.Random(99)
    for trial in range(6):
        machines = _random_machines(rng)
        n = rng.randint(50, 300)
        rate = sum(m.rate for m in machines)
        ready = make_arrivals("mmpp", n, rate, seed=trial)
        runs = dispatch_runs(machines, n, Policy.TC)
        wmap = {m.mid: rng.uniform(0.05, 1.0) for m in machines}
        vec = replay_module(machines, ready, runs, timeout=wmap)
        ev = replay_module(machines, ready, runs, timeout=wmap, method="events")
        assert vec.batches == ev.batches
        np.testing.assert_allclose(
            vec.finish, ev.finish, rtol=0, atol=1e-9, equal_nan=True
        )


def test_simulate_events_method_agrees():
    cfg = Config(8, 0.1)
    machines_rate = 8 / 0.1
    from repro.core.dispatch import Alloc

    allocs = [Alloc(cfg, machines=2.0, rate=2 * machines_rate)]
    for kind in ("uniform", "poisson"):
        a = simulate(allocs, 2 * machines_rate, n_requests=500, arrivals=kind)
        b = simulate(
            allocs, 2 * machines_rate, n_requests=500, arrivals=kind, method="events"
        )
        assert a.n_requests == b.n_requests
        assert a.max_latency == pytest.approx(b.max_latency, abs=1e-9)
        assert a.mean_latency == pytest.approx(b.mean_latency, abs=1e-9)


# ---------------------------------------------------------------- tail batches


def test_tail_requests_complete_under_timeout():
    """Regression (seed bug): tail requests now complete with real deadline
    semantics instead of inheriting whole-batch / drop behavior."""
    cfg = Config(8, 0.1)
    m = Machine(0, cfg, cfg.throughput)
    rate = cfg.throughput
    n = 20  # 2 full batches of 8 + a tail of 4
    ready = make_arrivals("uniform", n, rate)
    w = 0.3
    finish, _ = replay_machine(ready, 8, 0.1, timeout=w)
    assert not np.isnan(finish).any(), "tail requests must complete"
    # the tail batch opens at request 16 and flushes exactly at opener + W
    expected_flush = ready[16] + w
    assert finish[16:] == pytest.approx(expected_flush + 0.1)
    # legacy simulator dropped exactly those 4 requests
    from repro.core.dispatch import Alloc

    ref = simulate_reference([Alloc(cfg, 1.0, rate)], rate, n_requests=n)
    assert ref.n_requests == 16
    new = simulate([Alloc(cfg, 1.0, rate)], rate, n_requests=n, timeout=w, tail="flush")
    assert new.n_requests == n and new.dropped == 0


def test_no_op_deadline_fixed_tail_latency_bounded():
    """With a finite timeout, a tail request's latency is bounded by
    timeout + service (+ queueing), not by the never-arriving batch fill."""
    cfg = Config(32, 0.05)  # big batch: without the deadline the tail waits on
    rate = 100.0            # 24 more requests that never come
    ready = make_arrivals("uniform", 8, rate)  # lone partial batch
    w = 0.2
    finish, nb = replay_machine(ready, 32, 0.05, timeout=w)
    assert nb == 1
    lat = finish - ready
    assert lat.max() <= w + 0.05 + 1e-9
    # and the flush happens at the deadline, not at the last arrival
    assert finish[0] == pytest.approx(ready[0] + w + 0.05)


def test_midstream_timeout_flush_on_burst_gap():
    """A long arrival gap triggers a mid-stream partial flush — the event
    core and the kernel's greedy fallback must both split the batch."""
    ready = np.array([0.0, 0.01, 0.02, 0.03, 5.0, 5.01, 5.02, 5.03])
    cfg = Config(8, 0.1)
    m = Machine(0, cfg, cfg.throughput)
    for impl in ("kernel", "events"):
        if impl == "kernel":
            finish, nb = replay_machine(ready, 8, 0.1, timeout=1.0)
        else:
            finish, batches = simulate_module_events(
                [m], ready, np.zeros(8, dtype=int), timeout=1.0
            )
            nb = batches[0]
        assert nb == 2, impl
        # first four flush at t=0+1.0, done at 1.1; second four at 5.0+1.0
        assert finish[:4] == pytest.approx(1.1), impl
        assert finish[4:] == pytest.approx(6.1), impl


def test_tail_drop_vs_flush_without_timeout():
    ready = make_arrivals("uniform", 10, 50.0)
    f_drop, nb_drop = replay_machine(ready, 8, 0.1, tail="drop")
    f_flush, nb_flush = replay_machine(ready, 8, 0.1, tail="flush")
    assert np.isnan(f_drop[8:]).all() and not np.isnan(f_drop[:8]).any()
    assert not np.isnan(f_flush).any()
    assert nb_drop == 1 and nb_flush == 2
    # seed-engine semantics: tail executes at its last arrival
    assert f_flush[8:] == pytest.approx(max(ready[9], f_flush[0]) + 0.1)


def test_event_core_executor_plumbing():
    """A constant-duration executor must reproduce the profiled-duration
    virtual-time replay bit for bit."""
    cfg = Config(4, 0.07)
    m = Machine(0, cfg, cfg.throughput)
    ready = make_arrivals("poisson", 40, cfg.throughput, seed=9)
    assignment = np.zeros(40, dtype=int)
    calls = []

    def executor(machine, group):
        calls.append((machine.mid, group))
        return 0.07

    f_ex, b_ex = simulate_module_events(
        [m], ready, assignment, timeout=0.5, executor=executor
    )
    f_vt, b_vt = simulate_module_events([m], ready, assignment, timeout=0.5)
    np.testing.assert_allclose(f_ex, f_vt, atol=1e-12)
    assert len(calls) == b_ex[0] == b_vt[0]
    assert all(g <= cfg.batch for _, g in calls)
