"""Shared-pool serving: every app on one consolidated device pool.

`SharedPool` is the engine wiring of the tenancy layer: it takes the
per-app module-centric plans, derives the pool's :class:`DevicePlan`
through the :class:`GlobalAllocator`, and runs each app's serving loop
with interference folded into the co-located machines' service durations
(`InterferenceServiceTime` — co-located batches honestly run slower).

Cost accounting is the point: `PoolResult.savings` compares the pool's
integer-device bill against ``dedicated_cost`` — the sum of per-app
exclusive deployments with every fractional allocation rounded up to
whole devices.  Attainment is measured per app by the same simulators a
dedicated deployment would use, so "cheaper at equal attainment" is an
apples-to-apples claim.

Control-plane arbitration: with ``control=`` each app's `ControlRuntime`
gets an ``on_swap`` hook; every committed plan hot-swap resubmits the
tenant's new plan to the global allocator, which repacks the pool,
updates the app's live interference factors in place (the service-time
source reads them per batch start), and emits ``colocate`` / ``evict``
instants plus per-device occupancy counters to the pool's trace.  App
loops co-simulate sequentially over the same simulated horizon, so a
repack triggered by app A is visible to apps run after it and to A's own
remaining batches; it does not retroactively slow batches an earlier
tenant's finished run already recorded — the epoch-synchronous
approximation of a fully interleaved pool.

Tenancy off (``tenancy=None``) degrades to per-app dedicated serving
with no interference and no shared devices: results are bit-exact with
`ServingEngine.run` per app (pinned by ``tests/test_tenancy.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace as dc_replace
from typing import Mapping

from ...core.harpagon import Plan
from ...profiling.interference import InterferenceModel, calibrate
from ..control import ControlLoopConfig
from ..engine import ServeResult, ServingEngine
from ..faults import FaultConfig
from ..observability import Observability
from ..service_time import InterferenceServiceTime, resolve_service_time
from .allocator import AllocatorConfig, GlobalAllocator, dedicated_cost
from .device import DevicePlan, DevicePlanDelta


@dataclass(frozen=True)
class TenancyConfig:
    """Pool-level knobs: the interference model and the packing rules.

    ``interference=None`` calibrates the default model from ``seed``
    (`profiling.interference.calibrate` — deterministic).  The remaining
    fields forward to :class:`AllocatorConfig`; ``guard=False`` packs
    purely by capacity (useful to measure what the feasibility guard is
    buying), ``slo_slack`` tightens (<1) or loosens (>1) the guard's
    end-to-end latency ceiling.
    """

    interference: "InterferenceModel | None" = None
    seed: int = 0
    max_coresident: int = 2
    occupancy_cap: float = 1.0
    guard: bool = True
    slo_slack: float = 1.0

    def model(self) -> InterferenceModel:
        return self.interference or calibrate(seed=self.seed)

    def allocator(self) -> GlobalAllocator:
        return GlobalAllocator(
            AllocatorConfig(
                interference=self.model(),
                max_coresident=self.max_coresident,
                occupancy_cap=self.occupancy_cap,
                guard=self.guard,
                slo_slack=self.slo_slack,
            )
        )


@dataclass
class PoolResult:
    """Per-app serve results plus the pool-level consolidation ledger."""

    results: "dict[str, ServeResult]"
    device_plan: DevicePlan
    dedicated_cost: float
    n_frames: "dict[str, int]"
    repacks: "list[DevicePlanDelta]" = field(default_factory=list)
    trace: "object | None" = None  # pool-level TraceRecorder (colocate/evict)

    @property
    def pool_cost(self) -> float:
        return self.device_plan.cost

    @property
    def savings(self) -> float:
        """How much cheaper the shared pool is than dedicated deployments."""
        return self.dedicated_cost / self.pool_cost if self.pool_cost else 1.0

    @property
    def offered(self) -> int:
        return sum(r.offered for r in self.results.values())

    @property
    def attainment(self) -> float:
        """Offered-frame-weighted aggregate SLO attainment across apps."""
        total = self.offered
        if total == 0:
            return 1.0
        return sum(
            r.attainment * r.offered for r in self.results.values()
        ) / total

    def conservation(self) -> "dict[str, bool]":
        """Per-app frame accounting: every issued frame resolved terminally.

        completed + shed + dropped (+ skipped, for frames a zero-instance
        fanout legitimately excluded from the sink) == frames issued."""
        out: dict[str, bool] = {}
        for app, r in self.results.items():
            n = self.n_frames[app]
            p = r.pipeline
            if p is not None:
                resolved = int(
                    p.completed.sum() + p.shed.sum() + p.dropped.sum()
                    + p.skipped.sum()
                )
                out[app] = resolved == n
            else:
                out[app] = r.offered == n
        return out

    def summary(self) -> str:
        lines = [
            f"pool: cost={self.pool_cost:.4g} dedicated={self.dedicated_cost:.4g}"
            f" savings={self.savings:.3f}x attainment={self.attainment:.4f}"
            f" devices={len(self.device_plan.devices)}"
            f" shared={self.device_plan.n_shared} repacks={len(self.repacks)}"
        ]
        for app, r in sorted(self.results.items()):
            lines.append(
                f"  {app}: offered={r.offered} attainment={r.attainment:.4f}"
                f" p99={r.p99:.3f} shed={r.shed} dropped={r.dropped}"
            )
        return "\n".join(lines)


class SharedPool:
    """One machine pool serving every app's plan (see module docstring)."""

    def __init__(
        self,
        plans: "Mapping[str, Plan]",
        *,
        tenancy: "TenancyConfig | None" = TenancyConfig(),
        executors: "Mapping[str, Mapping] | None" = None,
    ):
        if not plans:
            raise ValueError("SharedPool needs at least one app plan")
        for app, plan in plans.items():
            if plan.workload.app.name != app:
                raise ValueError(
                    f"plans key {app!r} does not match its workload app "
                    f"{plan.workload.app.name!r}"
                )
        self.plans = dict(plans)
        self.tenancy = tenancy
        self.executors = executors or {}
        if tenancy is not None:
            self.model = tenancy.model()
            self.allocator = tenancy.allocator()
            self.device_plan = self.allocator.pack(self.plans)
        else:
            # disabled: every machine keeps its own device, no interference
            self.model = None
            self.allocator = GlobalAllocator(
                AllocatorConfig(interference=None, max_coresident=1)
            )
            self.device_plan = self.allocator.pack(self.plans)
        self.dedicated_cost = dedicated_cost(self.plans)

    @property
    def enabled(self) -> bool:
        return self.tenancy is not None

    def _emit_pack(self, obs: "Observability | None", t: float,
                   dp: DevicePlan) -> None:
        if obs is None:
            return
        for d in dp.devices:
            if d.shared:
                for s in d.slots:
                    obs.colocate(t, d.did, s.app, s.module, s.mid, s.fraction)
            obs.device_occupancy(t, d.did, d.occupancy)

    def _emit_delta(self, obs: "Observability | None", t: float,
                    dp: DevicePlan, delta: DevicePlanDelta) -> None:
        if obs is None or delta.empty:
            return
        for did, (app, module, mid) in delta.evicted:
            obs.evict(t, did, app, module, mid)
        for did, (app, module, mid) in delta.colocated:
            d = dp.devices[did] if did < len(dp.devices) else None
            frac = 0.0
            if d is not None:
                for s in d.slots:
                    if s.key == (app, module, mid):
                        frac = s.fraction
                        break
            obs.colocate(t, did, app, module, mid, frac)
        for d in dp.devices:
            obs.device_occupancy(t, d.did, d.occupancy)

    def _frame_rate(self, app: str,
                    frame_rates: "Mapping[str, float] | float | None") -> float:
        if isinstance(frame_rates, Mapping):
            return float(frame_rates[app])
        if frame_rates is not None:
            return float(frame_rates)
        # derive from the workload: the DAG's first module is the source
        # and carries the app-level frame rate (fanout 1.0 by convention)
        wl = self.plans[app].workload
        return float(wl.rates[wl.app.modules[0]])

    def run(
        self,
        n_frames: "int | Mapping[str, int]",
        frame_rates: "Mapping[str, float] | float | None" = None,
        *,
        arrivals="uniform",
        seed: int = 0,
        timeout=None,
        tail: str = "flush",
        frontend=None,
        offered_rates: "Mapping[str, float] | None" = None,
        pipeline=True,
        control: "ControlLoopConfig | Mapping[str, ControlLoopConfig] | None" = None,
        service_time=None,
        observability=None,
        faults: "FaultConfig | Mapping[str, FaultConfig] | None" = None,
    ) -> PoolResult:
        """Serve every app of the pool over one simulated horizon.

        Arguments mirror `ServingEngine.run`; per-app values may be given
        as mappings keyed by app name (``n_frames``, ``frame_rates``,
        ``offered_rates``, ``control``, ``faults``).  Each app's arrival
        stream is seeded with ``seed + its rank`` in sorted-app order, so
        streams are distinct but the whole pool run is deterministic.
        ``observability`` builds one pool-level sink (colocate/evict
        instants, occupancy counters — returned as ``PoolResult.trace``)
        and an independent per-app sink per run (on each `ServeResult`).

        ``faults`` arms the seeded injector inside each app's loop; with
        tenancy enabled the config is wired to the pool before the run —
        the app's machine slots are mapped to their physical devices (as
        packed at run start), so a ``device_loss`` fault takes down every
        co-located slot of one device at once and triggers the allocator's
        out-of-band `GlobalAllocator.fail_device` repack (evicted residues
        re-homed onto surviving devices, interference factors refreshed).
        """
        pool_obs = Observability.make(observability)
        dp = self.device_plan
        self._emit_pack(pool_obs, 0.0, dp)
        results: dict[str, ServeResult] = {}
        frames: dict[str, int] = {}
        repacks: list[DevicePlanDelta] = []
        for rank, app in enumerate(sorted(self.plans)):
            plan = self.plans[app]
            n = n_frames[app] if isinstance(n_frames, Mapping) else int(n_frames)
            frames[app] = n
            rate = self._frame_rate(app, frame_rates)
            base = resolve_service_time(
                service_time, self.executors.get(app)
            )
            factors: dict[tuple[str, int], float] = {}
            if self.enabled:
                factors.update({
                    (m, mid): f
                    for (a, m, mid), f in self.device_plan.interference_factors(
                        self.model, app
                    ).items()
                })
            app_control = (
                control.get(app) if isinstance(control, Mapping) else control
            )
            src = base
            if self.enabled and (factors or app_control is not None):
                # live factors: an epoch repack mutates the dict in place
                # and the next batch start reads the new slowdown
                src = InterferenceServiceTime(factors, base=base)
            if app_control is not None and self.enabled:
                def _on_swap(t, new_plan, _app=app, _factors=factors,
                             _obs=pool_obs):
                    new_dp, delta = self.allocator.submit(_app, new_plan)
                    self.device_plan = new_dp
                    self.plans[_app] = new_plan
                    _factors.clear()
                    _factors.update({
                        (m, mid): f
                        for (a, m, mid), f in new_dp.interference_factors(
                            self.model, _app
                        ).items()
                    })
                    repacks.append(delta)
                    self._emit_delta(_obs, t, new_dp, delta)
                app_control = dc_replace(app_control, on_swap=_on_swap)
            app_faults = (
                faults.get(app) if isinstance(faults, Mapping) else faults
            )
            if app_faults is not None and self.enabled:
                # wire the injector to the pool: this app's machine slots
                # mapped to their physical devices (run-start packing), and
                # the allocator's out-of-band device-death repack — the
                # hardware monitor's signal, fired at the injection instant
                device_map = {
                    (s.module, s.mid): d.did
                    for d in self.device_plan.devices
                    for s in d.slots
                    if s.app == app
                }
                def _on_loss(t, dead_did, _app=app, _factors=factors,
                             _obs=pool_obs):
                    new_dp, delta = self.allocator.fail_device(dead_did)
                    self.device_plan = new_dp
                    _factors.clear()
                    _factors.update({
                        (m, mid): f
                        for (a, m, mid), f in new_dp.interference_factors(
                            self.model, _app
                        ).items()
                    })
                    repacks.append(delta)
                    self._emit_delta(_obs, t, new_dp, delta)
                app_faults = dc_replace(
                    app_faults, device_map=device_map, on_device_loss=_on_loss
                )
            eng = ServingEngine(plan, executors=self.executors.get(app))
            results[app] = eng.run(
                n,
                rate,
                arrivals=arrivals,
                seed=seed + rank,
                timeout=timeout,
                tail=tail,
                frontend=frontend,
                offered_rate=(
                    offered_rates.get(app) if offered_rates else None
                ),
                pipeline=pipeline,
                control=app_control,
                service_time=src,
                observability=observability,
                faults=app_faults,
            )
        return PoolResult(
            results=results,
            device_plan=self.device_plan,
            dedicated_cost=self.dedicated_cost,
            n_frames=frames,
            repacks=repacks,
            trace=pool_obs.trace if pool_obs is not None else None,
        )


__all__ = ["PoolResult", "SharedPool", "TenancyConfig"]
