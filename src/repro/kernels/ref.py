"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: Pallas kernels are validated against these
in interpret mode across shape/dtype sweeps, and CPU execution (smoke tests,
examples) runs them directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6, gemma: bool = False) -> jax.Array:
    """RMSNorm; ``gemma=True`` uses the (1 + w) parameterization."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if gemma else w.astype(jnp.float32)
    return (y * scale).astype(dtype)


def _mask(
    q_len: int, k_len: int, *, causal: bool, window: int | None, q_offset: int = 0
) -> jax.Array:
    """(q_len, k_len) boolean attention mask.

    ``q_offset`` is the absolute position of query row 0 (for prefill the
    query block starts at 0; for masked decode it is the cache length).
    """
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(k_len)[None, :]
    m = jnp.ones((q_len, k_len), dtype=bool)
    if causal:
        m &= kj <= qi
    if window is not None:
        m &= kj > qi - window
    return m


def attention(
    q: jax.Array,  # (B, Sq, Hq, Dk)
    k: jax.Array,  # (B, Sk, Hkv, Dk)
    v: jax.Array,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
    kv_len: jax.Array | None = None,  # (B,) valid cache lengths, for decode
) -> jax.Array:
    """Grouped-query attention oracle.  Returns (B, Sq, Hq, Dv).

    Supports distinct key/value head dims (needed by MLA-absorbed decode) and
    an optional per-batch valid KV length for cache attention.
    """
    B, Sq, Hq, Dk = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    scale = scale if scale is not None else Dk ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, Dk)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    mask = _mask(Sq, Sk, causal=causal, window=window, q_offset=q_offset)
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < kv_len[:, None]  # (B, Sk)
        mask = mask[None] & valid[:, None, :]
        mask = mask[:, None, None]  # (B,1,1,Sq,Sk)
    else:
        mask = mask[None, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def attention_chunked(
    q: jax.Array,  # (B, Sq, Hq, Dk)
    k: jax.Array,  # (B, Sk, Hkv, Dk)
    v: jax.Array,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention over KV chunks (flash-style, jnp only).

    Never materializes the (Sq, Sk) logits — O(Sq * chunk) working set —
    so long-context prefill neither blows HBM nor forces the SPMD
    partitioner into resharding a quadratic tensor.
    """
    B, Sq, Hq, Dk = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else Dk ** -0.5
    C = min(chunk, Sk)
    assert Sk % C == 0, (Sk, C)
    nC = Sk // C
    # keep q/k/v in their native (bf16) dtype and accumulate in f32 — the
    # same contract as the TPU flash kernel; halves the streamed KV bytes
    qf = q.reshape(B, Sq, Hkv, g, Dk)
    kc = k.reshape(B, nC, C, Hkv, Dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nC, C, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(Sq)

    def body(carry, xs):
        acc, m, l = carry
        ci, kb, vb = xs  # (B, C, Hkv, D*)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qf, kb, preferred_element_type=jnp.float32
        ) * scale
        kpos = ci * C + jnp.arange(C)
        mask = jnp.ones((Sq, C), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Hkv, g, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, Hkv, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(nC), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, Hkv, g, Sq, Dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,        # (B, Hq, Dk) — one new token per sequence
    k_cache: jax.Array,  # (B, S, Hkv, Dk)
    v_cache: jax.Array,  # (B, S, Hkv, Dv)
    lengths: jax.Array,  # (B,) number of valid cache entries (incl. this token)
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Single-step cache attention oracle.  Returns (B, Hq, Dv)."""
    B, Hq, Dk = q.shape
    _, S, Hkv, _ = k_cache.shape
    Dv = v_cache.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else Dk ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Dk)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, kf) * scale
    pos = jnp.arange(S)[None, :]
    valid = pos < lengths[:, None]
    if window is not None:
        valid &= pos > (lengths[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(B, Hq, Dv).astype(q.dtype)


def ssm_scan(
    a: jax.Array,  # (B, L, D, N) discretized decay  exp(dt * A)
    bx: jax.Array,  # (B, L, D, N) discretized input  dt * B * x
    h0: jax.Array | None = None,  # (B, D, N)
) -> tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t * h_{t-1} + bx_t (selective-SSM core).

    Returns (h all steps (B, L, D, N), final state (B, D, N)).
    """
    B, L, D, N = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D, N), a.dtype)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = a_s * h0[:, None] + b_s
    return h, h[:, -1]


def selective_scan(
    x: jax.Array,  # (B, L, D)
    dt: jax.Array,  # (B, L, D)
    A: jax.Array,  # (D, N)
    Bm: jax.Array,  # (B, L, N)
    Cm: jax.Array,  # (B, L, N)
    h0: jax.Array | None = None,  # (B, N, D) transposed state layout
) -> tuple[jax.Array, jax.Array]:
    """Fused Mamba selective-scan oracle: y = C . scan(exp(dt A), dt B x).

    Returns (y (B, L, D), h_last (B, N, D)).
    """
    B, L, D = x.shape
    N = A.shape[1]
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A.astype(jnp.float32))  # (B, L, D, N)
    bx = (dtf * x.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[..., None, :]
    h0_dn = None if h0 is None else jnp.swapaxes(h0, 1, 2)  # (B, D, N)
    hs, h_last = ssm_scan(a, bx, h0_dn)
    y = jnp.einsum("bldn,bln->bld", hs, Cm.astype(jnp.float32))
    return y.astype(x.dtype), jnp.swapaxes(h_last, 1, 2)


def mlstm_chunked(
    q: jax.Array,  # (B, L, H, D)
    k: jax.Array,  # (B, L, H, D)
    v: jax.Array,  # (B, L, H, D)
    i_gate: jax.Array,  # (B, L, H) log input gate (pre-exp)
    f_gate: jax.Array,  # (B, L, H) log forget gate (log sigmoid applied)
    *,
    chunk: int = 64,
) -> jax.Array:
    """mLSTM parallel form oracle (full quadratic; the kernel is chunked).

    Stabilized exponential gating as in the xLSTM paper: with cumulative log
    forget F_t = sum_{s<=t} logf_s, the unnormalized weight of (t, s) is
    exp(F_t - F_s + i_s - m_t) where m_t is the running max for stability.
    """
    B, L, H, D = q.shape
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    lf = f_gate.astype(jnp.float32)
    li = i_gate.astype(jnp.float32)
    F = jnp.cumsum(lf, axis=1)  # (B, L, H)
    # log weight matrix  Dmat[t, s] = F_t - F_s + i_s  (s <= t)
    logw = F[:, :, None] - F[:, None, :] + li[:, None, :]  # (B, L, L, H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    logw = jnp.where(tri[None, :, :, None], logw, NEG_INF)
    m = jnp.max(logw, axis=2, keepdims=True)  # (B, L, 1, H)
    w = jnp.exp(logw - m)
    scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * (D ** -0.5)
    num = jnp.einsum("btsh,btsh,bshd->bthd", scores, w, vf)
    den = jnp.abs(jnp.einsum("btsh,btsh->bth", scores, w))
    den = jnp.maximum(den, jnp.exp(-m[:, :, 0, :]))  # xLSTM max(|n|, exp(-m))
    return (num / den[..., None]).astype(q.dtype)
