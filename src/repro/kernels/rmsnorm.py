"""Pallas TPU fused RMSNorm: one HBM round-trip per row block.

Grid over row blocks; each step loads a (BR, D) tile into VMEM, reduces the
mean-square in f32 on the VPU and writes the scaled tile back — avoiding the
separate square/mean/rsqrt/mul HLO ops (4x HBM traffic) of the naive form.

Oracle: `repro.kernels.ref.rmsnorm`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, *, eps: float, gemma: bool):
    x = x_ref[:].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[:].astype(jnp.float32)
    scale = 1.0 + w if gemma else w
    o_ref[:] = (y * scale[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "gemma", "block_rows", "interpret"))
def fused_rmsnorm(
    x: jax.Array,  # (..., D)
    w: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    gemma: bool = False,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    shape = x.shape
    D = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    xr = x.reshape(rows, D)
    br = min(block_rows, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % br
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps, gemma=gemma),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((rows + pad), D), x.dtype),
        compiler_params=pltpu.CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xr, w)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
